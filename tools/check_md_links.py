#!/usr/bin/env python3
"""Markdown link checker for the docs CI job.

    python tools/check_md_links.py README.md docs

Checks every relative ``[text](target)`` link in the given markdown
files (directories are scanned for ``*.md``) and fails when a target
does not resolve on disk.  External links (``http(s)://``, ``mailto:``)
and pure-anchor links (``#...``) are skipped; a ``path#anchor`` link is
checked for the path only."""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — excludes images' leading ! only for clarity; image
# targets are checked the same way
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files(args: list[str]) -> list[Path]:
    out: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        else:
            out.append(p)
    return out


def check(files: list[Path]) -> list[str]:
    errors: list[str] = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        for n, line in enumerate(f.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:          # pure in-page anchor
                    continue
                if not (f.parent / path).exists():
                    errors.append(f"{f}:{n}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = md_files(argv or ["README.md", "docs"])
    errors = check(files)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_md_links] {len(files)} files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
