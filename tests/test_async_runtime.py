"""Event-driven async region runtime (core/executor.py, core/schedule.py):
region-level DAG structure, the property-tested async == sync bitwise
equivalence, host-callback semantics under the pooled dispatcher
(threading, program order, donation snapshots, exception propagation),
and completion-time StepStats (runtime/supervisor.py)."""

import os
import sys
import threading

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DistTensor, ExecutionKind, Executor, Graph, Layout,
                        region_dag, region_waves)
from repro.runtime.supervisor import StepStats

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _graph_gen import build_random_graph  # noqa: E402

from conftest import run_subprocess_devices  # noqa: E402

LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)


def _cb_chain_graph(seen, tags=("a", "b")):
    """device(write a) -> host(read a) -> device(write b) -> host(read b):
    the minimal interleaved chain the dispatcher must keep in order."""
    a = DistTensor("a", (8,))
    b = DistTensor("b", (8,))
    g = Graph(name="cbchain")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then((lambda t: lambda x: seen.append((t, float(np.asarray(x)[0]))))(
        tags[0]), exec_kind=ExecutionKind.Cpu, args=(a,))
    g.then_split(lambda x: x + 2.0, b, writes=(0,))
    g.then((lambda t: lambda x: seen.append((t, float(np.asarray(x)[0]))))(
        tags[1]), exec_kind=ExecutionKind.Cpu, args=(b,))
    return g


# -- region-level DAG structure ------------------------------------------------

def test_region_dag_lifts_unit_edges_with_reasons():
    seen = []
    g = _cb_chain_graph(seen)
    ex = Executor(g, donate=False)
    edges = region_dag(ex.dag, ex.plan.regions)
    assert edges == ex.plan.region_edges
    pairs = {(e.src, e.dst): e.reason for e in edges}
    # every edge points forward, and the host reads depend on the device
    # writes that produce their arguments
    assert all(s < d for s, d in pairs)
    kinds = {r.index: r.kind for r in ex.plan.regions}
    host_deps = [e for e in edges if kinds[e.dst] == "host"
                 and kinds[e.src] == "device" and e.reason == "raw"]
    assert host_deps, edges


def test_region_waves_layer_by_dependencies():
    seen = []
    g = _cb_chain_graph(seen)
    ex = Executor(g, donate=False)
    waves = region_waves(ex.plan.regions, ex.plan.region_edges)
    assert waves == ex.plan.region_waves()
    placed = [i for w in waves for i in w]
    assert sorted(placed) == [r.index for r in ex.plan.regions]
    pos = {i: wi for wi, w in enumerate(waves) for i in w}
    for e in ex.plan.region_edges:
        assert pos[e.src] < pos[e.dst], e


def test_describe_lists_region_ready_waves():
    seen = []
    g = _cb_chain_graph(seen)
    ex = Executor(g, donate=False)
    out = ex.describe_dag()
    assert "region ready waves (async dispatch order):" in out
    assert "wave 0" in out
    assert "region 0" in out and "->" in out


# -- dispatcher behavior -------------------------------------------------------

def test_async_host_callbacks_run_on_pool_thread():
    threads = []
    a = DistTensor("a", (8,))
    g = Graph(name="thr")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda x: threads.append(threading.current_thread().name),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    ex = Executor(g, donate=False, async_regions=True)
    ex(ex.init_state())
    assert threads and all(t.startswith("ripple-host") for t in threads)


def test_sync_escape_hatch_runs_on_main_thread():
    threads = []
    a = DistTensor("a", (8,))
    g = Graph(name="thr2")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda x: threads.append(threading.current_thread().name),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    ex = Executor(g, donate=False, async_regions=False)
    ex(ex.init_state())
    assert threads == ["MainThread"]


def test_async_host_callbacks_preserve_program_order():
    """Side-effect order is part of the contract: pooled callbacks are
    chained, so two data-independent callbacks still fire in program
    order, across repeated steps."""
    seen = []
    g = _cb_chain_graph(seen)
    ex = Executor(g, donate=False, async_regions=True)
    ex.run(ex.init_state(), 3)
    assert seen == [("a", 1.0), ("b", 2.0), ("a", 2.0), ("b", 4.0),
                    ("a", 3.0), ("b", 6.0)]


def test_async_values_match_sync_per_step():
    """The callback must observe the value at its program point of the
    CURRENT step even while later steps are already dispatched."""
    for mode in (False, True):
        x = DistTensor("x", (8,))
        seen = []
        g = Graph(name="vals")
        g.split(lambda v: v + 1.0, x, writes=(0,))
        g.then(lambda v: seen.append(float(np.asarray(v)[0])),
               exec_kind=ExecutionKind.Cpu, args=(x,))
        g.then_split(lambda v: v * 2.0, x, writes=(0,))
        ex = Executor(g, donate=False, async_regions=mode)
        st = ex.run(ex.init_state(), 3)
        assert seen == [1.0, 3.0, 7.0], f"async_regions={mode}"
        np.testing.assert_array_equal(np.asarray(st["x"]), np.full(8, 14.0))


def test_async_donation_snapshots_host_args():
    """With donate=True the next region's executable overwrites the
    argument buffers in place — the dispatcher must snapshot host args at
    submit time so an in-flight callback reads the pre-overwrite value."""
    x = DistTensor("x", (1 << 16,))   # big enough to really be donated
    seen = []
    g = Graph(name="donated")
    g.split(lambda v: v + 1.0, x, writes=(0,))
    g.then(lambda v: seen.append(float(np.asarray(v)[0])),
           exec_kind=ExecutionKind.Cpu, args=(x,))
    g.then_split(lambda v: v * 2.0, x, writes=(0,))
    ex = Executor(g, donate=True, async_regions=True)
    ex.run(ex.init_state(), 4)
    assert seen == [1.0, 3.0, 7.0, 15.0]


def test_async_callback_exception_propagates_and_cancels():
    """A failing callback surfaces its ORIGINAL exception from the run,
    later chained callbacks are cancelled (side-effect order: nothing
    after a failure may fire), and nothing deadlocks."""
    a = DistTensor("a", (8,))
    seen = []

    def boom(x):
        raise ValueError("callback failed")

    g = Graph(name="boom")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda x: seen.append("before"), exec_kind=ExecutionKind.Cpu,
           args=(a,))
    g.then(boom, exec_kind=ExecutionKind.Cpu, args=(a,))
    g.then(lambda x: seen.append("after"), exec_kind=ExecutionKind.Cpu,
           args=(a,))
    ex = Executor(g, donate=False, async_regions=True)
    with pytest.raises(ValueError, match="callback failed"):
        ex(ex.init_state())
    assert seen == ["before"]


def test_async_executor_usable_after_callback_failure():
    """The pool is process-wide: one failed epoch must not poison the
    executor (or the pool) for later calls."""
    a = DistTensor("a", (8,))
    fail = [True]
    ran = []

    def maybe_boom(x):
        if fail[0]:
            raise RuntimeError("transient")
        ran.append(float(np.asarray(x)[0]))

    g = Graph(name="recover")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(maybe_boom, exec_kind=ExecutionKind.Cpu, args=(a,))
    ex = Executor(g, donate=False, async_regions=True)
    with pytest.raises(RuntimeError, match="transient"):
        ex(ex.init_state())
    fail[0] = False
    st = ex(ex.init_state())
    assert ran == [1.0]
    np.testing.assert_array_equal(np.asarray(st["a"]), np.full(8, 1.0))


def test_async_flag_not_in_plan_signature():
    """Both modes run the SAME cached executables — the flag must not
    fork the process-wide executable cache."""
    seen = []
    g = _cb_chain_graph(seen)
    ex_a = Executor(g, donate=False, async_regions=True)
    ex_s = Executor(g, donate=False, async_regions=False)
    assert ex_a.plan.signature == ex_s.plan.signature


# -- property tests: async == sync, bitwise ------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), layout=st.sampled_from(list(LAYOUTS)),
       donate=st.sampled_from([False, True]))
def test_prop_async_equals_sync(seed, layout, donate):
    """The acceptance bar: identical final state bitwise between the
    event-driven dispatcher and the synchronous escape hatch, on random
    graphs WITH host callbacks, across layouts and donation modes."""
    g, overrides, keys = build_random_graph(seed, layout,
                                            host_callbacks=True)
    outs = {}
    for mode in (True, False):
        ex = Executor(g, donate=donate, async_regions=mode)
        outs[mode] = ex.run(ex.init_state(**overrides()), 2)
    for k in keys:
        np.testing.assert_array_equal(
            np.asarray(outs[True][k]), np.asarray(outs[False][k]),
            err_msg=f"seed={seed} layout={layout} donate={donate} key={k}")


# -- StepStats completion-time contract ----------------------------------------

def test_stepstats_tracks_dispatch_separately():
    s = StepStats()
    for i in range(10):
        s.update(0.1, i, dispatch=0.02)
    assert s.mean == pytest.approx(0.1)
    assert s.dispatch_mean == pytest.approx(0.02)
    assert s.last_dispatch == pytest.approx(0.02)
    assert s.overlap_ms == pytest.approx(80.0)


def test_stepstats_overlap_zero_without_dispatch():
    s = StepStats()
    for i in range(5):
        s.update(0.1, i)
    assert s.overlap_ms == 0.0


def test_stepstats_straggler_judged_on_completion():
    """A step whose dispatch returned instantly but whose completion was
    slow IS a straggler — async dispatch must not blind the detector."""
    s = StepStats()
    for i in range(20):
        s.update(0.1 + 1e-4 * (i % 3), i, dispatch=0.001)
    assert s.update(1.0, 20, dispatch=0.001) is True
    assert s.stragglers and s.stragglers[-1][0] == 20


# -- multi-device equivalence (slow lane) --------------------------------------

_CHILD_ASYNC = r"""
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
from repro.core import Executor, Layout, make_mesh
from _graph_gen import build_random_graph

mesh = make_mesh(({n},), ("gx",))
for seed in range({seeds}):
    for layout in (Layout.AOS, Layout.SOA, Layout.AOSOA):
        g, overrides, keys = build_random_graph(seed, layout,
                                                partition=("gx",),
                                                host_callbacks=True)
        outs = []
        for mode in (True, False):
            ex = Executor(g, mesh=mesh, donate=False, async_regions=mode)
            outs.append(ex.run(ex.init_state(**overrides()), 2))
        for k in keys:
            np.testing.assert_array_equal(
                np.asarray(outs[0][k]), np.asarray(outs[1][k]),
                err_msg=f"seed={{seed}} layout={{layout}} key={{k}}")
print("ASYNC-EQUIV-OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n_devices,seeds", [(2, 6), (8, 4)])
def test_async_equals_sync_multidevice(n_devices, seeds):
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_subprocess_devices(
        _CHILD_ASYNC.format(tests_dir=tests_dir, n=n_devices, seeds=seeds),
        n_devices=n_devices)
    assert "ASYNC-EQUIV-OK" in out
