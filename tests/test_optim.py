"""Optimizers, schedules, ZeRO-1 spec derivation, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.optim import (AdamW, Adafactor, clip_by_global_norm,
                         cosine_schedule, dequantize_int8, linear_warmup,
                         quantize_int8)
from repro.optim.optimizers import zero1_pspec


def _quad_problem(opt, steps=200):
    """min ||x - 3||^2 — any reasonable optimizer converges."""
    params = {"x": jnp.zeros((4, 8))}
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        g = jax.grad(lambda p: jnp.sum((p["x"] - 3.0) ** 2))(params)
        return opt.update(g, state, params, i)

    for i in range(steps):
        params, state = step(params, state, jnp.asarray(i))
    return params


def test_adamw_converges():
    p = _quad_problem(AdamW(5e-2, weight_decay=0.0))
    np.testing.assert_allclose(np.asarray(p["x"]), 3.0, atol=0.05)


def test_adafactor_converges():
    p = _quad_problem(Adafactor(5e-1), steps=400)
    np.testing.assert_allclose(np.asarray(p["x"]), 3.0, atol=0.1)


def test_adamw_matches_reference_step():
    """One AdamW step against a hand-computed update."""
    opt = AdamW(1e-1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([2.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5])}
    newp, _ = opt.update(g, state, params, jnp.asarray(0))
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.01 * 0.25 / (1 - 0.99)
    expect = 2.0 - 0.1 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), [expect], rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), np.sqrt(90 + 160), rtol=1e-6)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_schedules():
    s = cosine_schedule(1.0, 10, 100, floor=0.1)
    assert float(s(0)) < 0.2
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-3)
    np.testing.assert_allclose(float(s(1000)), 0.1, rtol=1e-2)
    w = linear_warmup(2.0, 4)
    np.testing.assert_allclose(float(w(1)), 1.0)


def test_zero1_pspec():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    # data axis size 1 -> unchanged
    assert zero1_pspec(P(None, "model"), (8, 4), mesh, ("data",)) \
        == P(None, "model")


def test_adamw_state_pspecs_structure():
    opt = AdamW(1e-3)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    pspecs = {"w": P(None, "model")}
    out = opt.state_pspecs(shapes, pspecs, mesh, ("data",), zero1=True)
    assert set(out.keys()) == {"m", "v"}
    assert out["m"]["w"] == P(None, "model")


def test_adafactor_state_pspecs_structure():
    opt = Adafactor(1e-3)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    pspecs = {"w": P("model", None), "b": P(None)}
    out = opt.state_pspecs(shapes, pspecs, mesh, ("data",), zero1=True)
    assert out["w"]["vr"] == P("model")
    assert out["w"]["vc"] == P(None)
    assert "v" in out["b"]


# -- int8 compression -----------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-4, 1e3))
def test_prop_quantize_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128).astype(np.float32)) * scale
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-6  # round-to-nearest bound


def test_quantize_zero():
    q, s = quantize_int8(jnp.zeros(16))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)),
                                  np.zeros(16))


def test_error_feedback_accumulates_exactly():
    """With a constant gradient, error feedback makes the AVERAGE of the
    dequantized series converge to the true gradient."""
    from repro.optim.compression import quantize_int8 as qz
    g = jnp.asarray(np.random.default_rng(0).standard_normal(64)
                    .astype(np.float32))
    resid = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        eff = g + resid
        q, s = qz(eff)
        g_hat = dequantize_int8(q, s)
        resid = eff - g_hat
        total = total + g_hat
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g),
                               atol=float(s) / 2 / n * 3 + 1e-5)
