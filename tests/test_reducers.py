"""Reduction library: mul / bitwise and-or-xor + the NaN-correct
min/max pairs (spec table: ``min``/``max`` IGNORE quiet NaNs,
``minimum``/``maximum`` PROPAGATE them)."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess_devices
from repro.core import (AndReducer, DistTensor, Graph, MaxReducer,
                        MaximumReducer, MinReducer, MinimumReducer,
                        MulReducer, OrReducer, XorReducer, execute,
                        make_reduction_result)


def _reduce_value(values, reducer, dtype, init=0.0):
    x = DistTensor("x", (len(values),), dtype=dtype)
    res = make_reduction_result("r", init=init, dtype=dtype)
    g = Graph()
    g.reduce(x, res, reducer)
    state = execute(g, x=jnp.asarray(values, dtype))
    return np.asarray(state["r"])


def test_mul_reducer():
    got = _reduce_value([2.0, -3.0, 0.5, 4.0], MulReducer(), jnp.float32)
    np.testing.assert_allclose(got, -12.0)
    # zeros must work (no log-sum tricks)
    assert _reduce_value([2.0, 0.0, 5.0], MulReducer(), jnp.float32) == 0.0
    assert _reduce_value([3, 5, 7], MulReducer(), jnp.int32) == 105


def test_bitwise_reducers_int():
    vals = [0b1100, 0b1010, 0b1110]
    assert _reduce_value(vals, AndReducer(), jnp.int32) == 0b1000
    assert _reduce_value(vals, OrReducer(), jnp.int32) == 0b1110
    assert _reduce_value(vals, XorReducer(), jnp.int32) == (
        0b1100 ^ 0b1010 ^ 0b1110)


def test_logical_reducers_bool():
    assert _reduce_value([True, True, False], AndReducer(),
                         jnp.bool_) == False          # noqa: E712
    assert _reduce_value([False, False, True], OrReducer(),
                         jnp.bool_) == True           # noqa: E712
    assert _reduce_value([True, True, True], AndReducer(),
                         jnp.bool_) == True           # noqa: E712


def test_min_max_ignore_quiet_nan():
    vals = [1.0, np.nan, 3.0]
    assert _reduce_value(vals, MaxReducer(), jnp.float32) == 3.0
    assert _reduce_value(vals, MinReducer(), jnp.float32) == 1.0
    # the all-NaN slice still reduces to qNaN
    assert np.isnan(_reduce_value([np.nan, np.nan], MaxReducer(),
                                  jnp.float32))


def test_minimum_maximum_propagate_quiet_nan():
    vals = [1.0, np.nan, 3.0]
    assert np.isnan(_reduce_value(vals, MaximumReducer(), jnp.float32))
    assert np.isnan(_reduce_value(vals, MinimumReducer(), jnp.float32))
    # no NaN present: plain extrema
    assert _reduce_value([1.0, 3.0], MaximumReducer(), jnp.float32) == 3.0
    assert _reduce_value([1.0, 3.0], MinimumReducer(), jnp.float32) == 1.0


def test_int_minmax_unaffected():
    assert _reduce_value([4, -2, 9], MaxReducer(), jnp.int32) == 9
    assert _reduce_value([4, -2, 9], MinimumReducer(), jnp.int32) == -2


@pytest.mark.slow
def test_fold_combiners_sharded():
    """mul/xor/maximum have no lax.p* collective — the executor combines
    them with all_gather + local fold; the sharded result must equal the
    single-device reference (incl. NaN propagation across shards)."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (DistTensor, Executor, Graph, MaximumReducer,
                        MulReducer, XorReducer, make_mesh,
                        make_reduction_result)
mesh = make_mesh((4,), ("gx",))
size = 16

def run(reducer, vals, dtype):
    x = DistTensor("x", (size,), dtype=dtype, partition=("gx",))
    res = make_reduction_result("r", dtype=dtype)
    g = Graph()
    g.split(lambda xs: xs, x, writes=(0,))
    g.then_reduce(x, res, reducer)
    ex = Executor(g, mesh=mesh)
    st = ex(ex.init_state(x=jnp.asarray(vals, dtype)))
    return np.asarray(st["r"])

rng = np.random.default_rng(0)
fvals = rng.uniform(0.5, 1.5, size).astype(np.float32)
np.testing.assert_allclose(run(MulReducer(), fvals, jnp.float32),
                           np.prod(fvals), rtol=1e-5)
ivals = rng.integers(0, 1 << 16, size).astype(np.int32)
assert run(XorReducer(), ivals, jnp.int32) == np.bitwise_xor.reduce(ivals)
# NaN on ONE shard must poison the cross-shard maximum
nvals = fvals.copy(); nvals[9] = np.nan
assert np.isnan(run(MaximumReducer(), nvals, jnp.float32))
print("OK")
""")
