"""Tuner conformance harness (satellite of the joint-search tuner).

Property tests over random generated graphs asserting the autotuner's
CORRECTNESS contract: a tuned plan — whatever joint layout × tile
configuration the search commits — produces BITWISE-identical values to
the heuristic plan, across record layouts, donation settings and both
schedules.  Layout changes are pure storage permutations and the
generated tile site (``_graph_gen``'s ``"genrec"``) is
reshape-into-blocks + elementwise, so exact equality is the right bar:
any drift means the tuner changed semantics, not just performance.

Also covers pruning invariance (HLO cost-model pruning never changes
the committed argmin beyond timing noise vs. a measure-everything
search) and per-segment layout overrides (the tuner's per-segment
decision axis is value-exact on multi-segment graphs).
"""

import contextlib
import os
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Executor, Layout
from repro.tuning import cache as tune_cache
from repro.tuning import search as tune_search
from repro.tuning.search import TuneBudget

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _graph_gen import build_random_graph  # noqa: E402

LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)

# a tight budget keeps each tuned construction to a handful of timed
# candidates — conformance is about VALUES, not search quality
FAST_BUDGET = {"max_measure": 2, "neighborhoods": 1}


@contextlib.contextmanager
def _fresh_cache():
    """Hermetic tuning cache per hypothesis EXAMPLE (a function-scoped
    pytest fixture would be shared across a test's examples)."""
    with tempfile.TemporaryDirectory() as d:
        old = os.environ.get("REPRO_TUNE_CACHE")
        os.environ["REPRO_TUNE_CACHE"] = d
        tune_cache.clear_memo()
        tune_search.reset_stats()
        try:
            yield
        finally:
            tune_cache.clear_memo()
            if old is None:
                os.environ.pop("REPRO_TUNE_CACHE", None)
            else:
                os.environ["REPRO_TUNE_CACHE"] = old


def _canonical(ex, state, keys):
    """State values independent of storage layout: record tensors read
    field-by-field (undoing any tuned layout permutation), scalars and
    reduction results as-is."""
    out = {}
    for k in keys:
        t = ex.tensors.get(k)
        if t is not None and t.is_record:
            rec = ex.read(state, t)
            for f in t.spec.names:
                out[f"{k}.{f}"] = np.asarray(rec.field(f))
        else:
            out[k] = np.asarray(state[k])
    return out


# -- bitwise equality of tuned vs heuristic plans ------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), layout=st.sampled_from(list(LAYOUTS)),
       donate=st.booleans(), schedule=st.sampled_from(["dag", "sequential"]))
def test_tuned_plan_bitwise_equals_heuristic(seed, layout, donate, schedule):
    with _fresh_cache():
        g, overrides, keys = build_random_graph(seed, layout,
                                                tile_sites=True)
        base = Executor(g, donate=donate, schedule=schedule)
        tuned = Executor(g, donate=donate, schedule=schedule, tune="auto",
                         tune_budget=FAST_BUDGET)
        dec = tuned.plan.tuning
        assert dec is not None and dec.source == "measured"
        assert dec.proposed == dec.pruned + dec.measured

        want = _canonical(base, base.run(base.init_state(**overrides()), 3),
                          keys)
        got = _canonical(tuned, tuned.run(tuned.init_state(**overrides()),
                                          3), keys)
        assert want.keys() == got.keys()
        for k in want:
            np.testing.assert_array_equal(
                want[k], got[k],
                err_msg=f"seed={seed} layout={layout.name} donate={donate} "
                        f"schedule={schedule} key={k} "
                        f"decision={dec.describe()}")


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), layout=st.sampled_from(list(LAYOUTS)))
def test_tuned_tile_sites_bitwise_equal_across_blocks(seed, layout):
    """Forcing every feasible 'genrec' block produces the same bits —
    the generated tile axis provably cannot change values, so whatever
    block the tuner commits is conformant by construction."""
    g, overrides, keys = build_random_graph(seed, layout, tile_sites=True)
    outs = []
    for block in (2, 4, 8, 16):
        ex = Executor(g, donate=False, tile_overrides={"genrec": block})
        outs.append(_canonical(
            ex, ex.run(ex.init_state(**overrides()), 2), keys))
    for other in outs[1:]:
        for k in outs[0]:
            np.testing.assert_array_equal(outs[0][k], other[k],
                                          err_msg=f"seed={seed} key={k}")


# -- pruning invariance --------------------------------------------------------

def test_pruned_search_matches_measure_all_argmin_within_noise():
    """The HLO cost ranking decides what gets MEASURED, never what wins:
    on a fixed workload, the pruned search's committed configuration
    performs within timing noise of the exhaustive (measure_all)
    search's, and the pruned search really measures at most 40% of the
    proposed joint space."""
    with _fresh_cache():
        # seed 1 draws a record node: 3 layouts x 4 genrec tiles proposed
        g, overrides, keys = build_random_graph(1, Layout.AOS,
                                                tile_sites=True)
        ex = Executor(g, donate=False)

        full = tune_search.measure_plan(ex, "full",
                                        TuneBudget(measure_all=True))
        pruned = tune_search.measure_plan(ex, "pruned", None)

        assert full.proposed == pruned.proposed >= 8
        assert full.measured == full.proposed      # exhaustive: no pruning
        # the pruned run really pruned
        assert pruned.measured <= 0.4 * pruned.proposed + 1
        # the pruned run only ever measures configs the exhaustive run
        # measured too — ranking decides the ORDER, not the space
        full_configs = {m.candidate for m in full.measurements}
        assert {m.candidate for m in pruned.measurements} <= full_configs
        # and its argmin is not meaningfully worse than exhaustive search
        # (loose bound: this 16x12 workload is dispatch-dominated, so
        # run-to-run medians of IDENTICAL configs can differ ~2x)
        assert pruned.tuned_ms <= full.tuned_ms * 3.0
        # both runs beat (or tie) their own baselines by construction
        assert full.tuned_ms <= full.baseline_ms + 1e-9
        assert pruned.tuned_ms <= pruned.baseline_ms + 1e-9


def test_measure_all_times_every_proposal():
    with _fresh_cache():
        g, _, _ = build_random_graph(5, Layout.SOA, tile_sites=True)
        ex = Executor(g, donate=False)
        dec = tune_search.measure_plan(ex, "exhaustive",
                                       TuneBudget(measure_all=True))
        # every proposal got timing data (the baseline combo via the
        # probe), so nothing was pruned
        assert dec.pruned == 0
        assert dec.measured == dec.proposed
        assert all(not m.early_stopped for m in dec.measurements)


# -- per-segment decisions -----------------------------------------------------

def _multi_segment_workload():
    """A generated graph whose record tensor is live in >= 2 segments
    under the SEQUENTIAL schedule (host callbacks split device segments
    in program order; the DAG schedule would hoist all record nodes into
    segment 0), plus its overrides."""
    for seed in range(64):
        g, overrides, keys = build_random_graph(
            seed, Layout.AOS, host_callbacks=True, tile_sites=True)
        ex = Executor(g, donate=False, schedule="sequential")
        homes = [si for si, seg in enumerate(ex.plan.per_segment)
                 if "r" in seg]
        if len(homes) >= 2:
            return g, overrides, keys, homes
    pytest.skip("no multi-segment generated graph found")


def test_per_segment_layout_overrides_are_value_exact():
    g, overrides, keys, homes = _multi_segment_workload()
    base = Executor(g, donate=False, schedule="sequential")
    want = _canonical(base, base.run(base.init_state(**overrides()), 2),
                      keys)
    for lay in (Layout.SOA, Layout.AOSOA):
        ex = Executor(g, donate=False, schedule="sequential",
                      segment_layout_overrides={homes[-1]: {"r": lay}})
        assert ex.plan.per_segment[homes[-1]]["r"] is lay
        # a mixed-segment assignment forces a mid-graph relayout
        assert any(st.tensor == "r" for st in ex.plan.relayouts)
        got = _canonical(ex, ex.run(ex.init_state(**overrides()), 2), keys)
        for k in want:
            np.testing.assert_array_equal(
                want[k], got[k], err_msg=f"segment layout {lay.name} "
                                         f"key={k}")


def test_per_segment_override_changes_plan_signature():
    g, _, _, homes = _multi_segment_workload()
    a = Executor(g, donate=False, schedule="sequential")
    b = Executor(g, donate=False, schedule="sequential",
                 segment_layout_overrides={homes[-1]: {"r": Layout.SOA}})
    c = Executor(g, donate=False, schedule="sequential",
                 segment_layout_overrides={homes[-1]: {"r": Layout.SOA}})
    assert a.plan.signature != b.plan.signature
    assert b.plan.signature == c.plan.signature
