"""Loop-aware HLO cost analysis vs hand-counted programs."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import analyze_hlo, normalize_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * n ** 3


def test_scan_multiplies_trip_count():
    n, T = 128, 12

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * T * n ** 3
    # xla's own analysis counts the body once — document the discrepancy
    # (+ a few scalar flops for the loop counter); cost_analysis() is a
    # per-device list on older JAX, a dict on newer
    assert normalize_cost_analysis(c.cost_analysis())["flops"] < 2 * 2 * n ** 3


def test_nested_scan():
    n, T, U = 64, 5, 7

    def f(x, ws):
        def outer(c, w):
            c2 = lax.scan(lambda d, _: (d @ w, None), c, None, length=U)[0]
            return c2, None
        return lax.scan(outer, x, ws)[0]

    c = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * T * U * n ** 3


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 48, 16
    c = _compile(lambda x, y: jnp.einsum("bmk,bkn->bmn", x, y),
                 jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                 jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * b * m * k * n


def test_bytes_scale_with_trip_count():
    n, T = 128, 10

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c1 = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    c2 = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((2 * T, n, n), jnp.float32))
    r1 = analyze_hlo(c1.as_text())
    r2 = analyze_hlo(c2.as_text())
    assert 1.7 < r2["bytes"] / r1["bytes"] < 2.3


def test_no_collectives_single_device():
    c = _compile(lambda x: x * 2, jax.ShapeDtypeStruct((8,), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["collective_link_bytes"] == 0
