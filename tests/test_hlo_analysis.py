"""Loop-aware HLO cost analysis vs hand-counted programs.

Two layers of goldens:

* synthetic jit programs (matmul / scan / nested scan) pin the parser's
  trip-count and dot-flop arithmetic exactly;
* every repro kernel's REGION HLO (``Executor.region_hlo`` of a
  one-node graph on the jnp reference path) is checked against
  hand-counted flops (exact, where the kernel has dots) and a
  hand-derived algorithmic-minimum byte figure (banded — the model
  charges 2x per pad/slice/copy boundary, so the band documents the
  model's fusion-boundary semantics rather than an XLA version).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import (CostRanker, analyze_hlo, layout_access_penalty,
                            normalize_cost_analysis)
from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        RecordArray)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * n ** 3


def test_scan_multiplies_trip_count():
    n, T = 128, 12

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * T * n ** 3
    # xla's own analysis counts the body once — document the discrepancy
    # (+ a few scalar flops for the loop counter); cost_analysis() is a
    # per-device list on older JAX, a dict on newer
    assert normalize_cost_analysis(c.cost_analysis())["flops"] < 2 * 2 * n ** 3


def test_nested_scan():
    n, T, U = 64, 5, 7

    def f(x, ws):
        def outer(c, w):
            c2 = lax.scan(lambda d, _: (d @ w, None), c, None, length=U)[0]
            return c2, None
        return lax.scan(outer, x, ws)[0]

    c = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * T * U * n ** 3


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 48, 16
    c = _compile(lambda x, y: jnp.einsum("bmk,bkn->bmn", x, y),
                 jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                 jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * b * m * k * n


def test_bytes_scale_with_trip_count():
    n, T = 128, 10

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c1 = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((T, n, n), jnp.float32))
    c2 = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((2 * T, n, n), jnp.float32))
    r1 = analyze_hlo(c1.as_text())
    r2 = analyze_hlo(c2.as_text())
    assert 1.7 < r2["bytes"] / r1["bytes"] < 2.3


def test_no_collectives_single_device():
    c = _compile(lambda x: x * 2, jax.ShapeDtypeStruct((8,), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["collective_link_bytes"] == 0


# -- kernel region-HLO goldens --------------------------------------------------
#
# One small single-node graph per kernel (jnp reference path: the
# Pallas-interpret HLO is a dynamic-slice loop nest whose byte count
# reflects the interpreter, not the kernel).  flops goldens are EXACT —
# the model counts dots only, so elementwise/stencil kernels are 0 and
# attention/ssd are hand-countable.  bytes goldens are bands around the
# hand-counted algorithmic minimum ``ideal`` (every input read + output
# written once): the model charges result+operands at fusion boundaries
# and 2x for pad/slice/copy/transpose, so a kernel with k boundary ops
# per element lands at a small documented multiple of ideal.

_RNG = np.random.default_rng(0)


def _region_cost(ex, state):
    return analyze_hlo(ex.region_hlo(state))


def _saxpy_executor(n=4096, layout=Layout.SOA):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    r = DistTensor("r", (n,), spec=SAXPY_SPEC, layout=layout)
    g = Graph(name="hlo_saxpy")
    g.split(lambda rec: saxpy_record(rec, 2.0, use_pallas=False), r,
            writes=(0,))
    ex = Executor(g, donate=False)
    init = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(_RNG.standard_normal(n, dtype=np.float32)),
         "y": jnp.asarray(_RNG.standard_normal(n, dtype=np.float32))},
        layout)
    return ex, ex.init_state(r=init)


def _particle_executor(n=4096, layout=Layout.SOA):
    from repro.kernels.particle.kernel import PARTICLE_SPEC
    from repro.kernels.particle.ops import particle_update

    p = DistTensor("p", (n,), spec=PARTICLE_SPEC, layout=layout)
    g = Graph(name="hlo_particle")
    g.split(lambda rec: particle_update(rec, 0.25, use_pallas=False), p,
            writes=(0,))
    ex = Executor(g, donate=False)
    init = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(_RNG.standard_normal((n, 3), dtype=np.float32)),
         "v": jnp.asarray(_RNG.standard_normal((n, 3), dtype=np.float32))},
        layout)
    return ex, ex.init_state(p=init)


def test_region_saxpy_record_golden():
    n = 4096
    ex, state = _saxpy_executor(n)
    r = _region_cost(ex, state)
    assert r["flops"] == 0          # y = a*x + y is pure elementwise
    ideal = 3 * n * 4               # read x, read y, write y (f32)
    assert ideal <= r["bytes"] <= 6 * ideal
    assert r["collective_link_bytes"] == 0


def test_region_particle_golden():
    n = 4096
    ex, state = _particle_executor(n)
    r = _region_cost(ex, state)
    assert r["flops"] == 0          # leapfrog update: elementwise
    ideal = 4 * n * 3 * 4           # read x, v; write x, v ((n, 3) f32)
    assert ideal <= r["bytes"] <= 5 * ideal
    assert r["collective_link_bytes"] == 0


def test_region_flux_stencil_golden():
    from repro.kernels.stencil.ops import make_flux_difference_graph
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    nx, ny = 64, 128
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                   halo=(1, 1), boundary=Boundary.TRANSMISSIVE)
    out = DistTensor("du", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA)
    g = make_flux_difference_graph(u, out, 0.1, 0.1, overlap=False)
    ex = Executor(g, donate=False)
    state = ex.init_state(u=RecordArray(shock_bubble_init(nx, ny),
                                        EULER_SPEC, Layout.SOA))
    r = _region_cost(ex, state)
    assert r["flops"] == 0          # FORCE flux: elementwise + shifts
    # read + write one 4-field Euler record; FORCE pays the boundary pad
    # plus per-axis/per-field shifted slices, each charged 2x by the
    # model, hence the wide-but-bounded band
    ideal = 2 * 4 * nx * ny * 4
    assert 2 * ideal <= r["bytes"] <= 64 * ideal
    assert r["collective_link_bytes"] == 0


def test_region_eikonal_golden():
    from repro.kernels.eikonal.ops import make_eikonal_graph

    nx, ny = 64, 128
    phi = DistTensor("phi", (nx, ny), halo=(1, 1))
    mask = DistTensor("mask", (nx, ny), dtype=jnp.bool_)
    g = make_eikonal_graph(phi, mask, 1.0 / nx, overlap=False)
    ex = Executor(g, donate=False)
    phi0 = jnp.full((nx, ny), 10.0).at[nx // 2, ny // 2].set(0.0)
    mask0 = jnp.zeros((nx, ny), bool).at[nx // 2, ny // 2].set(True)
    r = _region_cost(ex, ex.init_state(phi=phi0, mask=mask0))
    assert r["flops"] == 0          # godunov update: min/sqrt, no dots
    ideal = 2 * nx * ny * 4         # read phi, write phi
    assert ideal <= r["bytes"] <= 6 * ideal
    assert r["collective_link_bytes"] == 0


def test_region_attention_golden():
    from repro.kernels.attention.ops import flash_attention

    B, H, S, D = 1, 2, 128, 32
    q = DistTensor("q", (B, H, S, D))
    k = DistTensor("k", (B, H, S, D))
    v = DistTensor("v", (B, H, S, D))
    g = Graph(name="hlo_attn")
    g.split(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                            use_pallas=False),
            q, k, v, writes=(0,))
    ex = Executor(g, donate=False)

    def arr():
        return jnp.asarray(_RNG.standard_normal((B, H, S, D),
                                                dtype=np.float32))

    r = _region_cost(ex, ex.init_state(q=arr(), k=arr(), v=arr()))
    # exactly two dots: Q@K^T and P@V, 2*B*H*S*S*D each (the causal mask
    # and softmax are elementwise/reduce — zero model flops)
    assert r["flops"] == 4 * B * H * S * S * D
    # the S x S score matrix dominates traffic; at least one
    # materialization, at most a dozen boundary crossings of it
    scores = B * H * S * S * 4
    assert scores <= r["bytes"] <= 24 * scores
    assert r["collective_link_bytes"] == 0


def test_region_ssd_golden():
    from repro.kernels.ssd.ops import ssd

    B, S, H, P, N, chunk = 1, 256, 2, 16, 8, 64
    x = DistTensor("x", (B, S, H, P))
    dt = DistTensor("dt", (B, S, H))
    A = DistTensor("A", (H,))
    Bm = DistTensor("Bm", (B, S, N))
    C = DistTensor("C", (B, S, N))
    g = Graph(name="hlo_ssd")
    g.split(lambda x, dt, A, Bm, C: ssd(x, dt, A, Bm, C, chunk=chunk,
                                        use_pallas=False)[0],
            x, dt, A, Bm, C, writes=(0,))
    ex = Executor(g, donate=False)
    state = ex.init_state(
        x=jnp.asarray(_RNG.standard_normal((B, S, H, P), dtype=np.float32)),
        dt=jnp.abs(jnp.asarray(_RNG.standard_normal((B, S, H),
                                                    dtype=np.float32))),
        A=-jnp.ones((H,), jnp.float32),
        Bm=jnp.asarray(_RNG.standard_normal((B, S, N), dtype=np.float32)),
        C=jnp.asarray(_RNG.standard_normal((B, S, N), dtype=np.float32)))
    r = _region_cost(ex, state)
    # chunked dual form, hand-counted dot by dot:
    #   CB^T       2*B*S*chunk*N      (per-chunk (L, N) @ (N, L))
    #   scores@dx  2*B*S*H*chunk*P
    #   B^T@x      2*B*S*H*P*N        (chunk states)
    #   C@state    2*B*S*H*P*N        (inter-chunk outputs)
    want = (2 * B * S * chunk * N + 2 * B * S * H * chunk * P
            + 4 * B * S * H * P * N)
    assert r["flops"] == want
    # read x, write y — the (nc, H, chunk, chunk) score blocks add ~2x
    # of that per materialization on top
    ideal = 2 * B * S * H * P * 4
    assert ideal <= r["bytes"] <= 20 * ideal
    assert r["collective_link_bytes"] == 0


# -- cost-ranking monotonicity --------------------------------------------------

def _rank_layouts(ex, state, storage_bytes, num_fields):
    """Rank AoS/AoSoA/SoA for one record workload from its heuristic
    region HLO, exactly as the joint tuner does."""
    ranker = CostRanker([ex.region_hlo(state)])
    entries = [(name, layout_access_penalty(name, storage_bytes,
                                            num_fields))
               for name in ("AOS", "AOSOA", "SOA")]
    return ranker.rank(entries)


def test_cost_ranking_orders_bad_layout_below_heuristic_saxpy():
    n = 4096
    ex, state = _saxpy_executor(n)          # heuristic: SoA streams fields
    ranked = _rank_layouts(ex, state, storage_bytes=2 * n * 4, num_fields=2)
    assert [c.label for c in ranked] == ["SOA", "AOSOA", "AOS"]
    assert ranked[0].predicted_bytes < ranked[-1].predicted_bytes
    # the penalty is additive on a shared HLO base
    assert ranked[-1].predicted_bytes - ranked[0].predicted_bytes == \
        layout_access_penalty("AOS", 2 * n * 4, 2)


def test_cost_ranking_orders_bad_layout_below_heuristic_particle():
    n = 4096
    ex, state = _particle_executor(n)
    ranked = _rank_layouts(ex, state, storage_bytes=2 * n * 3 * 4,
                           num_fields=2)
    assert [c.label for c in ranked] == ["SOA", "AOSOA", "AOS"]
    assert all(ranked[i].predicted_bytes <= ranked[i + 1].predicted_bytes
               for i in range(len(ranked) - 1))
