"""Model-level attention: chunked/tri vs dense, GQA replication, sliding
windows, decode paths, ring caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (_chunk_pairs, attention,
                                    chunked_attention, decode_attention,
                                    dense_attention, repeat_kv)


def _qkv(rng, B, S, H, Hkv, D, scale=0.3):
    q = jnp.asarray(rng.standard_normal((B, S, H, D), dtype=np.float32)) * scale
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                        dtype=np.float32)) * scale
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                        dtype=np.float32)) * scale
    return q, k, v


@pytest.mark.parametrize("impl", ["chunked", "tri"])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("hkv", [8, 4, 2])
def test_chunked_matches_dense(rng, impl, window, hkv):
    B, S, H, D = 2, 192, 8, 16
    q, k, v = _qkv(rng, B, S, H, hkv, D)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = dense_attention(q, repeat_kv(k, H), repeat_kv(v, H),
                          qpos=pos, kpos=pos, causal=True, window=window)
    out = attention(q, k, v, qpos=pos, kpos=pos, causal=True, window=window,
                    impl=impl, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_tri_pair_savings():
    assert len(_chunk_pairs(8, 8, causal=True, window_chunks=None)) == 36
    assert len(_chunk_pairs(8, 8, causal=True, window_chunks=2)) == 15
    assert len(_chunk_pairs(4, 4, causal=False, window_chunks=None)) == 16


def test_bidirectional_chunked(rng):
    B, S, H, D = 1, 128, 4, 16
    q, k, v = _qkv(rng, B, S, H, H, D)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = dense_attention(q, k, v, qpos=pos, kpos=pos, causal=False)
    out = chunked_attention(q, k, v, qpos=pos, kpos=pos, causal=False,
                            q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_decode_matches_truncated_dense(rng):
    B, S, H, Hkv, D = 3, 96, 8, 4, 16
    kc = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                         dtype=np.float32)) * 0.3
    vc = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                         dtype=np.float32)) * 0.3
    qd = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32)) * 0.3
    lens = jnp.asarray([96, 50, 7], jnp.int32)
    out = decode_attention(qd, kc, vc, lens)
    for b in range(B):
        L = int(lens[b])
        r = dense_attention(qd[b:b + 1, None], kc[b:b + 1, :L],
                            vc[b:b + 1, :L], qpos=jnp.asarray([L - 1]),
                            kpos=jnp.arange(L), causal=False)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(r[0, 0]),
                                   rtol=2e-4, atol=2e-5)


def test_decode_window(rng):
    B, S, H, Hkv, D, W = 2, 64, 4, 2, 16, 16
    kc = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                         dtype=np.float32)) * 0.3
    vc = jnp.asarray(rng.standard_normal((B, S, Hkv, D),
                                         dtype=np.float32)) * 0.3
    qd = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32)) * 0.3
    lens = jnp.asarray([60, 33], jnp.int32)
    out = decode_attention(qd, kc, vc, lens, window=W)
    for b in range(B):
        L = int(lens[b])
        r = dense_attention(qd[b:b + 1, None], kc[b:b + 1, L - W:L],
                            vc[b:b + 1, L - W:L], qpos=jnp.asarray([0]),
                            kpos=jnp.arange(W), causal=False)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(r[0, 0]),
                                   rtol=2e-4, atol=2e-5)


def test_ring_cache_decode_equivalence(rng):
    """Local-attention ring cache: decoding with the W-slot ring must equal
    decoding with the full (untruncated) cache + window mask."""
    import repro.configs as C
    from repro.models.blocks import (ShardCtx, attention_decode,
                                     fill_attn_cache, init_layer,
                                     make_attn_cache)
    from repro.models.common import ParamTree

    cfg = C.get_smoke("gemma3_12b")  # window 16
    W = cfg.window
    pt = ParamTree(jax.random.PRNGKey(0))
    init_layer(pt, cfg, "L", 1, name="l")
    p = pt.params["l"]["attn"]
    B, S = 2, 40
    ctx = ShardCtx()
    # build ring cache from a prefill of S tokens, then decode 3 more
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model),
                                        dtype=np.float32)) * 0.3
    from repro.models.blocks import attention_forward
    _, (k, v) = attention_forward(p, x, cfg, ctx, causal=True,
                                  window=W, want_cache=True)
    ring = make_attn_cache(cfg, B, S + 8, W, jnp.float32)
    ring = fill_attn_cache(ring, k, v, cfg, W)
    full = make_attn_cache(cfg, B, S + 8, None, jnp.float32)
    full = fill_attn_cache(full, k, v, cfg, None)

    h_t = jnp.asarray(rng.standard_normal((B, cfg.d_model),
                                          dtype=np.float32)) * 0.3
    for t in range(3):
        pos = jnp.asarray(S + t)
        o_ring, ring = attention_decode(p, h_t, ring, pos, cfg, ctx,
                                        window=W)
        ref, full = _windowed_ref(p, h_t, full, pos, cfg, W)
        np.testing.assert_allclose(np.asarray(o_ring).astype(np.float32),
                                   ref, rtol=2e-3, atol=2e-3)


def _windowed_ref(p, h_t, full_cache, pos, cfg, W):
    """Windowed decode against the FULL cache (explicit window mask) —
    the oracle the W-slot ring buffer must reproduce."""
    from repro.models import kvcache as kvc
    from repro.models.common import rope_cos_sin, apply_rope, rms_norm
    cdt = h_t.dtype
    q = jnp.einsum("bd,dhk->bhk", h_t, p["wq"].astype(cdt))
    k_t = jnp.einsum("bd,dhk->bhk", h_t, p["wk"].astype(cdt))
    v_t = jnp.einsum("bd,dhk->bhk", h_t, p["wv"].astype(cdt))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k_t = rms_norm(k_t, p["k_norm"], eps=cfg.norm_eps)
    cos, sin = rope_cos_sin(pos[None].astype(jnp.int32),
                            int(cfg.head_dim * cfg.rope_fraction),
                            base=cfg.rope_base)
    q = apply_rope(q[:, None], cos[None], sin[None], mode=cfg.rope_mode)[:, 0]
    k_t = apply_rope(k_t[:, None], cos[None], sin[None],
                     mode=cfg.rope_mode)[:, 0]
    cache = kvc.kv_write_token(full_cache, k_t, v_t, pos.astype(jnp.int32),
                               cfg.kv_layout)
    k, v = kvc.kv_read(cache, cfg.head_dim, cfg.kv_layout)
    B = h_t.shape[0]
    lens = jnp.broadcast_to(pos + 1, (B,)).astype(jnp.int32)
    out = decode_attention(q, repeat_kv(k, q.shape[1]),
                           repeat_kv(v, q.shape[1]), lens, window=W)
    o = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(out.dtype))
    return np.asarray(o, dtype=np.float32), cache


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 96, 128]), qc=st.sampled_from([16, 32, 64]),
       kc=st.sampled_from([16, 32]), seed=st.integers(0, 1000))
def test_prop_chunk_size_invariance(s, qc, kc, seed):
    """Attention output must not depend on chunking."""
    rng = np.random.default_rng(seed)
    B, H, D = 1, 2, 8
    q = jnp.asarray(rng.standard_normal((B, s, H, D), dtype=np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((B, s, H, D), dtype=np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((B, s, H, D), dtype=np.float32)) * 0.3
    pos = jnp.arange(s, dtype=jnp.int32)
    a = chunked_attention(q, k, v, qpos=pos, kpos=pos, q_chunk=qc, k_chunk=kc)
    b = dense_attention(q, k, v, qpos=pos, kpos=pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=3e-5)
