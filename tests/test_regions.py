"""Region compiler (core/executor.py + core/schedule.py): segment-run
fusion into single cached executables, the plan-signature executable
cache, retrace-free run(), donation end-to-end, and host_loop
sub-executor caching."""

import warnings
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DistTensor, ExecutionKind, Executor, Graph, Layout,
                        RecordSpec, Region, SumReducer, group_regions,
                        make_reduction_result, plan_signature,
                        preferred_layout)

SPEC = RecordSpec.create("a", "b")


def _bump_a(r):
    return r.set_field("a", r.field("a") + 1.0)


def _accum_b(r):
    return r.set_field("b", r.field("b") + r.field("a"))


def _chain_graph():
    """Device-only chain (one segment, one region, fused fori in run)."""
    u = DistTensor("u", (8, 8))
    ws = DistTensor("ws", (8, 8))
    smax = make_reduction_result("smax")
    g = Graph()
    g.split(lambda a, b: a * 2.0, u, ws)
    g.then_reduce(ws, smax, SumReducer())
    g.then_split(lambda a, s: a + s, u, smax, writes=(0,))
    return g


def build_relayout_chain(n_pairs=2, n=256):
    """``device, loop, device, loop, ...`` with AoS<->SoA relayouts at
    every segment boundary — the relayout-heavy multi-segment shape the
    region compiler exists for.  Each loop is flag-gated to run exactly
    once per pass (the preceding device segment resets its flag)."""
    r = DistTensor("r", (n,), spec=SPEC, layout=Layout.AOS)
    g = Graph(name=f"chain{n_pairs}")
    for i in range(n_pairs):
        f = DistTensor(f"f{i}", (1,))
        g.then_split(_bump_a, r, writes=(0,), layout=Layout.AOS)
        g.split(lambda x: jnp.zeros_like(x), f, writes=(0,))
        loop = Graph(name=f"loop{i}")
        loop.split(_accum_b, r, writes=(0,), layout=Layout.SOA)
        loop.split(lambda x: jnp.ones_like(x), f, writes=(0,))
        loop.conditional((lambda nm: lambda s: s[nm][0] < 0.5)(f"f{i}"))
        g.then(loop)
    return g


# -- region grouping -----------------------------------------------------------

def test_group_regions_fuses_device_and_loop_runs():
    regions = group_regions(["device", "loop", "device", "loop"])
    assert [(r.kind, r.start, r.stop) for r in regions] == [
        ("device", 0, 4)]
    regions = group_regions(["device", "host", "device", "host_loop",
                             "loop"])
    assert [(r.kind, r.start, r.stop) for r in regions] == [
        ("device", 0, 1), ("host", 1, 2), ("device", 2, 3),
        ("host_loop", 3, 4), ("device", 4, 5)]
    assert all(isinstance(r, Region) for r in regions)


def test_executor_regions_match_segments():
    ex = Executor(build_relayout_chain(), donate=False)
    assert [k for k, _ in ex._segments] == ["device", "loop", "device",
                                            "loop"]
    assert [(r.kind, len(r)) for r in ex._regions] == [("device", 4)]
    assert ex.plan.regions == ex._regions


# -- retrace-free run() --------------------------------------------------------

def test_run_fused_shares_one_trace_across_steps():
    """Satellite regression: the fused fori path must not close over
    ``steps`` — distinct step counts share one trace (checked both by
    our trace-event counter and jax's own lowering-cache size)."""
    ex = Executor(_chain_graph())
    assert ex.dag.device_only
    ex.run(ex.init_state(u=jnp.ones((8, 8))), steps=3)
    base = ex.cache_stats()["trace_events"]
    for steps in (1, 5, 17):
        ex.run(ex.init_state(u=jnp.ones((8, 8))), steps=steps)
    assert ex.cache_stats()["trace_events"] == base
    (key,) = [k for k in ex._cache.executables if k[0] == "fused"]
    jit_fn = ex._cache.executables[key].jit_fn
    if hasattr(jit_fn, "_cache_size"):
        assert jit_fn._cache_size() == 1


def test_run_fused_values_match_stepwise_calls():
    g = _chain_graph()
    ex = Executor(g, donate=False)
    st_fused = ex.run(ex.init_state(u=jnp.ones((8, 8))), steps=3)
    ex2 = Executor(g, donate=False, regions=False)
    st = ex2.init_state(u=jnp.ones((8, 8)))
    for _ in range(3):
        st = ex2(st)
    for k in ("u", "ws", "smax"):
        np.testing.assert_array_equal(np.asarray(st_fused[k]),
                                      np.asarray(st[k]), err_msg=k)


def test_region_run_steady_state_is_retrace_and_dispatch_free():
    """The non-fused path: after warmup, further run() calls add zero
    traces, and the only eager relayout left is the trailing
    restore-to-initial (once per run(), not per step)."""
    ex = Executor(build_relayout_chain(), donate=False)
    ex.run(ex.init_state(), steps=2)      # warm: traces both entry variants
    warm = ex.cache_stats()
    assert warm["trace_events"] >= 1
    eager0 = ex.eager_relayouts
    ex.run(ex.init_state(), steps=10)
    after = ex.cache_stats()
    assert after["trace_events"] == warm["trace_events"]
    assert after["executables"] == warm["executables"]
    # 10 steps crossed 40 segment boundaries; only the final restore
    # (exit SoA -> initial AoS) ran eagerly
    assert ex.eager_relayouts - eager0 == 1


def test_region_equals_sequential_per_segment_dispatch():
    """Bitwise acceptance: region-compiled DAG schedule == sequential
    per-segment dispatch on the relayout-heavy chain."""
    outs = {}
    for tag, kw in (("region", dict(schedule="dag", regions=True)),
                    ("legacy", dict(schedule="sequential", regions=False))):
        ex = Executor(build_relayout_chain(), donate=False, **kw)
        outs[tag] = ex.run(ex.init_state(), steps=3)
    for k in sorted(outs["region"]):
        np.testing.assert_array_equal(np.asarray(outs["region"][k]),
                                      np.asarray(outs["legacy"][k]),
                                      err_msg=k)


# -- plan signature + executable cache -----------------------------------------

def test_plan_signature_stable_across_rebuilds():
    ex1 = Executor(build_relayout_chain(), donate=False)
    ex2 = Executor(build_relayout_chain(), donate=False)
    assert plan_signature(ex1) == plan_signature(ex2)
    assert ex1.plan.signature == ex2.plan.signature
    assert ex1._cache is ex2._cache


def test_plan_signature_discriminates():
    base = Executor(build_relayout_chain(), donate=False)
    assert plan_signature(Executor(build_relayout_chain(), donate=True)) \
        != plan_signature(base)
    assert plan_signature(Executor(build_relayout_chain(), donate=False,
                                   schedule="sequential")) \
        != plan_signature(base)
    assert plan_signature(Executor(build_relayout_chain(n=512),
                                   donate=False)) != plan_signature(base)


def test_plan_signature_keys_bound_method_receiver():
    """A bound method proxies __code__ from its function; the receiver's
    state must still key the signature (wrong cache hits are forbidden —
    a miss is merely conservative)."""
    class Scaler:
        def __init__(self, k):
            self.k = k

        def apply(self, x):
            return x * self.k

    def build(k):
        u = DistTensor("u", (8,))
        g = Graph()
        g.split(Scaler(k).apply, u, writes=(0,))
        return Executor(g, donate=False)

    assert plan_signature(build(2.0)) != plan_signature(build(3.0))


_GLOBAL_SCALE = 2.0


def _scaled_by_global(x):
    return x * _GLOBAL_SCALE


def test_plan_signature_keys_kwonly_defaults_and_globals():
    """Wrong-hit regressions: keyword-only default values and the values
    of module globals a node fn reads must key the signature."""
    def build_kw(k):
        def f(x, *, s=k):
            return x * s
        u = DistTensor("u", (8,))
        g = Graph()
        g.split(f, u, writes=(0,))
        return Executor(g, donate=False)

    assert plan_signature(build_kw(2.0)) != plan_signature(build_kw(3.0))

    def build_global():
        u = DistTensor("u", (8,))
        g = Graph()
        g.split(_scaled_by_global, u, writes=(0,))
        return Executor(g, donate=False)

    global _GLOBAL_SCALE
    s1 = plan_signature(build_global())
    _GLOBAL_SCALE = 3.0
    try:
        s2 = plan_signature(build_global())
    finally:
        _GLOBAL_SCALE = 2.0
    assert s1 != s2


def test_regions_false_run_escapes_the_cache_machinery():
    """The escape hatch must not route run() through the fused/cached
    path it exists to escape — device-only graphs dispatch per segment."""
    g = _chain_graph()
    ex = Executor(g, donate=False, regions=False)
    st = ex.run(ex.init_state(u=jnp.ones((8, 8))), steps=3)
    assert len(ex._jitted) > 0                       # per-segment jits
    assert not any(k[0] == "fused" for k in ex._fetched)
    ref = Executor(g, donate=False).run(
        Executor(g, donate=False).init_state(u=jnp.ones((8, 8))), steps=3)
    for k in ("u", "ws", "smax"):
        np.testing.assert_array_equal(np.asarray(st[k]),
                                      np.asarray(ref[k]), err_msg=k)


def test_second_executor_reuses_executables_without_tracing():
    """The serving pattern: a re-instantiated Executor over an identical
    graph reports plan-signature cache hits and adds zero traces."""
    ex1 = Executor(build_relayout_chain(3), donate=False)
    ex1.run(ex1.init_state(), steps=2)
    before = ex1.cache_stats()
    ex2 = Executor(build_relayout_chain(3), donate=False)
    st = ex2.run(ex2.init_state(), steps=2)
    after = ex2.cache_stats()
    assert after["trace_events"] == before["trace_events"]
    assert after["builds"] == before["builds"]
    assert after["hits"] >= 2          # both entry-layout variants reused
    rec = ex2.read(st, DistTensor("r", (256,), spec=SPEC))
    np.testing.assert_allclose(np.asarray(rec.field("a")), 6.0)


def test_describe_dag_shows_regions_and_cache():
    ex = Executor(build_relayout_chain(), donate=False)
    out = ex.describe_dag()
    assert "regions (fused executables):" in out
    assert "region 0 (device): seg0..seg3 (4 segments -> 1 executable)" \
        in out
    assert f"plan signature {ex.plan.signature}" in out
    assert "executable cache:" in out


# -- donation end-to-end -------------------------------------------------------

def _ptr(arr):
    try:
        return arr.unsafe_buffer_pointer()
    except Exception:  # pragma: no cover - platform without raw pointers
        pytest.skip("unsafe_buffer_pointer unsupported on this backend")


def test_donation_reuses_state_buffers_across_region_calls():
    u = DistTensor("u", (128, 128))
    g = Graph()
    g.split(lambda x: x + 1.0, u, writes=(0,))
    ex = Executor(g, donate=True)
    st = ex.init_state()
    st1 = ex(st)
    assert st["u"].is_deleted()            # donated into the region call
    p1 = _ptr(st1["u"])
    st2 = ex(st1)
    assert st1["u"].is_deleted()
    assert _ptr(st2["u"]) == p1            # buffer recycled call-to-call


def test_donate_false_keeps_inputs_and_copies():
    u = DistTensor("u", (128, 128))
    g = Graph()
    g.split(lambda x: x + 1.0, u, writes=(0,))
    ex = Executor(g, donate=False)
    st = ex.init_state()
    p0 = _ptr(st["u"])
    st1 = ex(st)
    assert not st["u"].is_deleted()        # input still readable
    assert _ptr(st1["u"]) != p0            # output is a fresh buffer
    np.testing.assert_array_equal(np.asarray(st["u"]), 0.0)
    np.testing.assert_array_equal(np.asarray(st1["u"]), 1.0)


@contextmanager
def warnings_errored_on_donation():
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message=".*[Dd]onat.*")
        yield


def test_donation_skips_layout_unstable_buffers():
    """A tensor whose layout differs between region entry and exit cannot
    be aliased; the executor must not donate it (jax would warn about an
    unusable donation) but still donates the stable entries."""
    ex = Executor(build_relayout_chain(), donate=True)
    region = ex._regions[0]
    with ex._layout_epoch():
        fn, _ = ex._region_executable(region)
    assert "r" not in fn.donate_keys       # AoS at entry, SoA at exit
    assert "f0" in fn.donate_keys and "f1" in fn.donate_keys
    with warnings_errored_on_donation():
        st = ex.run(ex.init_state(), steps=3)
    rec = ex.read(st, DistTensor("r", (256,), spec=SPEC))
    np.testing.assert_allclose(np.asarray(rec.field("a")), 6.0)


# -- host_loop sub-executor caching --------------------------------------------

def test_host_loop_sub_executor_built_once():
    """Satellite regression: the host_loop sub-Executor used to be
    re-constructed (and re-jitted) on every pass."""
    x = DistTensor("x", (8,))
    seen = []
    loop = Graph(name="dec")
    loop.split(lambda v: v - 1.0, x, writes=(0,))
    loop.then(lambda v: seen.append(float(v[0])),
              exec_kind=ExecutionKind.Cpu, args=(x,))
    loop.conditional(lambda s: s["x"][0] > 0.0)
    g = Graph()
    g.split(lambda v: jnp.full_like(v, 3.0), x, writes=(0,))
    g.then(loop)
    ex = Executor(g, donate=False)
    kinds = [k for k, _ in ex._segments]
    assert "host_loop" in kinds
    st = ex.run(ex.init_state(), steps=2)
    assert len(ex._sub_execs) == 1
    sub = next(iter(ex._sub_execs.values()))
    ex.run(st, steps=1)
    assert next(iter(ex._sub_execs.values())) is sub
    assert seen == [2.0, 1.0, 0.0] * 3
    np.testing.assert_array_equal(np.asarray(st["x"]), np.zeros(8))


# -- layout-hint interplay -----------------------------------------------------

def test_region_with_record_hints_restores_initial_layout():
    """A region whose exit layout differs from the initial one restores
    eagerly on exit — state dicts stay interchangeable outside calls."""
    t = DistTensor("p", (256,), spec=SPEC, layout=Layout.SOA)
    g = Graph()
    g.split(_bump_a, preferred_layout(t, Layout.AOS), writes=(0,))
    g.sync()
    g.split(_bump_a, preferred_layout(t, Layout.AOSOA), writes=(0,))
    ex = Executor(g, donate=False)
    assert [r.kind for r in ex._regions] == ["device", "host", "device"]
    st = ex(ex.init_state())
    assert st["p"].shape == (256, 2)       # restored to initial (AoS)
    np.testing.assert_allclose(np.asarray(ex.read(st, t).field("a")), 2.0)
