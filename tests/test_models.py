"""Per-arch smoke tests (reduced same-family configs): one forward/train
step on CPU asserting output shapes + no NaNs, plus prefill+decode ==
full-sequence logits for every architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.steps import make_train_step
from repro.models.blocks import ShardCtx
from repro.models.lm import (decode_step, forward_loss, init_lm, param_count,
                             prefill)

CTX = ShardCtx()
B, S = 2, 32


def _batch(cfg, rng, with_labels=True):
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    if with_labels:
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(rng.standard_normal(
            (B, 16, cfg.frontend_dim)).astype(np.float32) * 0.1)
    elif cfg.frontend_dim:
        out["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
            * 0.1)
    return out


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_forward(arch, rng):
    cfg = C.get_smoke(arch)
    params, specs = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    assert param_count(cfg) > 0
    loss, metrics = forward_loss(params, _batch(cfg, rng), cfg, CTX)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert np.isfinite(float(metrics["aux"]))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = C.get_smoke(arch)
    step_fn, opt = make_train_step(cfg, None)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = _batch(cfg, rng)
    jstep = jax.jit(step_fn)
    losses = []
    for _ in range(4):
        state, m = jstep(state, batch)
        assert np.isfinite(float(m["loss"]))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_prefill_decode_matches_full(arch, rng):
    cfg = C.get_smoke(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    batch = _batch(cfg, rng, with_labels=False)
    tokens = batch["tokens"]
    kw = {"enc_len": 16} if cfg.is_encdec else {}
    extra = cfg.frontend_tokens if (cfg.frontend_dim
                                    and not cfg.is_encdec) else 0
    logits_full, _ = prefill(params, batch, cfg, CTX)
    bp = dict(batch)
    bp["tokens"] = tokens[:, : S - 2]
    _, caches = prefill(params, bp, cfg, CTX, max_seq=S + extra + 4)
    lg, caches = decode_step(params, caches, tokens[:, S - 2], cfg, CTX, **kw)
    lg, caches = decode_step(params, caches, tokens[:, S - 1], cfg, CTX, **kw)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, : cfg.vocab_size]),
        np.asarray(lg[:, : cfg.vocab_size]), rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("arch", ["qwen3_8b", "mamba2_130m"])
def test_padded_vocab_masked(arch, rng):
    """Logits beyond the true vocab must be -inf when padded."""
    cfg = C.get_smoke(arch).with_(vocab_size=250)  # pad to 256 under tp 8
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=8)
    batch = _batch(cfg, rng, with_labels=False)
    logits, _ = prefill(params, batch, cfg, CTX)
    assert logits.shape[-1] == 256
    assert np.all(np.asarray(logits[:, 250:]) < -1e29)


def test_full_configs_instantiable():
    """The exact assigned configs must build (metadata only, no params)."""
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        n, pattern, tail = cfg.layer_groups()
        assert n * len(pattern) + len(tail) == cfg.n_layers, arch
        assert cfg.padded_heads(16) % 16 == 0, arch
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, arch
        assert cfg.padded_vocab(16) % 16 == 0, arch


def test_param_counts_match_assignment():
    """Full configs land near their nameplate sizes (no TP padding)."""
    expect = {
        "llava_next_mistral_7b": (6.5e9, 8.0e9),
        "qwen1_5_4b": (3.0e9, 4.5e9),
        "chatglm3_6b": (5.5e9, 7.0e9),
        "qwen3_8b": (7.0e9, 9.0e9),
        "gemma3_12b": (10e9, 13.5e9),
        "mamba2_130m": (0.10e9, 0.16e9),
        "arctic_480b": (430e9, 520e9),
        "phi3_5_moe": (38e9, 46e9),
        "recurrentgemma_9b": (8e9, 11e9),
        "seamless_m4t_medium": (0.55e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(C.get(arch), tp=1)
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:.2e},{hi:.2e}]"


@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_kv_order_equivalence(order, rng):
    """C1 space-order knob: both cache orders decode identically."""
    cfg = C.get_smoke("gemma3_12b").with_(kv_order=order)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))
                       .astype(np.int32))
    _, caches = prefill(params, {"tokens": toks[:, : S - 1]}, cfg, CTX,
                        max_seq=S + 4)
    lg, _ = decode_step(params, caches, toks[:, S - 1], cfg, CTX)
    full, _ = prefill(params, {"tokens": toks}, cfg, CTX)
    np.testing.assert_allclose(np.asarray(lg[:, : cfg.vocab_size]),
                               np.asarray(full[:, : cfg.vocab_size]),
                               rtol=3e-3, atol=3e-3)
