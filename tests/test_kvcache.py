"""Polymorphic KV cache: layout x order matrix, write/read roundtrips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layout import Layout
from repro.models import kvcache as kvc

B, S, H, D = 2, 8, 3, 4


LAYOUTS = [Layout.AOS, Layout.SOA, Layout.AOSOA]


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_prefill_roundtrip(rng, layout, order):
    k = jnp.asarray(rng.standard_normal((B, S, H, D), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D), dtype=np.float32))
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    store = kvc.kv_write_prefill(store, k, v, layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), rtol=1e-6)


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_token_write(rng, layout, order):
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    k_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    v_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    store = kvc.kv_write_token(store, k_t, v_t, jnp.int32(5), layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    np.testing.assert_allclose(np.asarray(k2[:, 5]), np.asarray(k_t),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2[:, 5]), np.asarray(v_t),
                               rtol=1e-6)
    assert float(jnp.abs(k2[:, :5]).max()) == 0.0
    assert float(jnp.abs(k2[:, 6:]).max()) == 0.0


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_pspec_rank_matches_storage(layout, order):
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    ps = kvc.kv_pspec(layout, batch_axes=("data",), seq_axes=("model",),
                      order=order)
    assert len(ps) == store.ndim
    # the sequence axis must land on the actual S dim
    seq_dim = [i for i, e in enumerate(ps)
               if e == ("model",) or e == "model"]
    assert len(seq_dim) == 1
    if layout is Layout.AOSOA and order == "bhs":
        # the tiled sequence axis shards on its tile-MAJOR extent
        assert store.shape[seq_dim[0]] * store.shape[-1] == S
    else:
        assert store.shape[seq_dim[0]] == S


def test_registry_aliases():
    import repro.configs as C
    assert C.get("qwen3-8b").name == "qwen3_8b"
    assert C.get("phi3.5-moe-42b-a6.6b").name == "phi3_5_moe"
    with pytest.raises(KeyError):
        C.get("not-a-model")
    for a in C.ARCH_IDS:
        assert C.get(a).name == a


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_vector_pos_token_write(rng, layout, order):
    """Continuous batching: every batch slot writes at its OWN depth."""
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    k_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    v_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    pos = jnp.asarray([2, 6], jnp.int32)          # per-slot positions
    store = kvc.kv_write_token(store, k_t, v_t, pos, layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    k2, v2 = np.asarray(k2), np.asarray(v2)
    for b in range(B):
        p = int(pos[b])
        np.testing.assert_allclose(k2[b, p], np.asarray(k_t[b]), rtol=1e-6)
        np.testing.assert_allclose(v2[b, p], np.asarray(v_t[b]), rtol=1e-6)
        mask = np.ones(S, bool)
        mask[p] = False
        assert np.abs(k2[b, mask]).max() == 0.0
        assert np.abs(v2[b, mask]).max() == 0.0


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_prefill_then_decode_roundtrip(rng, layout, order):
    """Prefill S0 positions, then append tokens one by one (scalar pos) —
    the assembled cache must equal the dense reference regardless of the
    storage layout the solver picked."""
    S0 = 3
    k = rng.standard_normal((B, S, H, D)).astype(np.float32)
    v = rng.standard_normal((B, S, H, D)).astype(np.float32)
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    store = kvc.kv_write_prefill(store, jnp.asarray(k[:, :S0]),
                                 jnp.asarray(v[:, :S0]), layout, order)
    for t in range(S0, S):
        store = kvc.kv_write_token(store, jnp.asarray(k[:, t]),
                                   jnp.asarray(v[:, t]), jnp.int32(t),
                                   layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    np.testing.assert_allclose(np.asarray(k2), k, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), v, rtol=1e-6)


@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_layout_value_equivalence(rng, order):
    """The same write sequence through every layout yields identical
    logical values — layout is pure storage polymorphism."""
    k0 = rng.standard_normal((B, 4, H, D)).astype(np.float32)
    v0 = rng.standard_normal((B, 4, H, D)).astype(np.float32)
    kt = rng.standard_normal((B, H, D)).astype(np.float32)
    vt = rng.standard_normal((B, H, D)).astype(np.float32)
    got = {}
    for layout in LAYOUTS:
        store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
        store = kvc.kv_write_prefill(store, jnp.asarray(k0),
                                     jnp.asarray(v0), layout, order)
        store = kvc.kv_write_token(store, jnp.asarray(kt), jnp.asarray(vt),
                                   jnp.asarray([4, 5], jnp.int32),
                                   layout, order)
        k2, v2 = kvc.kv_read(store, D, layout, order)
        got[layout] = (np.asarray(k2), np.asarray(v2))
    for layout in LAYOUTS[1:]:
        np.testing.assert_allclose(got[layout][0], got[LAYOUTS[0]][0],
                                   rtol=1e-6, err_msg=str(layout))
        np.testing.assert_allclose(got[layout][1], got[LAYOUTS[0]][1],
                                   rtol=1e-6, err_msg=str(layout))
