"""Polymorphic KV cache: layout x order matrix, write/read roundtrips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layout import Layout
from repro.models import kvcache as kvc

B, S, H, D = 2, 8, 3, 4


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_prefill_roundtrip(rng, layout, order):
    k = jnp.asarray(rng.standard_normal((B, S, H, D), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D), dtype=np.float32))
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    store = kvc.kv_write_prefill(store, k, v, layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), rtol=1e-6)


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_token_write(rng, layout, order):
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    k_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    v_t = jnp.asarray(rng.standard_normal((B, H, D), dtype=np.float32))
    store = kvc.kv_write_token(store, k_t, v_t, jnp.int32(5), layout, order)
    k2, v2 = kvc.kv_read(store, D, layout, order)
    if order == "bhs":
        k2, v2 = jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2)
    np.testing.assert_allclose(np.asarray(k2[:, 5]), np.asarray(k_t),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2[:, 5]), np.asarray(v_t),
                               rtol=1e-6)
    assert float(jnp.abs(k2[:, :5]).max()) == 0.0
    assert float(jnp.abs(k2[:, 6:]).max()) == 0.0


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
@pytest.mark.parametrize("order", ["bsh", "bhs"])
def test_pspec_rank_matches_storage(layout, order):
    store = kvc.kv_make(B, S, H, D, jnp.float32, layout, order)
    ps = kvc.kv_pspec(layout, batch_axes=("data",), seq_axes=("model",),
                      order=order)
    assert len(ps) == store.ndim
    # the sequence axis must land on the actual S dim
    seq_dim = [i for i, e in enumerate(ps)
               if e == ("model",) or e == "model"]
    assert len(seq_dim) == 1
    assert store.shape[seq_dim[0]] == S


def test_registry_aliases():
    import repro.configs as C
    assert C.get("qwen3-8b").name == "qwen3_8b"
    assert C.get("phi3.5-moe-42b-a6.6b").name == "phi3_5_moe"
    with pytest.raises(KeyError):
        C.get("not-a-model")
    for a in C.ARCH_IDS:
        assert C.get(a).name == a


def test_aosoa_rejected():
    """kvcache accessors dynamic-slice the sequence axis, which AOSOA
    tiles — constructing such a cache must fail loudly, not later."""
    with pytest.raises(ValueError, match="AOS/SOA only"):
        kvc.kv_make(B, S, H, D, layout=Layout.AOSOA)
