"""C1: polymorphic data layout — unit + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Field, Layout, RecordArray, RecordSpec, Vector

SPEC = RecordSpec.create("rho", "E", Vector("mom", 2))


def _random_fields(rng, space):
    return {"rho": jnp.asarray(rng.standard_normal(space, dtype=np.float32)),
            "E": jnp.asarray(rng.standard_normal(space, dtype=np.float32)),
            "mom": jnp.asarray(
                rng.standard_normal((*space, 2), dtype=np.float32))}


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
def test_storage_shapes(layout):
    space = (6, 5)
    shape = RecordArray.storage_shape(SPEC, space, layout)
    assert shape == ((6, 5, 4) if layout is Layout.AOS else (4, 6, 5))


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
def test_field_roundtrip(rng, layout):
    space = (4, 3)
    fields = _random_fields(rng, space)
    rec = RecordArray.from_fields(SPEC, fields, layout)
    assert rec.space == space
    for name, v in fields.items():
        np.testing.assert_array_equal(np.asarray(rec.field(name)),
                                      np.asarray(v))


def test_layout_interop_zero_cost_semantics(rng):
    """with_layout must be a pure re-layout: every field identical."""
    fields = _random_fields(rng, (7, 2))
    a = RecordArray.from_fields(SPEC, fields, Layout.AOS)
    s = a.with_layout(Layout.SOA)
    back = s.with_layout(Layout.AOS)
    np.testing.assert_array_equal(np.asarray(a.data), np.asarray(back.data))
    for name in SPEC.names:
        np.testing.assert_array_equal(np.asarray(a.field(name)),
                                      np.asarray(s.field(name)))


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA])
def test_set_field(rng, layout):
    rec = RecordArray.create(SPEC, (5, 4), layout)
    v = jnp.asarray(rng.standard_normal((5, 4), dtype=np.float32))
    rec2 = rec.set_field("E", v)
    np.testing.assert_array_equal(np.asarray(rec2.field("E")), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(rec2.field("rho")),
                                  np.zeros((5, 4), np.float32))


def test_pytree_and_jit(rng):
    rec = RecordArray.from_fields(SPEC, _random_fields(rng, (4, 4)),
                                  Layout.SOA)

    @jax.jit
    def f(r: RecordArray) -> RecordArray:
        return r.set_field("rho", r.field("rho") * 2.0)

    out = f(rec)
    assert isinstance(out, RecordArray)
    np.testing.assert_allclose(np.asarray(out.field("rho")),
                               2 * np.asarray(rec.field("rho")))


def test_spec_validation():
    with pytest.raises(ValueError):
        RecordSpec.create("a", "a")
    with pytest.raises(ValueError):
        Field("x", 0)
    with pytest.raises(KeyError):
        SPEC.offset("nope")


# -- hypothesis properties ---------------------------------------------------

field_names = st.lists(
    st.text(alphabet="abcdefgh", min_size=1, max_size=4),
    min_size=1, max_size=4, unique=True)


@settings(max_examples=25, deadline=None)
@given(names=field_names,
       sizes=st.lists(st.integers(1, 3), min_size=4, max_size=4),
       nx=st.integers(1, 6), ny=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_prop_layout_conversion_preserves_fields(names, sizes, nx, ny, seed):
    spec = RecordSpec.create(*[(n, s) for n, s in zip(names, sizes)])
    rng = np.random.default_rng(seed)
    # documented convention: size-1 fields pass (*space), vectors (*space, k)
    fields = {f.name: jnp.asarray(
        rng.standard_normal((nx, ny, f.size) if f.size > 1 else (nx, ny),
                            dtype=np.float32))
        for f in spec.fields}
    for lay in (Layout.AOS, Layout.SOA):
        rec = RecordArray.from_fields(spec, fields, lay)
        other = rec.with_layout(
            Layout.SOA if lay is Layout.AOS else Layout.AOS)
        for f in spec.fields:
            a = np.asarray(rec.field(f.name))
            b = np.asarray(other.field(f.name))
            expect = np.asarray(fields[f.name])
            np.testing.assert_array_equal(a, expect)
            np.testing.assert_array_equal(b, expect)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
       layout=st.sampled_from([Layout.AOS, Layout.SOA]))
def test_prop_set_then_get(n, seed, layout):
    rng = np.random.default_rng(seed)
    rec = RecordArray.create(SPEC, (n, n), layout)
    v = jnp.asarray(rng.standard_normal((n, n, 2), dtype=np.float32))
    rec = rec.set_field("mom", v)
    np.testing.assert_array_equal(np.asarray(rec.field("mom")),
                                  np.asarray(v))
