"""C1: polymorphic data layout — unit + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Field, Layout, RecordArray, RecordSpec, Vector,
                        aosoa_tile, block_spec_for, relayout)

SPEC = RecordSpec.create("rho", "E", Vector("mom", 2))
ALL_LAYOUTS = [Layout.AOS, Layout.SOA, Layout.AOSOA]


def _random_fields(rng, space):
    return {"rho": jnp.asarray(rng.standard_normal(space, dtype=np.float32)),
            "E": jnp.asarray(rng.standard_normal(space, dtype=np.float32)),
            "mom": jnp.asarray(
                rng.standard_normal((*space, 2), dtype=np.float32))}


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_storage_shapes(layout):
    space = (6, 5)
    shape = RecordArray.storage_shape(SPEC, space, layout)
    expect = {Layout.AOS: (6, 5, 4), Layout.SOA: (4, 6, 5),
              # tile = gcd(5, 128) = 1 -> (6, 5 tiles, 4 comps, 1 lane)
              Layout.AOSOA: (6, 5, 4, 1)}[layout]
    assert shape == expect


def test_aosoa_tile_is_lane_aligned_and_exact():
    assert aosoa_tile(1024) == 128
    assert aosoa_tile(192) == 64
    assert aosoa_tile(7) == 1    # degenerate but exact
    shape = RecordArray.storage_shape(SPEC, (2, 256), Layout.AOSOA)
    assert shape == (2, 2, 4, 128)


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_field_roundtrip(rng, layout):
    space = (4, 3)
    fields = _random_fields(rng, space)
    rec = RecordArray.from_fields(SPEC, fields, layout)
    assert rec.space == space
    for name, v in fields.items():
        np.testing.assert_array_equal(np.asarray(rec.field(name)),
                                      np.asarray(v))


def test_layout_interop_zero_cost_semantics(rng):
    """with_layout must be a pure re-layout: every field identical."""
    fields = _random_fields(rng, (7, 2))
    a = RecordArray.from_fields(SPEC, fields, Layout.AOS)
    s = a.with_layout(Layout.SOA)
    back = s.with_layout(Layout.AOS)
    np.testing.assert_array_equal(np.asarray(a.data), np.asarray(back.data))
    for name in SPEC.names:
        np.testing.assert_array_equal(np.asarray(a.field(name)),
                                      np.asarray(s.field(name)))


@pytest.mark.parametrize("src", ALL_LAYOUTS)
@pytest.mark.parametrize("dst", ALL_LAYOUTS)
def test_relayout_all_pairs_roundtrip(rng, src, dst):
    """relayout is value-exact for every ordered layout pair, and the
    round trip restores the original storage bit-for-bit."""
    fields = _random_fields(rng, (3, 8))
    a = RecordArray.from_fields(SPEC, fields, src)
    b = relayout(a, dst)
    assert b.layout is dst and b.space == a.space
    for name in SPEC.names:
        np.testing.assert_array_equal(np.asarray(b.field(name)),
                                      np.asarray(a.field(name)))
    back = relayout(b, src)
    np.testing.assert_array_equal(np.asarray(back.data), np.asarray(a.data))


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_set_field(rng, layout):
    rec = RecordArray.create(SPEC, (5, 4), layout)
    v = jnp.asarray(rng.standard_normal((5, 4), dtype=np.float32))
    rec2 = rec.set_field("E", v)
    np.testing.assert_array_equal(np.asarray(rec2.field("E")), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(rec2.field("rho")),
                                  np.zeros((5, 4), np.float32))


def test_pytree_and_jit(rng):
    rec = RecordArray.from_fields(SPEC, _random_fields(rng, (4, 4)),
                                  Layout.SOA)

    @jax.jit
    def f(r: RecordArray) -> RecordArray:
        return r.set_field("rho", r.field("rho") * 2.0)

    out = f(rec)
    assert isinstance(out, RecordArray)
    np.testing.assert_allclose(np.asarray(out.field("rho")),
                               2 * np.asarray(rec.field("rho")))


def test_spec_validation():
    with pytest.raises(ValueError):
        RecordSpec.create("a", "a")
    with pytest.raises(ValueError):
        Field("x", 0)
    with pytest.raises(KeyError):
        SPEC.offset("nope")


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_block_spec_for_drives_a_kernel(rng, layout):
    """Pin the block_spec_for contract for every layout with a real
    pallas_call: a whole-record copy through the generated BlockSpec.
    For AOSOA the last space_block entry is the storage tile extent and
    the index map's last output addresses tile-count units."""
    from jax.experimental import pallas as pl

    n = 256
    rec = RecordArray.from_fields(
        SPEC,
        {"rho": jnp.asarray(rng.standard_normal(n, dtype=np.float32)),
         "E": jnp.asarray(rng.standard_normal(n, dtype=np.float32)),
         "mom": jnp.asarray(rng.standard_normal((n, 2), dtype=np.float32))},
        layout)
    if layout is Layout.AOSOA:
        tile = aosoa_tile(n)
        grid = (n // tile,)
        bspec = block_spec_for(SPEC, layout, (tile,), lambda i: (i,))
    else:
        block = 64
        grid = (n // block,)
        bspec = block_spec_for(SPEC, layout, (block,), lambda i: (i,))

    out = pl.pallas_call(
        lambda i_ref, o_ref: o_ref.__setitem__(..., i_ref[...]),
        out_shape=jax.ShapeDtypeStruct(rec.data.shape, rec.dtype),
        grid=grid, in_specs=[bspec], out_specs=bspec, interpret=True,
    )(rec.data)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rec.data))


# -- hypothesis properties ---------------------------------------------------

field_names = st.lists(
    st.text(alphabet="abcdefgh", min_size=1, max_size=4),
    min_size=1, max_size=4, unique=True)


@settings(max_examples=25, deadline=None)
@given(names=field_names,
       sizes=st.lists(st.integers(1, 3), min_size=4, max_size=4),
       nx=st.integers(1, 6), ny=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_prop_layout_conversion_preserves_fields(names, sizes, nx, ny, seed):
    spec = RecordSpec.create(*[(n, s) for n, s in zip(names, sizes)])
    rng = np.random.default_rng(seed)
    # documented convention: size-1 fields pass (*space), vectors (*space, k)
    fields = {f.name: jnp.asarray(
        rng.standard_normal((nx, ny, f.size) if f.size > 1 else (nx, ny),
                            dtype=np.float32))
        for f in spec.fields}
    for lay in (Layout.AOS, Layout.SOA, Layout.AOSOA):
        rec = RecordArray.from_fields(spec, fields, lay)
        for other_lay in (Layout.AOS, Layout.SOA, Layout.AOSOA):
            other = rec.with_layout(other_lay)
            for f in spec.fields:
                a = np.asarray(rec.field(f.name))
                b = np.asarray(other.field(f.name))
                expect = np.asarray(fields[f.name])
                np.testing.assert_array_equal(a, expect)
                np.testing.assert_array_equal(b, expect)


@settings(max_examples=25, deadline=None)
@given(names=field_names,
       sizes=st.lists(st.integers(1, 3), min_size=4, max_size=4),
       nx=st.integers(1, 4), ny=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1))
def test_prop_relayout_roundtrip_arbitrary_specs(names, sizes, nx, ny, seed):
    """AoS <-> SoA <-> AoSoA chain preserves every field for arbitrary
    specs and shapes (the tiled dim hits aligned and degenerate tiles)."""
    spec = RecordSpec.create(*[(n, s) for n, s in zip(names, sizes)])
    rng = np.random.default_rng(seed)
    fields = {f.name: jnp.asarray(
        rng.standard_normal((nx, ny, f.size) if f.size > 1 else (nx, ny),
                            dtype=np.float32))
        for f in spec.fields}
    rec = RecordArray.from_fields(spec, fields, Layout.AOS)
    chain = relayout(relayout(relayout(rec, Layout.AOSOA), Layout.SOA),
                     Layout.AOS)
    np.testing.assert_array_equal(np.asarray(chain.data),
                                  np.asarray(rec.data))
    for f in spec.fields:
        np.testing.assert_array_equal(
            np.asarray(relayout(rec, Layout.AOSOA).field(f.name)),
            np.asarray(fields[f.name]))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
       layout=st.sampled_from([Layout.AOS, Layout.SOA, Layout.AOSOA]))
def test_prop_set_then_get(n, seed, layout):
    rng = np.random.default_rng(seed)
    rec = RecordArray.create(SPEC, (n, n), layout)
    v = jnp.asarray(rng.standard_normal((n, n, 2), dtype=np.float32))
    rec = rec.set_field("mom", v)
    np.testing.assert_array_equal(np.asarray(rec.field("mom")),
                                  np.asarray(v))
