"""Static validation of all 40 assigned (arch x shape) cells x 2 meshes:
divisibility of every sharded dim, input/state spec construction, and
rules resolution — no compilation (the compile pass is the dry-run)."""

import math

import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.models.config import SHAPES, shapes_for

MESHES = {
    "16x16": {"data": 16, "model": 16},
    "2x16x16": {"pod": 2, "data": 16, "model": 16},
}


class FakeMesh:
    """Shape-only mesh stand-in (enough for rules/spec math)."""

    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def all_cells():
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        for shape in shapes_for(cfg):
            yield arch, shape.name


def test_cell_count_is_40():
    cells = list(all_cells())
    # 10 archs x 3 shapes + long_500k for the 3 sub-quadratic archs
    assert len(cells) == 33
    # the remaining 7 long_500k cells are skipped by design (full attention)
    skipped = [(a, "long_500k") for a in C.ARCH_IDS
               if not C.get(a).supports_long_context]
    assert len(cells) + len(skipped) == 40


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch,shape_name", list(all_cells()))
def test_cell_divisibility(arch, shape_name, mesh_name):
    from repro.launch.steps import make_rules
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    mesh = FakeMesh(MESHES[mesh_name])
    tp = mesh.shape["model"]
    dp = math.prod(v for k, v in mesh.shape.items() if k != "model")

    # batch divisibility (except the intentionally unsharded B=1 decode)
    if shape.global_batch > 1:
        assert shape.global_batch % dp == 0, "batch must shard over DP"
    # TP dims
    assert cfg.padded_heads(tp) % tp == 0
    assert cfg.padded_vocab(tp) % tp == 0
    if cfg.d_ff:
        assert cfg.d_ff % tp == 0
    if cfg.ssm_state:
        assert cfg.padded_ssm_heads(tp) % tp == 0
    if cfg.lru_width:
        assert cfg.lru_width % tp == 0
    # decode cache sequence sharding
    if shape.is_decode:
        n = tp if shape.global_batch > 1 else tp * dp
        assert shape.seq_len % n == 0
    # rules resolve without error
    rules = make_rules(cfg, FakeMesh(MESHES[mesh_name]))
    if cfg.n_experts:
        e_ax = rules["experts"]
        if e_ax is not None:
            assert cfg.n_experts % mesh.shape[e_ax] == 0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_config_same_family(arch):
    """Reduced config preserves the family / layer pattern / feature flags
    of the full config (the brief's 'same family' requirement)."""
    full, smoke = C.get(arch), C.get_smoke(arch)
    assert full.family == smoke.family
    assert full.pattern == smoke.pattern
    assert full.is_encdec == smoke.is_encdec
    assert (full.n_experts > 0) == (smoke.n_experts > 0)
    assert full.qk_norm == smoke.qk_norm
    assert full.qkv_bias == smoke.qkv_bias
    assert full.rope_mode == smoke.rope_mode
    assert (full.lru_width > 0) == (smoke.lru_width > 0)
    assert (full.ssm_state > 0) == (smoke.ssm_state > 0)
