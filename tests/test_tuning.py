"""Measured autotuner (repro/tuning + core/executor tune modes):
candidate enumeration, measurement-driven decisions, the persistent
cache (hit = zero timed measurements, corrupt file = heuristics + one
warning, cross-process load), and value equality of tuned plans."""

import json
import os
import subprocess
import sys
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DistTensor, Executor, Graph, Layout, RecordSpec,
                        layout_candidates, storage_candidates)
from repro.tuning import cache as tune_cache
from repro.tuning import search as tune_search
from repro.tuning import tiles as tune_tiles

SPEC = RecordSpec.create("a", "b")


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own on-disk cache dir and fresh counters."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune-cache"))
    tune_cache.clear_memo()
    tune_search.reset_stats()
    yield
    tune_cache.clear_memo()


def _mix(r):
    return r.set_field("a", r.field("a") * 1.5 + r.field("b"))


def _record_graph(n=2048, name="p"):
    p = DistTensor(name, (4, n), spec=SPEC, layout=Layout.AOS)
    g = Graph(name=f"tune_{name}")
    g.split(_mix, p, writes=(0,))
    return g, p


def _kernel_graph(n=4096):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    r = DistTensor("r", (n,), spec=SAXPY_SPEC, layout=Layout.AOS)
    g = Graph(name="tune_saxpy")
    g.split(lambda rec: saxpy_record(rec, 2.0), r, writes=(0,))
    return g, r


# -- candidate enumeration -----------------------------------------------------

def test_storage_candidates_halo_partition_clamp():
    assert storage_candidates((4, 256)) == (
        Layout.AOS, Layout.SOA, Layout.AOSOA)
    assert storage_candidates((4, 256), halo=(1, 0)) == (
        Layout.AOS, Layout.SOA, Layout.AOSOA)
    assert storage_candidates((4, 256), halo=(0, 1)) == (
        Layout.AOS, Layout.SOA)
    assert storage_candidates((4, 256), partition=(None, "x")) == (
        Layout.AOS, Layout.SOA)


def test_layout_candidates_respect_pins_and_halo():
    g, _ = _record_graph()
    assert layout_candidates(Executor(g)) == {
        "p": (Layout.AOS, Layout.SOA, Layout.AOSOA)}

    pinned = DistTensor("q", (4, 256), spec=SPEC, layout=Layout.AOS,
                        pin_layout=True)
    g2 = Graph()
    g2.split(_mix, pinned, writes=(0,))
    assert layout_candidates(Executor(g2)) == {}

    haloed = DistTensor("h", (64,), spec=SPEC, layout=Layout.SOA,
                        halo=(1,))
    g3 = Graph()
    g3.split(lambda r: r, haloed, writes=(0,))
    assert layout_candidates(Executor(g3)) == {
        "h": (Layout.AOS, Layout.SOA)}


def test_tile_registry_has_every_kernel():
    import repro.kernels.attention.kernel   # noqa: F401
    import repro.kernels.eikonal.kernel     # noqa: F401
    import repro.kernels.particle.kernel    # noqa: F401
    import repro.kernels.saxpy.kernel       # noqa: F401
    import repro.kernels.ssd.kernel         # noqa: F401
    import repro.kernels.stencil.kernel     # noqa: F401

    names = set(tune_tiles.registered_tile_kernels())
    assert {"saxpy", "particle", "flux", "eikonal", "attention",
            "ssd"} <= names
    assert tune_tiles.tile_candidates("saxpy", (4096,)) == (
        256, 512, 1024, 2048, 4096)
    assert (8, 128) in tune_tiles.tile_candidates("flux", (64, 128))
    # infeasible shapes yield no candidates rather than bad tiles
    assert tune_tiles.tile_candidates("saxpy", (100,)) == ()


def test_tile_scope_resolution_precedence():
    assert tune_tiles.resolve_tile("saxpy", None, 1024) == 1024
    with tune_tiles.tile_scope({"saxpy": 2048}):
        assert tune_tiles.resolve_tile("saxpy", None, 1024) == 2048
        assert tune_tiles.resolve_tile("saxpy", 512, 1024) == 512  # explicit
        with tune_tiles.tile_scope({"saxpy": 256}):
            assert tune_tiles.resolve_tile("saxpy", None, 1024) == 256
    assert tune_tiles.resolve_tile("saxpy", None, 1024) == 1024


# -- measurement + decision ----------------------------------------------------

def test_auto_measures_commits_and_matches_heuristic_values():
    g, p = _record_graph()
    ex = Executor(g, donate=False, tune="auto")
    dec = ex.plan.tuning
    assert dec is not None and dec.source == "measured"
    assert dec.baseline_ms is not None and dec.tuned_ms is not None
    assert dec.tuned_ms <= dec.baseline_ms + 1e-9
    assert tune_search.STATS["measurements"] >= 3  # baseline + 2 layouts
    # the decision is rendered, with the chosen rows marked
    txt = ex.plan.describe_tuning()
    assert "measured" in txt and "heuristic" in txt
    assert ex.plan.describe().endswith(txt)

    base = Executor(g, donate=False)
    s0 = base.run(base.init_state(), 3)
    s1 = ex.run(ex.init_state(), 3)
    np.testing.assert_allclose(
        np.asarray(base.read(s0, p).field("a")),
        np.asarray(ex.read(s1, p).field("a")), rtol=1e-6)


def test_auto_measures_under_donation():
    """Candidates bench under the CALLER's donation setting (donation is
    part of the plan signature): a donating executor's tuner times the
    real donating executables — chaining state through each timed call —
    and the committed plan still matches the heuristic values."""
    g, p = _record_graph(name="pd")
    ex = Executor(g, tune="auto")                 # donate=True default
    assert ex.donate
    dec = ex.plan.tuning
    assert dec is not None and dec.source == "measured"
    assert tune_search.STATS["measurements"] >= 3

    base = Executor(g, donate=False)
    s0 = base.run(base.init_state(), 3)
    s1 = ex.run(ex.init_state(), 3)
    np.testing.assert_allclose(
        np.asarray(base.read(s0, p).field("a")),
        np.asarray(ex.read(s1, p).field("a")), rtol=1e-6)

    # second donating construction: cache hit, zero new measurements
    measured = tune_search.STATS["measurements"]
    ex2 = Executor(g, tune="auto")
    assert tune_search.STATS["measurements"] == measured
    assert ex2.plan.tuning.source == "cache"


def test_tuned_kernel_tiles_apply_and_preserve_values():
    g, r = _kernel_graph()
    ex = Executor(g, donate=False, tune="auto")
    dec = ex.plan.tuning
    # the saxpy kernel was consulted during the probe, so its tile axis
    # entered the joint search space (3 layouts x 5 tiles), and at least
    # one measured joint candidate carries a saxpy tile
    assert dec.proposed >= 15
    assert dec.measured >= 2
    assert dec.proposed == dec.pruned + dec.measured
    assert any(m.kind == "joint" and "saxpy=" in m.candidate
               for m in dec.measurements)
    base = Executor(g, donate=False)
    s0 = base.run(base.init_state(), 2)
    s1 = ex.run(ex.init_state(), 2)
    np.testing.assert_allclose(
        np.asarray(base.read(s0, r).field("y")),
        np.asarray(ex.read(s1, r).field("y")), rtol=1e-5)


def test_load_mode_without_cache_keeps_heuristics_and_never_measures():
    g, _ = _record_graph(name="pl")
    ex = Executor(g, tune="load")
    assert tune_search.STATS["measurements"] == 0
    dec = ex.plan.tuning
    assert dec.source == "heuristic" and not dec.applied
    assert "heuristic configuration in effect" in ex.plan.describe_tuning()
    # plan identical to tune="off"
    assert ex.plan.per_segment == Executor(g).plan.per_segment


def test_invalid_tune_mode_rejected():
    g, _ = _record_graph(name="pv")
    with pytest.raises(ValueError, match="tune must be"):
        Executor(g, tune="always")


# -- cache persistence ---------------------------------------------------------

def test_cache_hit_performs_zero_timed_measurements():
    g, _ = _record_graph(name="pc")
    Executor(g, donate=False, tune="auto")
    measured = tune_search.STATS["measurements"]
    assert measured > 0

    ex2 = Executor(g, donate=False, tune="auto")
    assert tune_search.STATS["measurements"] == measured  # ZERO new
    assert ex2.plan.tuning.source == "cache"

    # drop the in-process memo: the decision still loads from DISK with
    # zero measurements (the cross-process path, same process)
    tune_cache.clear_memo()
    ex3 = Executor(g, donate=False, tune="auto")
    assert tune_search.STATS["measurements"] == measured
    assert ex3.plan.tuning.source == "cache"
    assert ex3.plan.tuning.measurements  # report survives the round-trip
    # and the applied plans agree
    assert ex3.plan.per_segment == ex2.plan.per_segment


def test_device_assortment_shape_and_determinism():
    """The key ingredient: sorted (platform, kind, count) triples over
    the FULL device complement, plus the process count."""
    kinds, procs = tune_cache.device_assortment()
    assert tune_cache.device_assortment() == (kinds, procs)
    assert procs >= 1
    import jax
    assert sum(n for _, _, n in kinds) == len(jax.devices())
    assert list(kinds) == sorted(kinds)
    for platform, kind, n in kinds:
        assert isinstance(platform, str) and isinstance(kind, str)
        assert n >= 1


def test_tuning_key_changes_with_device_assortment(monkeypatch):
    """A decision measured on one device assortment must MISS on another
    (1x cpu vs 8x cpu vs multi-host) — keying by devices()[0] alone used
    to conflate them all."""
    g, _ = _record_graph(name="pa")
    probe = Executor(g, donate=False)
    key_here = tune_search.tuning_key(probe)
    seen = set()
    for fake in ((("cpu", "", 1),), (("cpu", "", 8),),
                 (("tpu", "TPU v5e", 4),)):
        for procs in (1, 2):
            monkeypatch.setattr(tune_cache, "device_assortment",
                                lambda f=fake, p=procs: (f, p))
            seen.add(tune_search.tuning_key(probe))
    assert len(seen) == 6           # every assortment keys differently
    monkeypatch.undo()
    assert tune_search.tuning_key(probe) == key_here  # and it's stable


def test_corrupt_cache_falls_back_to_heuristics_with_single_warning():
    g, _ = _record_graph(name="pk")
    probe = Executor(g)   # same heuristic plan -> same tuning key
    key = tune_search.tuning_key(probe)
    path = tune_cache.cache_path(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{ this is not json")

    with pytest.warns(RuntimeWarning, match="corrupt or incompatible"):
        ex = Executor(g, tune="load")
    assert not ex.plan.tuning.applied       # heuristics in effect
    assert ex.plan.per_segment == probe.plan.per_segment

    # second construction: the warning does NOT repeat
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ex2 = Executor(g, tune="load")
    assert not ex2.plan.tuning.applied
    assert tune_search.STATS["measurements"] == 0


def test_schema_mismatch_is_a_miss_and_auto_remeasures():
    g, _ = _record_graph(name="ps")
    # donate is part of the plan signature, hence of the tuning key —
    # the probe must match the tuned executor's construction
    probe = Executor(g, donate=False)
    key = tune_search.tuning_key(probe)
    path = tune_cache.cache_path(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"schema": 999, "key": key,
                                "layouts": {}, "tiles": {}}))
    with pytest.warns(RuntimeWarning, match="schema"):
        ex = Executor(g, donate=False, tune="auto")
    assert ex.plan.tuning.source == "measured"
    assert tune_search.STATS["measurements"] > 0
    # the re-measured decision overwrote the bad file
    assert json.loads(path.read_text())["schema"] == \
        tune_cache.SCHEMA_VERSION


def test_cache_written_by_one_process_loads_in_subprocess(tmp_path):
    """The serving pattern across processes: this process tunes and
    persists; a fresh interpreter constructs the same graph with
    tune="auto" and must apply the cached decision with ZERO timed
    measurements."""
    from _tuning_workload import make_graph

    cache_dir = os.environ["REPRO_TUNE_CACHE"]
    g = make_graph()
    ex = Executor(g, donate=False, tune="auto")
    assert tune_search.STATS["measurements"] > 0
    assert ex.plan.tuning.source == "measured"
    files = os.listdir(cache_dir)
    assert len(files) == 1

    # the graph must come from the same importable module in both
    # processes — the plan signature keys node fns by module/qualname
    code = """
from _tuning_workload import make_graph
from repro.core import Executor
from repro.tuning import search as tune_search

ex = Executor(make_graph(), donate=False, tune="auto")
assert ex.plan.tuning.source == "cache", ex.plan.tuning.source
assert tune_search.STATS["measurements"] == 0, tune_search.STATS
assert tune_search.STATS["cache_hits"] == 1, tune_search.STATS
print("SUBPROCESS-LAYOUTS:",
      sorted((k, v.name) for k, v in ex.plan.tuning.layouts.items()))
print("SUBPROCESS-OK")
"""
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here, env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "SUBPROCESS-OK" in out.stdout
    # the subprocess applied the SAME decision this process measured
    want = sorted((k, v.name) for k, v in ex.plan.tuning.layouts.items())
    assert f"SUBPROCESS-LAYOUTS: {want}" in out.stdout


# -- v2 -> v3 migration --------------------------------------------------------


def _write_legacy_entry(key, layouts, tiles, measurements=()):
    """Hand-craft a schema-1 entry exactly as the v2 coordinate tuner
    persisted it."""
    path = tune_cache.cache_path(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({
        "schema": 1, "key": key, "layouts": layouts, "tiles": tiles,
        "baseline_ms": 1.0, "tuned_ms": 0.5,
        "measurements": list(measurements)}))


def test_v2_entry_migrates_to_v3_without_remeasure():
    g, p = _record_graph(name="pg")
    probe = Executor(g, donate=False)
    v2key = tune_search.legacy_tuning_key(probe)
    v3key = tune_search.tuning_key(probe)
    _write_legacy_entry(v2key, {"pg": "SOA"}, {})

    ex = Executor(g, donate=False, tune="auto")
    dec = ex.plan.tuning
    assert dec.source == "migrated"
    assert dec.layouts == {"pg": Layout.SOA}
    assert tune_search.STATS["measurements"] == 0     # zero re-measurement
    assert tune_search.STATS["migrations"] == 1
    # the decision was re-keyed and re-persisted under the v3 schema
    v3 = json.loads(tune_cache.cache_path(v3key).read_text())
    assert v3["schema"] == tune_cache.SCHEMA_VERSION
    assert v3["key"] == v3key
    # and it really applied: every segment stores p as SOA
    assert all(seg["pg"] is Layout.SOA for seg in ex.plan.per_segment
               if "pg" in seg)

    # second construction: a plain v3 cache hit, no second migration
    ex2 = Executor(g, donate=False, tune="auto")
    assert ex2.plan.tuning.source == "cache"
    assert tune_search.STATS["migrations"] == 1
    assert tune_search.STATS["measurements"] == 0


def test_infeasible_v2_entry_warns_once_and_retunes():
    g, p = _record_graph(name="ph")
    probe = Executor(g, donate=False)
    v2key = tune_search.legacy_tuning_key(probe)
    # a layout decision for a key this plan cannot search, and a tile
    # decision for a kernel with no registered hook: both infeasible
    _write_legacy_entry(v2key, {"nosuchkey": "SOA"},
                        {"nosuchkernel": 4})

    with pytest.warns(RuntimeWarning, match="no longer feasible"):
        ex = Executor(g, donate=False, tune="auto")
    assert ex.plan.tuning.source == "measured"        # fresh tuning
    assert tune_search.STATS["measurements"] > 0
    assert tune_search.STATS["migrations"] == 0

    # the warning does NOT repeat on the next construction (which now
    # hits the freshly measured v3 entry anyway)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ex2 = Executor(g, donate=False, tune="auto")
    assert ex2.plan.tuning.source == "cache"


def test_v2_migration_applies_in_subprocess(tmp_path):
    """The serving pattern across the schema bump: a process holding
    only a v2 cache entry constructs with tune="auto" in a fresh
    interpreter and must apply the migrated decision with ZERO timed
    measurements."""
    from _tuning_workload import make_graph

    g = make_graph()
    probe = Executor(g, donate=False)
    v2key = tune_search.legacy_tuning_key(probe)
    _write_legacy_entry(v2key, {"px": "SOA"}, {})

    code = """
from _tuning_workload import make_graph
from repro.core import Executor, Layout
from repro.tuning import search as tune_search

ex = Executor(make_graph(), donate=False, tune="auto")
assert ex.plan.tuning.source == "migrated", ex.plan.tuning.source
assert tune_search.STATS["measurements"] == 0, tune_search.STATS
assert tune_search.STATS["migrations"] == 1, tune_search.STATS
assert ex.plan.tuning.layouts == {"px": Layout.SOA}
print("SUBPROCESS-MIGRATED-OK")
"""
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here, env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "SUBPROCESS-MIGRATED-OK" in out.stdout


def test_atomic_store_and_memo_roundtrip():
    tune_cache.store("k1", {"layouts": {}, "tiles": {},
                            "measurements": []})
    assert tune_cache.load("k1")["schema"] == tune_cache.SCHEMA_VERSION
    tune_cache.clear_memo()
    loaded = tune_cache.load("k1")
    assert loaded is not None and loaded["key"] == "k1"
