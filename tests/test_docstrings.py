"""Public-API documentation contract.

Every name exported from ``repro.core`` (the user-facing surface: Graph,
Executor, Layout, plan introspection, halo schedule types, ...) and from
``repro.tuning`` must carry a non-empty docstring, as must the public
methods they expose.  The README quickstart snippet must stay in sync
with ``examples/quickstart.py`` (the tested doc-example)."""

import inspect
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _exported(module):
    for name in module.__all__:
        yield name, getattr(module, name)


def _documentable(obj) -> bool:
    return inspect.isclass(obj) or inspect.isfunction(obj) \
        or inspect.ismethod(obj) or isinstance(obj, property) \
        or callable(obj)


def _check_module_exports(module):
    missing = []
    for name, obj in _exported(module):
        if inspect.ismodule(obj) or not _documentable(obj):
            continue   # plain constants (AOSOA_LANE) / submodules
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip():
            missing.append(name)
    return missing


def test_core_exports_have_docstrings():
    import repro.core as core

    missing = _check_module_exports(core)
    assert not missing, (
        f"exported names without docstrings in repro.core: {missing}")


def test_tuning_exports_have_docstrings():
    import repro.tuning as tuning

    missing = _check_module_exports(tuning)
    assert not missing, (
        f"exported names without docstrings in repro.tuning: {missing}")


@pytest.mark.parametrize("cls_path", [
    "repro.core.Graph", "repro.core.Executor", "repro.core.DistTensor",
    "repro.core.RecordArray", "repro.core.RecordSpec",
    "repro.core.LayoutPlan", "repro.core.ScheduleDag",
])
def test_public_methods_have_docstrings(cls_path):
    mod_name, cls_name = cls_path.rsplit(".", 1)
    mod = __import__(mod_name, fromlist=[cls_name])
    cls = getattr(mod, cls_name)
    missing = []
    for name, member in vars(cls).items():
        if name.startswith("_"):
            continue
        obj = member.fget if isinstance(member, property) else member
        if not (inspect.isfunction(obj) or isinstance(member, property)):
            continue
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip():
            missing.append(name)
    assert not missing, (
        f"public methods without docstrings on {cls_path}: {missing}")


def test_key_exports_carry_examples():
    """The tentpole public symbols document themselves with a worked
    example (an ``Example``/``>>>``/code block in the docstring)."""
    import repro.core as core

    for name in ("Executor", "storage_candidates"):
        doc = inspect.getdoc(getattr(core, name)) or ""
        assert "Example" in doc or ">>>" in doc, (
            f"{name} docstring lacks an example")


def test_readme_quickstart_snippet_matches_example_file():
    """The README's quickstart code block is extracted verbatim from
    ``examples/quickstart.py`` (between the readme-snippet markers) —
    drift in either place fails here."""
    readme = open(os.path.join(REPO, "README.md")).read()
    example = open(os.path.join(REPO, "examples", "quickstart.py")).read()

    m = re.search(
        r"<!-- doc-example: examples/quickstart.py -->\s*```python\n"
        r"(.*?)```", readme, re.S)
    assert m, "README lacks the tested quickstart doc-example block"
    readme_snippet = m.group(1).strip()

    m2 = re.search(r"# --8<-- \[start:readme\]\n(.*?)# --8<-- \[end:readme\]",
                   example, re.S)
    assert m2, "examples/quickstart.py lacks the readme snippet markers"
    file_snippet = m2.group(1).strip()

    assert readme_snippet == file_snippet, (
        "README quickstart snippet drifted from examples/quickstart.py — "
        "update whichever side is stale")
