"""Minimal stand-in for ``hypothesis`` when it is not installed.

The real dependency is declared in requirements-dev.txt; this fallback
keeps test *collection* from hard-erroring on bare containers and runs
each ``@given`` test over a deterministic pseudo-random sample of the
strategy space (seeded per test name, so failures reproduce).

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``booleans``, ``lists``, ``text``.  Shrinking, the
database, and ``@example`` are out of scope — install hypothesis for the
real thing.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

__version__ = "0.0-stub"

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=10):
    alphabet = list(alphabet)

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(alphabet[rng.randrange(len(alphabet))]
                       for _ in range(n))

    return _Strategy(draw)


def lists(elements, min_size=0, max_size=10, unique=False):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out, tries = [], 0
        while len(out) < n and tries < 1000:
            v = elements.example(rng)
            tries += 1
            if v not in out:
                out.append(v)
        return out

    return _Strategy(draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            # @settings may be stacked on top of @given: read from wrapper
            n = getattr(wrapper, "_stub_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                fn(*args, **kw, **drawn)

        # hide the strategy params from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    """Register this stub as ``hypothesis`` in ``sys.modules``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__version__ = __version__
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "lists",
                 "text"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
