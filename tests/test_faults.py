"""Chaos suite: deterministic fault injection across the runtime.

Every test installs a :class:`FaultPlan` (``repro.runtime.faults``)
scheduling named faults at exact ``(step, site)`` coordinates and
asserts *bitwise-equal* recovery — injected chaos must be invisible in
the results, visible only in the fault log / degradation ladder.

Covers: the fault-kind x {async, sync} x {dag, sequential} chaos
matrix on the executor, the hung-callback watchdog, the graceful-
degradation ladder (demotion AND re-promotion), halo-block faults,
FaultPlan/RetryPolicy unit semantics, Supervisor restore edge cases +
deterministic straggler injection + checkpoint-write faults, the
Prefetcher robustness contract, and the tuning cache's corrupt-file
fallback and cross-process lock protocol.
"""

import json
import os
import subprocess
import sys
import textwrap
import time
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import (Boundary, DistTensor, ExecutionKind, Executor, Graph,
                        HostTimeoutError, clear_executable_cache,
                        concurrent_padded_access)
from repro.data import Prefetcher
from repro.runtime import Supervisor, TransientError
from repro.runtime.faults import (Fault, FaultPlan, InjectedDeterministicFault,
                                  InjectedFault, RetryPolicy, fault_scope,
                                  trip)
from repro.tuning import cache as tcache

# backoff-free policy: chaos tests retry instantly and deterministically
_NOSLEEP = RetryPolicy(max_retries=6, base_delay=0.0, sleep=lambda d: None)
_SILENT = staticmethod(lambda *_: None)


def _chain_graph(seen=None, name="chaos-chain"):
    """device split -> host callback -> device split (the async-runtime
    shape: both device regions AND a pooled host node to fault)."""
    a = DistTensor("a", (8,))
    g = Graph(name=name)
    g.split(lambda x: x + 1.0, a, writes=(0,))
    sink = seen if seen is not None else []
    g.then(lambda x: sink.append(float(np.asarray(x)[0])),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    g.then_split(lambda x: x * 2.0, a, writes=(0,))
    return g


def _assert_state_equal(got, want):
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# -- the chaos matrix: kind x dispatch mode x schedule ------------------------

_KINDS = [
    ("region-error", lambda: Fault("executor.region", nth=0)),
    ("host-error", lambda: Fault("executor.host", nth=0)),
    ("region-delay", lambda: Fault("executor.region", nth=0,
                                   kind="delay", delay_s=0.01)),
]


@pytest.mark.parametrize("schedule", ["dag", "sequential"])
@pytest.mark.parametrize("async_regions", [True, False],
                         ids=["async", "sync"])
@pytest.mark.parametrize("kind,mk", _KINDS, ids=[k for k, _ in _KINDS])
def test_chaos_matrix_bitwise_recovery(kind, mk, async_regions, schedule):
    """Every fault kind, in every dispatch mode and schedule, recovers to
    a bitwise-identical state under the shared RetryPolicy — and the
    executor stays usable afterwards."""
    g = _chain_graph()
    ref = Executor(g, donate=False, schedule=schedule,
                   async_regions=async_regions)
    s0 = ref.init_state()
    want = ref(dict(s0))

    ex = Executor(g, donate=False, schedule=schedule,
                  async_regions=async_regions)
    plan = FaultPlan([mk()])
    with fault_scope(plan):
        got = _NOSLEEP.call(lambda: ex(dict(s0)))
    assert plan.exhausted(), plan.report()
    _assert_state_equal(got, want)
    # recovered executor completes a subsequent clean pass
    _assert_state_equal(ex(dict(s0)), want)


def test_dispatch_fault_recovers_in_async_mode():
    """A fault at host-pool submission (async dispatcher only) is
    transient: the pass aborts cleanly and the retry is bitwise-equal."""
    g = _chain_graph()
    ex = Executor(g, donate=False, async_regions=True)
    s0 = ex.init_state()
    want = ex(dict(s0))
    plan = FaultPlan([Fault("executor.dispatch", nth=0)])
    with fault_scope(plan):
        got = _NOSLEEP.call(lambda: ex(dict(s0)))
    assert plan.exhausted(), plan.report()
    _assert_state_equal(got, want)


def test_halo_block_fault_aborts_build_then_recovers():
    """A fault in one scheduled halo-block transfer aborts the pass
    before any state is consumed; the retry re-runs the exchange and the
    stencil result is bitwise-identical."""
    clear_executable_cache()   # halo trips fire when the exchange runs
    src = DistTensor("src", (32,), halo=(1,), boundary=Boundary.TRANSMISSIVE)
    dst = DistTensor("dst", (32,))
    g = Graph(name="chaos-halo")
    g.split(lambda s, d: s[2:] - s[:-2], concurrent_padded_access(src), dst)
    x0 = np.arange(32, dtype=np.float32)

    ref = Executor(g, donate=False)
    s0 = ref.init_state(src=x0)
    want = ref(dict(s0))

    clear_executable_cache()
    ex = Executor(g, donate=False)
    plan = FaultPlan([Fault("halo.block", nth=0)])
    with fault_scope(plan):
        got = _NOSLEEP.call(lambda: ex(dict(s0)))
    assert plan.exhausted(), plan.report()
    _assert_state_equal(got, want)


# -- hung-callback watchdog ---------------------------------------------------

def test_watchdog_trips_hung_callback_without_deadlock():
    """A host callback that hangs past ``host_timeout`` raises
    HostTimeoutError (transient) instead of deadlocking — and the
    executor (and the shared host pool) stay usable afterwards."""
    g = _chain_graph()
    ex = Executor(g, donate=False, host_timeout=0.3, degrade=False)
    s0 = ex.init_state()
    want = ex(dict(s0))

    plan = FaultPlan([Fault("executor.host", nth=0,
                            kind="delay", delay_s=1.5)])
    t0 = time.perf_counter()
    with fault_scope(plan):
        with pytest.raises(HostTimeoutError):
            ex(dict(s0))
    assert time.perf_counter() - t0 < 1.4, "watchdog waited out the hang"
    assert isinstance(HostTimeoutError("x"), TransientError)
    # the hung worker still occupies its pool slot, but the executor
    # itself completes subsequent clean passes
    _assert_state_equal(ex(dict(s0)), want)


def test_watchdog_cancels_successor_callbacks():
    """When a host callback hangs, its successors on the host-order
    chain are cancelled — they never execute their side effects."""
    seen = []
    a = DistTensor("a", (8,))
    g = Graph(name="chaos-two-hosts")
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda x: seen.append("first"),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    g.then(lambda x: seen.append("second"),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    ex = Executor(g, donate=False, host_timeout=0.25, degrade=False)
    s0 = ex.init_state()
    ex(dict(s0))
    assert seen == ["first", "second"]

    base = len(seen)
    plan = FaultPlan([Fault("executor.host", nth=0,
                            kind="delay", delay_s=1.0)])
    with fault_scope(plan):
        with pytest.raises(HostTimeoutError):
            ex(dict(s0))
    time.sleep(1.2)   # let the hung worker finish its injected sleep
    assert "second" not in seen[base:], seen[base:]


# -- the graceful-degradation ladder ------------------------------------------

def test_ladder_demotes_then_repromotes():
    """Repeated transient failures at one site walk the executor down
    the ladder one level per ``demote_after`` failures; ``promote_after``
    consecutive clean passes walk it back up.  Results stay bitwise-
    identical at every level, and every transition is introspectable in
    ``plan.degradations`` / ``plan.describe()``."""
    g = _chain_graph()
    ex = Executor(g, donate=False, demote_after=1, promote_after=2)
    s0 = ex.init_state()
    want = ex(dict(s0))

    plan = FaultPlan([Fault("executor.region", nth=0, times=2)])
    with fault_scope(plan):
        got = _NOSLEEP.call(lambda: ex(dict(s0)))
    assert plan.exhausted(), plan.report()
    _assert_state_equal(got, want)

    # two failures at executor.region with demote_after=1:
    # async_regions -> sync -> sequential
    assert ex.ladder_level == 2
    assert not ex.async_regions and ex.schedule == "sequential"
    evs = ex.plan.degradations
    assert [(e.action, e.frm, e.to) for e in evs] == [
        ("demote", "async_regions", "sync"),
        ("demote", "sync", "sequential")]
    assert all(e.site == "executor.region" for e in evs)
    text = ex.plan.describe()
    assert "ladder" in text and "demote" in text

    # re-promotion: promote_after=2 clean passes climb one level each
    _assert_state_equal(ex(dict(s0)), want)   # (recovery pass was clean #1)
    assert ex.ladder_level == 1
    for _ in range(2):
        _assert_state_equal(ex(dict(s0)), want)
    assert ex.ladder_level == 0
    assert ex.async_regions and ex.schedule == "dag"
    actions = [e.action for e in ex.plan.degradations]
    assert actions == ["demote", "demote", "promote", "promote"]


def test_deterministic_fault_bypasses_retry_and_ladder():
    """``transient=False`` faults raise InjectedDeterministicFault:
    RetryPolicy re-raises immediately and the ladder does not move."""
    g = _chain_graph()
    ex = Executor(g, donate=False, demote_after=1)
    s0 = ex.init_state()
    ex(dict(s0))
    plan = FaultPlan([Fault("executor.region", nth=0, transient=False)])
    calls = []
    with fault_scope(plan):
        with pytest.raises(InjectedDeterministicFault):
            _NOSLEEP.call(lambda: (calls.append(1), ex(dict(s0))))
    assert len(calls) == 1          # no retry
    assert ex.ladder_level == 0
    assert ex.plan.degradations == []


# -- FaultPlan semantics ------------------------------------------------------

def test_fault_validation_rejects_bad_plans():
    with pytest.raises(ValueError, match="unknown fault site"):
        Fault("executor.regino", nth=0)
    with pytest.raises(ValueError, match="coordinate"):
        Fault("executor.region")
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("executor.region", nth=0, kind="nuke")


def test_fault_step_coordinate_and_site_attribution():
    plan = FaultPlan([Fault("batcher.step", step=3)])
    with fault_scope(plan):
        for s in range(3):
            assert trip("batcher.step", step=s) is None
        with pytest.raises(InjectedFault) as ei:
            trip("batcher.step", step=3)
        assert trip("batcher.step", step=3) is None   # times=1 spent
    assert ei.value.site == "batcher.step"
    assert isinstance(ei.value, TransientError)
    assert plan.exhausted()
    assert plan.visits["batcher.step"] == 5


def test_fault_nth_times_and_match_filters():
    plan = FaultPlan([Fault("executor.region", nth=1, times=2,
                            match="segment")])
    with fault_scope(plan):
        trip("executor.region", detail="segment0")   # visit 0: before nth
        with pytest.raises(InjectedFault):
            trip("executor.region", detail="segment1")
        trip("executor.region", detail="region2")    # match filter: no fire
        with pytest.raises(InjectedFault):
            trip("executor.region", detail="segment3")
        trip("executor.region", detail="segment4")   # times exhausted
    assert plan.exhausted()
    assert [d for _, d, _, _ in plan.fired] == ["segment1", "segment3"]


def test_delay_fault_sleeps_then_continues():
    plan = FaultPlan([Fault("supervisor.step", nth=0,
                            kind="delay", delay_s=0.05)])
    with fault_scope(plan):
        t0 = time.perf_counter()
        f = trip("supervisor.step")
        dt = time.perf_counter() - t0
    assert f is not None and f.kind == "delay"
    assert dt >= 0.04
    assert plan.exhausted()


def test_trip_is_noop_without_a_plan():
    assert trip("executor.region", detail="x") is None


def test_plan_report_lists_visits_and_fired():
    plan = FaultPlan([Fault("batcher.step", nth=0),
                      Fault("halo.block", nth=5)])
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            trip("batcher.step")
    assert not plan.exhausted()
    r = plan.report()
    assert "batcher.step" in r and "FIRED" in r


# -- RetryPolicy semantics ----------------------------------------------------

def test_retry_policy_classification():
    pol = RetryPolicy()
    assert pol.is_transient(TransientError("x"))
    assert pol.is_transient(InjectedFault("x"))
    assert pol.is_transient(HostTimeoutError("x"))
    assert not pol.is_transient(ValueError("x"))
    assert not pol.is_transient(InjectedDeterministicFault("x"))
    extra = RetryPolicy(transient_types=(ConnectionError,))
    assert extra.is_transient(ConnectionError("x"))


def test_retry_policy_backoff_deterministic_and_capped():
    a, b = RetryPolicy(seed=7), RetryPolicy(seed=7)
    seq = [a.backoff(n) for n in range(1, 9)]
    assert seq == [b.backoff(n) for n in range(1, 9)]
    assert all(d <= a.max_delay * (1 + a.jitter) for d in seq)
    assert seq[1] > seq[0]   # exponential growth before the cap
    assert RetryPolicy(seed=1).backoff(1) != RetryPolicy(seed=2).backoff(1)


def test_retry_policy_call_retries_then_raises():
    sleeps = []
    pol = RetryPolicy(max_retries=3, base_delay=0.01, sleep=sleeps.append)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError("boom")
        return "ok"

    assert pol.call(flaky) == "ok"
    assert calls["n"] == 3 and len(sleeps) == 2

    with pytest.raises(TransientError):
        pol.call(lambda: (_ for _ in ()).throw(TransientError("down")))
    assert len(sleeps) == 2 + pol.max_retries   # budget exhausted with backoff
    n0 = len(sleeps)
    with pytest.raises(ValueError):   # deterministic: no retry, no sleep
        pol.call(lambda: (_ for _ in ()).throw(ValueError("no")))
    assert len(sleeps) == n0


# -- Supervisor: restore edge cases, stragglers, checkpoint faults ------------

def _fastsup(**kw):
    kw.setdefault("log", lambda *_: None)
    kw.setdefault("retry", RetryPolicy(base_delay=0.0, sleep=lambda d: None))
    return Supervisor(**kw)


def test_supervisor_restore_without_checkpoint_replays_in_place(tmp_path):
    """A transient failure before the first checkpoint restores to the
    SAME step with the live state — and logs a recovery episode."""
    armed = {"on": True}

    def step_fn(state, batch):
        if armed["on"] and int(state["x"]) == 2:
            armed["on"] = False
            raise TransientError("hiccup")
        return {"x": state["x"] + batch}

    sup = _fastsup(step_fn=step_fn,
                   ckpt=CheckpointManager(str(tmp_path / "ck")),
                   ckpt_every=10**9)
    state = sup.run({"x": jnp.zeros(())}, lambda i: jnp.asarray(1.0), 0, 6)
    assert float(state["x"]) == 6.0
    assert sup.failures == 1
    assert len(sup.recoveries) == 1
    failed, resumed, ms = sup.recoveries[0]
    assert (failed, resumed) == (2, 2) and ms >= 0.0


def test_supervisor_restore_after_resize_with_none_shardings(tmp_path):
    """``resize()`` to explicit per-leaf None shardings must not break a
    later checkpoint restore (device_put without a target sharding)."""
    armed = {"on": True}

    def step_fn(state, batch):
        if armed["on"] and int(state["x"]) == 3:
            armed["on"] = False
            raise TransientError("flap")
        return {"x": state["x"] + 1.0}

    sup = _fastsup(step_fn=step_fn,
                   ckpt=CheckpointManager(str(tmp_path / "ck")),
                   ckpt_every=2)
    state = sup.resize({"x": jnp.zeros(())}, {"x": None})
    assert sup.state_shardings == {"x": None}
    state = sup.run(state, lambda i: None, 0, 6)
    assert float(state["x"]) == 6.0
    assert sup.failures == 1
    # rewound to the step-2 checkpoint: per-step retry budget was reset
    failed, resumed, _ = sup.recoveries[0]
    assert failed == 3 and resumed == 3


def test_injected_slow_step_is_flagged_straggler(tmp_path):
    """A delay-kind fault at supervisor.step makes straggler detection
    deterministic: the injected step is flagged with its wall time."""
    sup = _fastsup(step_fn=lambda s, b: s,
                   ckpt=CheckpointManager(str(tmp_path / "ck")),
                   ckpt_every=10**9, straggler_zscore=3.0)
    plan = FaultPlan([Fault("supervisor.step", step=18,
                            kind="delay", delay_s=0.25)])
    with fault_scope(plan):
        sup.run({"x": jnp.zeros(())}, lambda i: None, 0, 24)
    assert plan.exhausted()
    flagged = [s for s, dt in sup.stats.stragglers]
    assert 18 in flagged
    dt = dict(sup.stats.stragglers)[18]
    assert dt >= 0.25


def test_checkpoint_write_fault_is_retried_transparently(tmp_path):
    """An injected checkpoint.save failure surfaces on the next save's
    wait() INSIDE the supervised loop, is classified transient, and the
    run completes with the correct state."""
    plan = FaultPlan([Fault("checkpoint.save", nth=0)])
    sup = _fastsup(step_fn=lambda s, b: {"x": s["x"] + 1.0},
                   ckpt=CheckpointManager(str(tmp_path / "ck")),
                   ckpt_every=5)
    with fault_scope(plan):
        state = sup.run({"x": jnp.zeros(())}, lambda i: None, 0, 10)
    assert plan.exhausted(), plan.report()
    assert float(state["x"]) == 10.0
    assert sup.failures == 1


# -- Prefetcher robustness contract -------------------------------------------

class _Source:
    def __init__(self, fail_at=None):
        self.fail_at = fail_at

    def batch_at(self, step):
        if self.fail_at is not None and step == self.fail_at:
            raise ValueError(f"bad shard at {step}")
        return {"step": np.asarray(step)}


def test_prefetcher_propagates_producer_error_with_step():
    pf = Prefetcher(_Source(fail_at=2), depth=2)
    assert pf.next()[0] == 0
    assert pf.next()[0] == 1
    with pytest.raises(RuntimeError, match="step 2") as ei:
        pf.next()
    assert isinstance(ei.value.__cause__, ValueError)
    pf.close()
    assert not pf._thread.is_alive()


def test_prefetcher_never_drops_batches_under_slow_consumer():
    pf = Prefetcher(_Source(), depth=1)
    got = []
    for _ in range(12):
        time.sleep(0.005)   # let the producer outrun the queue
        step, batch = pf.next()
        got.append(step)
        assert int(batch["step"]) == step
    pf.close()
    assert got == list(range(12))
    assert not pf._thread.is_alive()


def test_prefetcher_close_reaps_blocked_producer():
    pf = Prefetcher(_Source(), depth=1)
    time.sleep(0.05)   # producer is now blocked on the full queue
    pf.close()
    assert not pf._thread.is_alive()


# -- tuning cache: corrupt-file fallback + cross-process lock -----------------

def test_corrupt_fault_exercises_warn_once_fallback(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    tcache.clear_memo()
    key = "chaoskey"
    tcache.store(key, {"layouts": {}, "tiles": {}, "measurements": []})
    tcache.clear_memo()   # force the (about-to-be-garbled) file read

    plan = FaultPlan([Fault("tuning.cache.load", nth=0, kind="corrupt")])
    with fault_scope(plan):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert tcache.load(key) is None
    assert plan.exhausted()
    assert any(issubclass(x.category, RuntimeWarning) for x in w)

    # second read of the same corrupt file: still a miss, NO new warning
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        assert tcache.load(key) is None
    assert not any(issubclass(x.category, RuntimeWarning) for x in w2)
    tcache.clear_memo()


def test_error_fault_on_cache_load_raises(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    tcache.clear_memo()
    with fault_scope(FaultPlan([Fault("tuning.cache.load", nth=0)])):
        with pytest.raises(InjectedFault):
            tcache.load("anything")
    tcache.clear_memo()


def test_tuning_lock_acquire_release(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    with tcache.tuning_lock("k") as got:
        assert got is True
        assert (tmp_path / "k.lock").exists()
    assert not (tmp_path / "k.lock").exists()


def test_tuning_lock_breaks_stale_lock(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    lock = tmp_path / "k.lock"
    lock.write_text("999999 0\n")
    old = time.time() - 3600
    os.utime(lock, (old, old))
    t0 = time.perf_counter()
    with tcache.tuning_lock("k", timeout_s=10.0, stale_s=60.0) as got:
        assert got is True
    assert time.perf_counter() - t0 < 5.0
    assert not lock.exists()


def test_tuning_lock_timeout_proceeds_unlocked(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    lock = tmp_path / "k.lock"
    lock.write_text(f"{os.getpid()} {time.time()}\n")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with tcache.tuning_lock("k", timeout_s=0.2, stale_s=3600.0) as got:
            assert got is False
    assert any("proceeding unlocked" in str(x.message) for x in w)
    assert lock.exists()   # not ours: left in place


def test_tuning_lock_cross_process_mutual_exclusion(tmp_path):
    """Two processes do racing read-modify-write increments under
    ``tuning_lock``; no update may be lost."""
    src_dir = Path(tcache.__file__).resolve().parents[2]
    code = textwrap.dedent("""
        import json
        from repro.tuning import cache
        p = cache.cache_dir() / "counter.json"
        for _ in range(15):
            with cache.tuning_lock("ctr", timeout_s=120.0) as got:
                assert got, "lock must be acquired"
                n = json.loads(p.read_text())["n"] if p.exists() else 0
                p.write_text(json.dumps({"n": n + 1}))
    """)
    env = dict(os.environ, REPRO_TUNE_CACHE=str(tmp_path),
               PYTHONPATH=str(src_dir))
    procs = [subprocess.Popen([sys.executable, "-c", code], env=env)
             for _ in range(2)]
    for p in procs:
        assert p.wait(timeout=300) == 0
    assert json.loads((tmp_path / "counter.json").read_text())["n"] == 30
    assert not (tmp_path / "ctr.lock").exists()
