"""Multi-device behaviour (8 fake CPU devices via subprocess — jax locks
the device count at first init, so these cannot run in the main pytest
process; see conftest.run_subprocess_devices)."""

import pytest

from conftest import run_subprocess_devices


@pytest.mark.slow
def test_halo_exchange_multidevice():
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (DistTensor, Graph, Executor, Boundary,
                        concurrent_padded_access, make_mesh)
mesh = make_mesh((4,), ("gx",))
size = 64
src = DistTensor("src", (size,), partition=("gx",), halo=(1,),
                 boundary=Boundary.TRANSMISSIVE)
dst = DistTensor("dst", (size,), partition=("gx",))
for overlap in (False, True):
    g = Graph()
    g.split(lambda s, d: s[2:] - s[:-2], concurrent_padded_access(src), dst,
            overlap=overlap)
    ex = Executor(g, mesh=mesh)
    x0 = jnp.arange(size, dtype=jnp.float32) ** 2
    st = ex.init_state(src=x0)
    st = ex(st)
    xp = np.pad(np.arange(size, dtype=np.float64) ** 2, 1, mode="edge")
    np.testing.assert_allclose(np.asarray(st["dst"]), xp[2:] - xp[:-2])
print("OK")
""")


@pytest.mark.slow
def test_halo_corners_2d_all_policies():
    """2-D-partitioned halo exchange (edge strips + corner blocks via the
    two-phase schedule) matches the single-device reference for every
    Boundary policy, through both the synchronous and the overlapped
    lowering, on an 8-device (4x2) mesh."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (DistTensor, Graph, Executor, Boundary,
                        concurrent_padded_access, make_mesh,
                        pad_boundary_only)

mesh = make_mesh((4, 2), ("gx", "gy"))
nx, ny = 16, 12

def sten(s, d):
    # 3x5-point stencil touching the corner halo cells; shape-polymorphic
    n0, n1 = s.shape[0] - 2, s.shape[1] - 4
    out = 0.0
    for di in range(3):
        for dj in range(5):
            out = out + (di + 1) * (dj + 1) * s[di:di + n0, dj:dj + n1]
    return out

x0 = jnp.asarray(np.random.default_rng(0).standard_normal((nx, ny)),
                 jnp.float32)
for boundary in Boundary:
    src = DistTensor("src", (nx, ny), partition=("gx", "gy"), halo=(1, 2),
                     boundary=boundary, boundary_constant=3.5)
    dst = DistTensor("dst", (nx, ny), partition=("gx", "gy"))
    outs = {}
    for overlap in (False, True):
        g = Graph()
        g.split(sten, concurrent_padded_access(src), dst, overlap=overlap)
        ex = Executor(g, mesh=mesh)
        outs[overlap] = np.asarray(ex(ex.init_state(src=x0))["dst"])
        ht = ex.plan.transfers_for_segment(0)
        assert any(h.mesh_axis == "gx" for h in ht)
        assert any(h.mesh_axis == "gy" for h in ht)
        assert any(len(h.block) == 2 for h in ht)  # corners scheduled
        assert all(h.overlapped == overlap for h in ht)
        assert not ex.plan.overlap_fallbacks
    ref_in = pad_boundary_only(x0, axis=0, width=1, boundary=boundary,
                               constant=3.5)
    ref_in = pad_boundary_only(ref_in, axis=1, width=2, boundary=boundary,
                               constant=3.5)
    ref = np.asarray(sten(ref_in, None))
    np.testing.assert_allclose(outs[False], ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[True], ref, rtol=1e-5, atol=1e-5)
print("OK")
""")


@pytest.mark.slow
def test_euler_2d_overlap_matches_sync():
    """The flagship finite-volume path 2-D-partitioned on 8 devices:
    dimension-split AND unsplit Euler steps with overlap=True produce the
    same values as the synchronous lowering, and the plan reports the
    scheduled transfers."""
    run_subprocess_devices("""
import sys, os, jax, jax.numpy as jnp, numpy as np, repro
src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(src_dir), "examples"))
from euler2d import build_solver
from repro.physics.euler import shock_bubble_init

nx, ny = 64, 32
U0 = shock_bubble_init(nx, ny)
for unsplit in (False, True):
    outs = {}
    for overlap in (False, True):
        ex, u = build_solver(nx, ny, n_devices=8, px=2, overlap=overlap,
                             unsplit=unsplit)
        state = ex.init_state(u=U0)
        state = ex.run(state, steps=5)
        outs[overlap] = np.asarray(state["u"])
        if overlap:
            ht = ex.plan.halo_transfers
            assert any(h.overlapped and h.mesh_axis == "gx" for h in ht)
            assert any(h.overlapped and h.mesh_axis == "gy" for h in ht)
            if unsplit:  # one node spans both axes -> corner blocks
                assert any(h.overlapped and len(h.block) == 2 for h in ht)
            assert not ex.plan.overlap_fallbacks
    np.testing.assert_allclose(outs[True], outs[False], rtol=1e-5,
                               atol=1e-6)
    print("unsplit" if unsplit else "split", "overlap == sync")
print("OK")
""")


@pytest.mark.slow
def test_kernel_graphs_2d_overlap():
    """The stencil (FORCE flux) and eikonal kernel graph builders run
    2-D-partitioned with overlap and match their synchronous lowering."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (Boundary, DistTensor, Executor, Layout, RecordArray,
                        make_mesh, pad_boundary_only)
from repro.kernels.stencil.ops import make_flux_difference_graph
from repro.kernels.eikonal.ops import make_eikonal_graph
from repro.physics.euler import EULER_SPEC, shock_bubble_init

mesh = make_mesh((2, 4), ("gx", "gy"))
nx, ny = 32, 16

# FORCE flux difference over a 2-D-partitioned Euler record
u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
               partition=("gx", "gy"), halo=(1, 1),
               boundary=Boundary.TRANSMISSIVE)
out = DistTensor("du", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                 partition=("gx", "gy"))
U0 = shock_bubble_init(nx, ny)
res = {}
for overlap in (False, True):
    g = make_flux_difference_graph(u, out, 0.1, 0.2, overlap=overlap)
    ex = Executor(g, mesh=mesh)
    st = ex(ex.init_state(u=U0))
    res[overlap] = np.asarray(st["du"])
    if overlap:
        assert not ex.plan.overlap_fallbacks
        assert any(h.overlapped and len(h.block) == 2
                   for h in ex.plan.halo_transfers)
np.testing.assert_allclose(res[True], res[False], rtol=1e-5, atol=1e-6)

# eikonal FIM sweep: phi updated in place, unpadded mask sliced per strip
phi0 = jnp.full((nx, ny), 10.0).at[nx // 2, ny // 2].set(0.0)
mask0 = jnp.zeros((nx, ny), bool).at[nx // 2, ny // 2].set(True)
phi = DistTensor("phi", (nx, ny), partition=("gx", "gy"), halo=(1, 1))
mask = DistTensor("mask", (nx, ny), dtype=jnp.bool_,
                  partition=("gx", "gy"))
res = {}
for overlap in (False, True):
    g = make_eikonal_graph(phi, mask, 1.0 / nx, overlap=overlap)
    ex = Executor(g, mesh=mesh)
    st = ex.init_state(phi=phi0, mask=mask0)
    st = ex.run(st, steps=6)
    res[overlap] = np.asarray(st["phi"])
    if overlap:
        assert not ex.plan.overlap_fallbacks
np.testing.assert_allclose(res[True], res[False], rtol=1e-5, atol=1e-6)
# the sweeps actually propagated the front off the source shard
assert (res[True] < 10.0).mean() > 0.1
print("OK")
""")


@pytest.mark.slow
def test_overlap_small_shard_warns_and_falls_back():
    """Shards too thin for boundary strips: overlap degrades to the
    synchronous path with a warning + plan record, same values."""
    run_subprocess_devices("""
import warnings
import jax, jax.numpy as jnp, numpy as np
from repro.core import (DistTensor, Graph, Executor, Boundary,
                        concurrent_padded_access, make_mesh)

mesh = make_mesh((8,), ("gx",))
size = 16  # shard extent 2 == 2 * halo -> no interior left
src = DistTensor("src", (size,), partition=("gx",), halo=(1,))
dst = DistTensor("dst", (size,), partition=("gx",))
outs = {}
for overlap in (False, True):
    g = Graph()
    g.split(lambda s, d: s[2:] - s[:-2], concurrent_padded_access(src), dst,
            overlap=overlap)
    if overlap:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ex = Executor(g, mesh=mesh)
        assert any("falls back to synchronous" in str(x.message) for x in w)
        assert len(ex.plan.overlap_fallbacks) == 1
        assert "shard extent" in ex.plan.overlap_fallbacks[0].reason
    else:
        ex = Executor(g, mesh=mesh)
    x0 = jnp.arange(size, dtype=jnp.float32) ** 2
    outs[overlap] = np.asarray(ex(ex.init_state(src=x0))["dst"])
np.testing.assert_allclose(outs[True], outs[False])
print("OK")
""")


@pytest.mark.slow
def test_sharded_train_matches_unsharded():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
import repro.configs as C
from repro.launch.steps import make_train_step, input_specs, param_specs
from repro.launch.mesh import make_mesh
from repro.models.lm import init_lm
from repro.models.config import ShapeCfg

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ("qwen3_8b", "phi3_5_moe", "recurrentgemma_9b"):
    cfg = C.get_smoke(arch)
    step_fn, opt = make_train_step(cfg, mesh)
    p_sds, _ = param_specs(cfg, mesh)
    params = init_lm(cfg, jax.random.PRNGKey(0), tp=2)[0]
    params = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                          params, p_sds)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    shape = ShapeCfg("t", "train", 32, 8)
    bspecs = input_specs(cfg, shape, mesh)
    rng = np.random.default_rng(0)
    batch = {}
    for k, sd in bspecs.items():
        arr = (rng.integers(0, cfg.vocab_size, sd.shape).astype(np.int32)
               if k in ("tokens", "labels")
               else rng.standard_normal(sd.shape).astype(np.float32))
        batch[k] = jax.device_put(arr, sd.sharding)
    _, m = jax.jit(step_fn)(state, batch)

    step1, opt1 = make_train_step(cfg, None)
    params1 = init_lm(cfg, jax.random.PRNGKey(0), tp=2)[0]
    state1 = {"params": params1, "opt": opt1.init(params1),
              "step": jnp.zeros((), jnp.int32)}
    batch1 = {k: jnp.asarray(np.asarray(v)) for k, v in batch.items()}
    _, m1 = jax.jit(step1)(state1, batch1)
    d = abs(float(m["loss"]) - float(m1["loss"]))
    assert d < 5e-3, (arch, d)
    print(arch, "ok", d)
print("OK")
""", timeout=1800)


@pytest.mark.slow
def test_sharded_flash_decode_matches_local():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models.attention import decode_attention, make_sharded_decode_attention
mesh = make_mesh((2, 4), ("data", "model"))
B, S, H, Hkv, D = 4, 64, 8, 2, 16
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32)) * 0.3
kc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32)) * 0.3
vc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32)) * 0.3
clen = jnp.asarray([50, 33, 64, 7], dtype=jnp.int32)
fn = make_sharded_decode_attention(mesh, batch_axes=("data",),
                                   seq_axes=("model",), heads_tp=True)
out = jax.jit(fn)(
    jax.device_put(q, NamedSharding(mesh, P("data", "model", None))),
    jax.device_put(kc, NamedSharding(mesh, P("data", "model", None, None))),
    jax.device_put(vc, NamedSharding(mesh, P("data", "model", None, None))),
    jax.device_put(clen, NamedSharding(mesh, P("data"))))
ref = decode_attention(q, kc, vc, clen)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-4, atol=2e-5)
print("OK")
""")


@pytest.mark.slow
def test_moe_a2a_matches_local_dispatch():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models.common import ParamTree
from repro.models.moe import init_moe, make_moe_a2a, moe_block
mesh = make_mesh((4, 2), ("data", "model"))
E, d, f, T = 8, 16, 32, 64
pt = ParamTree(jax.random.PRNGKey(0))
init_moe(pt, d_model=d, d_ff=f, n_experts=E, name="moe")
p = pt.params["moe"]
x = jnp.asarray(np.random.default_rng(0).standard_normal((T, d))
                .astype(np.float32)) * 0.5
fn = make_moe_a2a(mesh, dp_axes=("data",), top_k=2, capacity_factor=8.0,
                  residual_tp=False)
ps = {"router": jax.device_put(p["router"], NamedSharding(mesh, P(None, None))),
      "wi": jax.device_put(p["wi"], NamedSharding(mesh, P("data", None, None, "model"))),
      "wo": jax.device_put(p["wo"], NamedSharding(mesh, P("data", "model", None)))}
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
out, aux = jax.jit(fn)(ps, xs)
# generous capacity on both sides -> dropless -> exact match
ref, aux_ref = moe_block(p, x, top_k=2, capacity_factor=8.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-3, atol=2e-4)
# aux is the mean of per-shard estimates (GShard convention) — close to
# but not identical with the global estimate
assert abs(float(aux) - float(aux_ref)) < 0.25
print("OK")
""", timeout=1200)


@pytest.mark.slow
def test_compressed_psum_error_feedback():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.optim.compression import ErrorFeedbackState, compressed_psum
mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
gs = rng.standard_normal((8, 64)).astype(np.float32)
true_mean = gs.mean(axis=0)

def run_step(g_all, resid):
    def f(g, r):
        out, ef = compressed_psum({"g": g}, "data",
                                  ef=ErrorFeedbackState({"g": r}))
        return out["g"], ef.residual["g"]
    return jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P("data", None), P("data", None)),
        out_specs=(P(None, None), P("data", None)), check_vma=False))(
        g_all, resid)

resid = jnp.zeros((8, 64), jnp.float32)
total = np.zeros((1, 64), np.float32)
n = 30
for _ in range(n):
    mean, resid = run_step(jnp.asarray(gs), resid)
    total += np.asarray(mean)
# error feedback: time-averaged compressed mean converges to true mean
np.testing.assert_allclose(total[0] / n, true_mean, atol=2e-2)
print("OK")
""", timeout=1200)


@pytest.mark.slow
def test_seqpar_halo_and_carry():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models.ssm import seqpar_conv_halo, seqpar_scan_carry
mesh = make_mesh((4,), ("sp",))
B, S, C = 2, 32, 4
x = jnp.asarray(np.random.default_rng(0).standard_normal((B, S, C))
                .astype(np.float32))

def f(x_l):
    halo = seqpar_conv_halo(x_l, width=3, axis_name="sp")
    return jnp.concatenate([halo, x_l], axis=1)

out = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P(None, "sp", None),),
              out_specs=P(None, "sp", None), check_vma=False))(x)
# each shard's first 3 entries = previous shard's last 3 (zeros for shard 0)
out = np.asarray(out).reshape(B, 4, 8 + 3, C)
ref = np.asarray(x).reshape(B, 4, 8, C)
np.testing.assert_allclose(out[:, 0, :3], 0.0)
for i in range(1, 4):
    np.testing.assert_allclose(out[:, i, :3], ref[:, i - 1, -3:])

# linear recurrence carry: h_t = a h_{t-1} + b with constant a per shard
a = jnp.asarray(np.random.default_rng(1).uniform(0.5, 0.9, (B, S, C))
                .astype(np.float32))
b = jnp.asarray(np.random.default_rng(2).standard_normal((B, S, C))
                .astype(np.float32))

def local_scan(a_l, b_l):
    def step(h, inp):
        ai, bi = inp
        h = ai * h + bi
        return h, h
    h_last, _ = jax.lax.scan(step, jnp.zeros((B, C)),
                             (jnp.moveaxis(a_l, 1, 0), jnp.moveaxis(b_l, 1, 0)))
    return h_last

def f2(a_l, b_l):
    h_local = local_scan(a_l, b_l)
    a_total = jnp.prod(a_l, axis=1)
    incoming = seqpar_scan_carry(a_total, h_local, axis_name="sp")
    # true last state of this shard given incoming carry
    return (incoming * a_total + h_local)[:, None]

out = jax.jit(jax.shard_map(f2, mesh=mesh,
                            in_specs=(P(None, "sp", None),) * 2,
                            out_specs=P(None, "sp", None),
                            check_vma=False))(a, b)
# reference: global sequential scan, take last state of each shard
h = np.zeros((B, C), np.float32)
refs = []
for t in range(S):
    h = np.asarray(a)[:, t] * h + np.asarray(b)[:, t]
    if (t + 1) % 8 == 0:
        refs.append(h.copy())
ref = np.stack(refs, axis=1)  # (B, 4, C): last state of each shard
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)
print("OK")
""", timeout=1200)


@pytest.mark.slow
def test_fsdp_train_matches_unsharded():
    run_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
import repro.configs as C
from repro.launch.steps import make_train_step, input_specs, param_specs
from repro.launch.mesh import make_mesh
from repro.models.lm import init_lm
from repro.models.config import ShapeCfg

mesh = make_mesh((2, 4), ("data", "model"))
cfg = C.get_smoke("qwen3_8b").with_(train_sharding="fsdp")
step_fn, opt = make_train_step(cfg, mesh)
p_sds, _ = param_specs(cfg, mesh)
params = init_lm(cfg, jax.random.PRNGKey(0), tp=1)[0]
params = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                      params, p_sds)
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
shape = ShapeCfg("t", "train", 32, 8)
bspecs = input_specs(cfg, shape, mesh)
rng = np.random.default_rng(0)
batch = {k: jax.device_put(
    rng.integers(0, cfg.vocab_size, sd.shape).astype(np.int32), sd.sharding)
    for k, sd in bspecs.items()}
_, m = jax.jit(step_fn)(state, batch)

cfg1 = cfg.with_(train_sharding="tp")
step1, opt1 = make_train_step(cfg1, None)
params1 = init_lm(cfg1, jax.random.PRNGKey(0), tp=1)[0]
state1 = {"params": params1, "opt": opt1.init(params1),
          "step": jnp.zeros((), jnp.int32)}
batch1 = {k: jnp.asarray(np.asarray(v)) for k, v in batch.items()}
_, m1 = jax.jit(step1)(state1, batch1)
d = abs(float(m["loss"]) - float(m1["loss"]))
assert d < 5e-3, d
print("OK", d)
""", timeout=1800)
