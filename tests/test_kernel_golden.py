"""Golden kernel-vs-ref matrix: every kernel package in
``src/repro/kernels/*`` against its pure-jnp ``ref.py`` oracle over the
full layout (AoS / SoA / AoSoA, for record kernels) × dtype (f32 / bf16)
grid.  The per-kernel suites in test_kernels.py spot-check shapes and
single combinations; this module owns the exhaustive grid so no layout or
dtype column is silently untested."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Boundary, Layout, RecordArray, pad_boundary_only,
                        relayout)

LAYOUTS = [Layout.AOS, Layout.SOA, Layout.AOSOA]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype, f32=1e-5, bf16=2e-2):
    return f32 if dtype == jnp.float32 else bf16


def _assert_close(out, ref, tol):
    o = out.data if isinstance(out, RecordArray) else out
    r = ref.data if isinstance(ref, RecordArray) else ref
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


# -- saxpy (flat + record) ----------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_saxpy(rng, dtype):
    from repro.kernels.saxpy.ops import saxpy
    from repro.kernels.saxpy.ref import saxpy_ref
    x = jnp.asarray(rng.standard_normal(2048), dtype)
    y = jnp.asarray(rng.standard_normal(2048), dtype)
    _assert_close(saxpy(1.75, x, y), saxpy_ref(1.75, x, y), _tol(dtype))


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_saxpy_record(rng, layout, dtype):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record
    from repro.kernels.saxpy.ref import saxpy_record_ref
    rec = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(rng.standard_normal(1024), dtype),
         "y": jnp.asarray(rng.standard_normal(1024), dtype)},
        layout)
    out = saxpy_record(rec, 2.5, block=1024)
    assert out.layout is layout and out.dtype == dtype
    _assert_close(out, saxpy_record_ref(rec, 2.5), _tol(dtype))


# -- particle ------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_particle(rng, layout, dtype):
    from repro.kernels.particle.ops import (PARTICLE_SPEC, particle_update,
                                            particle_update_ref)
    rec = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(rng.standard_normal((512, 3)), dtype),
         "v": jnp.asarray(rng.standard_normal((512, 3)), dtype)},
        layout)
    out = particle_update(rec, 0.25, block=256)
    assert out.layout is layout and out.dtype == dtype
    _assert_close(out, particle_update_ref(rec, 0.25), _tol(dtype))


# -- stencil (FORCE flux) ------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_flux(layout, dtype):
    from repro.kernels.stencil.ops import flux_difference, flux_difference_ref
    from repro.physics.euler import EULER_SPEC, shock_bubble_init
    d = shock_bubble_init(32, 16).astype(dtype)
    for ax in (1, 2):
        d = pad_boundary_only(d, axis=ax, width=1,
                              boundary=Boundary.TRANSMISSIVE)
    hal = relayout(RecordArray(d, EULER_SPEC, Layout.SOA), layout)
    out = flux_difference(hal, 0.1, 0.1)
    assert out.layout is layout and out.dtype == dtype
    _assert_close(out, flux_difference_ref(hal, 0.1, 0.1),
                  _tol(dtype, f32=1e-4))


# -- eikonal (scalar field: no layout axis) ------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_eikonal(dtype):
    from repro.kernels.eikonal.ops import eikonal_fim_ref, eikonal_fim_sweep
    n = 32
    phi = jnp.full((n, n), 1e3, dtype)
    src = jnp.zeros((n, n), bool).at[n // 2, n // 2].set(True)
    phi = jnp.where(src, jnp.zeros((), dtype), phi)
    ph = pad_boundary_only(pad_boundary_only(phi, axis=0, width=1),
                           axis=1, width=1)
    out = eikonal_fim_sweep(ph, src, 1.0 / n)
    assert out.dtype == dtype
    _assert_close(out, eikonal_fim_ref(ph, src, 1.0 / n),
                  _tol(dtype, bf16=5e-2))


# -- attention -----------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_flash_attention(rng, dtype):
    from repro.kernels.attention.ops import flash_attention, mha_ref
    b, h, hkv, s, d = 2, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)) * 0.3, dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, dtype)
    out = flash_attention(q, k, v, causal=True)
    assert out.dtype == dtype
    _assert_close(out, mha_ref(q, k, v, causal=True),
                  _tol(dtype, f32=2e-3))


# -- ssd -----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_golden_ssd(rng, dtype):
    from repro.kernels.ssd.ops import ssd, ssd_naive
    b, s, h, dh, ds = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, dh)) * 0.3, dtype)
    dt = jnp.asarray(
        np.log1p(np.exp(rng.standard_normal((b, s, h)))), dtype)
    A = -jnp.exp(jnp.asarray(rng.standard_normal(h), jnp.float32))
    B = jnp.asarray(rng.standard_normal((b, s, ds)) * 0.3, dtype)
    C = jnp.asarray(rng.standard_normal((b, s, ds)) * 0.3, dtype)
    D = jnp.asarray(rng.standard_normal(h), jnp.float32)
    y, st = ssd(x, dt, A, B, C, D, chunk=32)
    y_ref, st_ref = ssd_naive(x, dt, A, B, C, D)
    assert y.dtype == dtype
    _assert_close(y, y_ref, _tol(dtype, f32=2e-3))
    _assert_close(st, st_ref, _tol(dtype, f32=2e-3, bf16=2e-2))
