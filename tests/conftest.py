"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see
the single real CPU device; multi-device behaviour is tested via
subprocesses in test_distributed.py (jax locks device count on first use).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# hypothesis is a dev dependency (requirements-dev.txt); on bare containers
# fall back to the deterministic stub so collection never hard-errors
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    _hypothesis_stub.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_subprocess_devices(code: str, n_devices: int = 8,
                           timeout: int = 900) -> str:
    """Run python code in a fresh process with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
