"""Multi-axis halo transfer schedule + overlap plan introspection
(single-device semantics; the 8-device corner/overlap equivalence runs in
test_distributed.py subprocesses)."""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Boundary, DistTensor, Executor, Graph,
                        concurrent_padded_access, make_mesh,
                        pad_boundary_only)
from repro.core.halo import (HaloAxis, assemble_region, exchange_blocks,
                             exchange_multi, iter_block_keys)


# -- transfer schedule (fill-only axes run anywhere) ---------------------------

@pytest.mark.parametrize("boundary", list(Boundary))
def test_exchange_multi_matches_chained_pads(boundary):
    x = jnp.arange(20.0).reshape(4, 5)
    axes = [HaloAxis(0, 2, None), HaloAxis(1, 1, None)]
    got = exchange_multi(x, axes, boundary=boundary, constant=7.0)
    ref = pad_boundary_only(x, axis=0, width=2, boundary=boundary,
                            constant=7.0)
    ref = pad_boundary_only(ref, axis=1, width=1, boundary=boundary,
                            constant=7.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


def test_exchange_multi_three_axes():
    x = jnp.arange(24.0).reshape(2, 3, 4)
    axes = [HaloAxis(0, 1, None), HaloAxis(1, 1, None), HaloAxis(2, 2, None)]
    got = exchange_multi(x, axes, boundary=Boundary.LINEAR)
    ref = x
    for a in axes:
        ref = pad_boundary_only(ref, axis=a.axis, width=a.width,
                                boundary=Boundary.LINEAR)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


def test_assemble_region_is_slice_of_full():
    x = jnp.arange(20.0).reshape(4, 5)
    axes = [HaloAxis(0, 1, None), HaloAxis(1, 2, None)]
    blocks = exchange_blocks(x, axes, boundary=Boundary.LINEAR)
    full = assemble_region(blocks, axes, [(0, 6), (0, 9)])
    for ranges in ([(0, 3), (2, 9)], [(1, 5), (0, 4)], [(5, 6), (7, 9)],
                   [(1, 5), (2, 7)]):
        sub = assemble_region(blocks, axes, ranges)
        (r0, r1) = ranges
        np.testing.assert_allclose(
            np.asarray(sub), np.asarray(full)[r0[0]:r0[1], r1[0]:r1[1]])


def test_iter_block_keys_phase_structure():
    axes2 = [HaloAxis(0, 1, None), HaloAxis(1, 1, None)]
    keys2 = list(iter_block_keys(axes2))
    assert len(keys2) == 8  # 4 edge strips + 4 corners
    assert sorted({p for p, _ in keys2}) == [1, 2]
    assert all(len(k) == p for p, k in keys2)  # phase == corner order

    axes3 = [HaloAxis(0, 1, None), HaloAxis(1, 1, None), HaloAxis(2, 1, None)]
    assert len(list(iter_block_keys(axes3))) == 3 ** 3 - 1

    # zero-width axes contribute no blocks but keep key indices aligned
    axes_gap = [HaloAxis(0, 1, None), HaloAxis(1, 0, None),
                HaloAxis(2, 1, None)]
    keys = list(iter_block_keys(axes_gap))
    assert len(keys) == 8
    assert all(j != 1 for _, k in keys for j, _ in k)


# -- plan introspection --------------------------------------------------------

def _stencil_graph(overlap, halo=(1, 1), size=(8, 6), partition=()):
    src = DistTensor("src", size, partition=partition, halo=halo)
    dst = DistTensor("dst", size, partition=partition)

    def sten(s, d):
        n0, n1 = s.shape[0] - 2 * halo[0], s.shape[1] - 2 * halo[1]
        return s[2 * halo[0]:, 2 * halo[1]:][:n0, :n1]

    g = Graph()
    g.split(sten, concurrent_padded_access(src), dst, overlap=overlap)
    return g


def test_plan_lists_scheduled_transfers_per_segment():
    ex = Executor(_stencil_graph(overlap=False))
    ht = ex.plan.transfers_for_segment(0)
    # 2 haloed dims, no mesh -> 4 fill strips + 4 fill corners
    assert len(ht) == 8
    assert all(h.mesh_axis is None and not h.overlapped for h in ht)
    assert {h.phase for h in ht} == {1, 2}
    assert {h.block for h in ht if h.phase == 2} == {
        ((0, "low"), (1, "low")), ((0, "low"), (1, "high")),
        ((0, "high"), (1, "low")), ((0, "high"), (1, "high"))}
    assert "fill" in ht[0].describe()
    assert ex.plan.describe_transfers().count("\n") >= 7


def test_overlap_fallback_recorded_without_mesh():
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # benign fallback must NOT warn
        ex = Executor(_stencil_graph(overlap=True))
    fb = ex.plan.overlap_fallbacks
    assert len(fb) == 1
    assert fb[0].segment == 0
    assert "no mesh" in fb[0].reason


def test_overlap_fallback_single_shard_mesh_is_silent():
    mesh = make_mesh((1,), ("gx",))
    g = _stencil_graph(overlap=True, partition=("gx", None))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ex = Executor(g, mesh=mesh)
    fb = ex.plan.overlap_fallbacks
    assert len(fb) == 1
    assert "no mesh-partitioned halo axis" in fb[0].reason


def test_overlap_fallback_no_padded_arg_warns_once():
    mesh = make_mesh((1,), ("gx",))
    x = DistTensor("x", (8,), partition=("gx",))
    g = Graph()
    g.split(lambda xs: xs + 1.0, x, writes=(0,), overlap=True)
    with pytest.warns(RuntimeWarning, match="falls back to synchronous"):
        ex = Executor(g, mesh=mesh)
    assert len(ex.plan.overlap_fallbacks) == 1
    assert "no padded-access" in ex.plan.overlap_fallbacks[0].reason
    # warn ONCE: re-lowering the same node (e.g. a rebuilt executor) is quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ex2 = Executor(g, mesh=mesh)
    assert len(ex2.plan.overlap_fallbacks) == 1


def test_overlap_fallback_still_computes_correctly():
    """A declined overlap request lowers through the synchronous path and
    produces the same values as overlap=False."""
    outs = {}
    for overlap in (False, True):
        g = _stencil_graph(overlap=overlap)
        ex = Executor(g)
        x0 = jnp.arange(48.0).reshape(8, 6)
        st = ex.init_state(src=x0)
        outs[overlap] = np.asarray(ex(st)["dst"])
    np.testing.assert_allclose(outs[True], outs[False])
