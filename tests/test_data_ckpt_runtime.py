"""Data pipeline, checkpoint store, and fault-tolerant supervisor."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import MemmapCorpus, Prefetcher, SyntheticLM
from repro.runtime import Supervisor, TransientError


# -- data -----------------------------------------------------------------------

def test_synthetic_deterministic():
    a = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4)
    b = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4)
    x, y = a.batch_at(7), b.batch_at(7)
    np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert (x["tokens"] != a.batch_at(8)["tokens"]).any()
    # labels are next-token shifted
    full_a = a.batch_at(3)
    np.testing.assert_array_equal(full_a["labels"][:, :-1],
                                  full_a["tokens"][:, 1:])


def test_synthetic_shards_disjoint_and_cover():
    full = SyntheticLM(vocab_size=50, seq_len=4, global_batch=8)
    s0 = SyntheticLM(vocab_size=50, seq_len=4, global_batch=8,
                     shard=0, num_shards=2)
    s1 = SyntheticLM(vocab_size=50, seq_len=4, global_batch=8,
                     shard=1, num_shards=2)
    f = full.batch_at(5)["tokens"]
    np.testing.assert_array_equal(np.concatenate(
        [s0.batch_at(5)["tokens"], s1.batch_at(5)["tokens"]]), f)


def test_memmap_corpus(tmp_path):
    toks = np.arange(1000, dtype=np.uint16)
    path = tmp_path / "corpus.bin"
    toks.tofile(path)
    c = MemmapCorpus(str(path), seq_len=10, global_batch=2)
    b = c.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(10))
    np.testing.assert_array_equal(b["labels"][0], np.arange(1, 11))
    b2 = c.batch_at(1)
    assert (b2["tokens"] != b["tokens"]).any()


def test_prefetcher():
    src = SyntheticLM(vocab_size=100, seq_len=8, global_batch=2)
    pf = Prefetcher(src, start_step=3, depth=2)
    try:
        s, batch = pf.next()
        assert s == 3
        np.testing.assert_array_equal(batch["tokens"],
                                      src.batch_at(3)["tokens"])
        s, _ = pf.next()
        assert s == 4
    finally:
        pf.close()


# -- checkpoint -------------------------------------------------------------------

def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 7, _state(2.5), extra={"step": 7})
    step, restored, extra = load_checkpoint(d, _state(0.0))
    assert step == 7 and extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 2.5))


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _state(float(s)))
    mgr.wait()
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert steps == [30, 40]
    step, restored, _ = mgr.restore_latest(_state(0.0))
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 40.0))


def test_checkpoint_atomic_tmp_ignored(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 5, _state(5.0))
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated crash
    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, _state())
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros(3)},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        load_checkpoint(d, bad)


# -- supervisor --------------------------------------------------------------------

def test_supervisor_runs_and_checkpoints(tmp_path):
    calls = []

    def step_fn(state, batch):
        calls.append(batch)
        return {"x": state["x"] + batch}

    sup = Supervisor(step_fn=step_fn,
                     ckpt=CheckpointManager(str(tmp_path / "ck")),
                     ckpt_every=5, log=lambda *_: None)
    state = sup.run({"x": jnp.zeros(())}, lambda i: jnp.asarray(1.0),
                    start_step=0, num_steps=12)
    assert float(state["x"]) == 12.0
    assert sup.ckpt.latest_step() == 10


def test_supervisor_recovers_from_transient_failure(tmp_path):
    """Fail at step 7 twice; supervisor restores from the step-5 checkpoint
    and replays — the final state must equal the failure-free run."""
    fail_at = {"n": 2}

    def step_fn(state, batch):
        step = int(state["step"])
        if step == 7 and fail_at["n"] > 0:
            fail_at["n"] -= 1
            raise TransientError("simulated preemption")
        return {"x": state["x"] + batch, "step": state["step"] + 1}

    sup = Supervisor(step_fn=step_fn,
                     ckpt=CheckpointManager(str(tmp_path / "ck")),
                     ckpt_every=5, log=lambda *_: None)
    state = sup.run({"x": jnp.zeros(()), "step": jnp.asarray(0, jnp.int32)},
                    lambda i: jnp.asarray(1.0), start_step=0, num_steps=12)
    assert float(state["x"]) == 12.0
    assert sup.failures == 2


def test_supervisor_gives_up_on_persistent_failure(tmp_path):
    def step_fn(state, batch):
        raise TransientError("hard down")

    sup = Supervisor(step_fn=step_fn,
                     ckpt=CheckpointManager(str(tmp_path / "ck")),
                     max_retries_per_step=2, log=lambda *_: None)
    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, lambda i: 1.0, 0, 5)


def test_straggler_detection():
    stats_holder = []

    def step_fn(state, batch):
        time.sleep(0.05 if batch else 0.001)
        return state

    sup = Supervisor(step_fn=step_fn, ckpt=CheckpointManager("/tmp/_ck_x"),
                     ckpt_every=10**9, straggler_zscore=2.0,
                     log=lambda *_: None)
    sup.run({}, lambda i: i == 18, start_step=0, num_steps=20)
    assert any(s == 18 for s, _ in sup.stats.stragglers), \
        sup.stats.stragglers
