"""Random small Ripple graphs of saxpy / stencil / reduce nodes.

Shared by the in-process property tests (tests/test_schedule_dag.py) and
the multi-device subprocess equivalence tests — no pytest imports here so
the subprocess children can import it with a bare ``sys.path`` insert.

The generator is deterministic per seed: the same (seed, layout,
partition) always builds the same graph and the same initial state, so a
failure reproduces exactly.
"""

from __future__ import annotations

import random

import jax.numpy as jnp
import numpy as np

from repro.core import (Boundary, DistTensor, ExecutionKind, Graph, Layout,
                        MaxReducer, RecordArray, RecordSpec, SumReducer,
                        concurrent_padded_access, make_reduction_result)
from repro.tuning.tiles import register_tile_kernel, resolve_tile

SPEC = RecordSpec.create("x", "y")
NX, NY = 16, 12
N_SCALARS = 3

# the generated graphs' tunable tile site (tile_sites=True): a record
# saxpy blocked over the leading space dim.  Every candidate divides NX,
# and the op is elementwise after a reshape-into-blocks, so results are
# bitwise identical across block sizes — the tuner conformance tests
# rely on exactly that.
register_tile_kernel(
    "genrec", lambda shape: tuple(b for b in (2, 4, 8, 16)
                                  if shape[0] % b == 0))


def _tiled_rec(cc):
    def fn(r):
        b = resolve_tile("genrec", None, NX, shape=(NX,))
        x, y = r.field("x"), r.field("y")
        xb = x.reshape((x.shape[0] // b, b) + x.shape[1:])
        yb = y.reshape((y.shape[0] // b, b) + y.shape[1:])
        return r.set_field("y", (cc * xb + yb).reshape(y.shape))
    return fn


def _host_noop(x):
    """Host-callback body for generated graphs: a REAL host-side read
    (numpy materialization) with no side effects, so injecting it can
    never change values — only scheduling.  Module-level so every graph
    built from the same seed has an identical plan signature."""
    np.asarray(x)


def make_tensors(layout: Layout, partition=()):
    scalars = [
        DistTensor(f"t{i}", (NX, NY), partition=partition, halo=(1, 1),
                   boundary=Boundary.TRANSMISSIVE)
        for i in range(N_SCALARS)
    ]
    rec = DistTensor("r", (NX, NY), spec=SPEC, layout=layout,
                     partition=partition)
    return scalars, rec


def _stencil(s, _d):
    # (m+2, n+2) -> (m, n) five-point combination (shape-polymorphic)
    return (s[2:, 1:-1] + s[:-2, 1:-1] + s[1:-1, 2:] + s[1:-1, :-2]
            - 3.5 * s[1:-1, 1:-1])


def build_random_graph(seed: int, layout: Layout, partition=(), *,
                       host_callbacks: bool = False,
                       tile_sites: bool = False):
    """A 2-4 level graph, 1-3 nodes per level, drawn from the pool
    {scalar saxpy, 2-d stencil, reduce, record saxpy, result broadcast}.

    With ``host_callbacks=True`` each level also injects, with 50%
    probability, a side-effect-free host read of a random scalar tensor
    (``exec_kind=Cpu``) — the async-runtime property tests exercise the
    event-driven dispatcher on exactly these graphs.  The extra draws
    happen only when enabled, so ``host_callbacks=False`` graphs are
    bit-identical to what this generator always produced for a seed.

    With ``tile_sites=True`` the record-saxpy nodes route through the
    ``"genrec"`` tunable tile site (same rng draws — the graph structure
    per seed is unchanged; only the node body differs), which gives the
    tuner conformance tests a real tile axis whose block size provably
    cannot change values.

    Returns ``(graph, overrides, state_keys)``: pass ``overrides`` to
    ``Executor.init_state`` (fresh arrays each call — donation-safe) and
    compare the ``state_keys`` entries between schedules.
    """
    rng = random.Random(seed)
    scalars, rec = make_tensors(layout, partition)
    results = []
    g = Graph(name=f"rand{seed}")

    for li in range(rng.randint(2, 4)):
        if li:
            g._new_level()
        if host_callbacks and rng.random() < 0.5:
            g.then(_host_noop, exec_kind=ExecutionKind.Cpu,
                   args=(scalars[rng.randrange(N_SCALARS)],))
        for _ in range(rng.randint(1, 3)):
            kind = rng.choice(
                ["saxpy", "stencil", "reduce", "rec", "result_add"])
            if kind == "saxpy":
                a, b = rng.sample(range(N_SCALARS), 2)
                c = round(rng.uniform(0.5, 2.0), 3)
                g.split((lambda cc: lambda xs, ys: cc * xs + ys)(c),
                        scalars[a], scalars[b])
            elif kind == "stencil":
                a, b = rng.sample(range(N_SCALARS), 2)
                g.split(_stencil, concurrent_padded_access(scalars[a]),
                        scalars[b])
            elif kind == "reduce":
                i = rng.randrange(N_SCALARS)
                res = make_reduction_result(f"res{len(results)}_{seed}")
                results.append(res)
                g.reduce(scalars[i], res,
                         rng.choice([SumReducer(), MaxReducer()]))
            elif kind == "rec":
                c = round(rng.uniform(0.5, 2.0), 3)
                if tile_sites:
                    g.split(_tiled_rec(c), rec, writes=(0,))
                else:
                    g.split((lambda cc: lambda r: r.set_field(
                        "y", cc * r.field("x") + r.field("y")))(c),
                        rec, writes=(0,))
            elif results:  # result_add: broadcast a reduction back in
                res = rng.choice(results)
                i = rng.randrange(N_SCALARS)
                g.split(lambda xs, rv: xs + 0.125 * rv, scalars[i], res)

    def overrides():
        """Fresh arrays every call (executors donate their state)."""
        out = {
            f"t{i}": jnp.asarray(
                np.linspace(0.0, 1.0 + i, NX * NY, dtype=np.float32)
                .reshape(NX, NY))
            for i in range(N_SCALARS)
        }
        out["r"] = RecordArray.from_fields(
            SPEC,
            {"x": jnp.asarray(np.linspace(-1.0, 1.0, NX * NY,
                                          dtype=np.float32).reshape(NX, NY)),
             "y": jnp.asarray(np.full((NX, NY), 0.25, dtype=np.float32))},
            layout)
        return out

    # only tensors the graph actually references get state entries
    keys = sorted(g.all_tensors()) + [r.name for r in results]
    return g, overrides, keys
