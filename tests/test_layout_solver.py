"""Layout solver (core/executor.py): per-segment layout choice, user pins,
kernel hints, and relayout insertion at segment boundaries."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        RecordArray, RecordSpec, Vector,
                        concurrent_padded_access, pad_boundary_only,
                        preferred_layout, relayout)

SPEC = RecordSpec.create(Vector("x", 3), Vector("v", 3))


def _bump(r):
    return r.set_field("x", r.field("x") + 1.0)


def _tensor(**kw):
    return DistTensor("p", (256,), spec=SPEC, **kw)


# -- choice rules -------------------------------------------------------------

def test_solver_defaults_to_declared_layout():
    t = _tensor(layout=Layout.AOS)
    g = Graph()
    g.split(_bump, t, writes=(0,))
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.AOS
    assert ex.plan.relayouts == []


def test_solver_honors_node_hint():
    t = _tensor(layout=Layout.SOA)
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOSOA), writes=(0,))
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.AOSOA
    st = ex.init_state()
    assert st["p"].shape == (2, 6, 128)  # materialized directly in AoSoA


def test_solver_layout_kwarg_on_split():
    t = _tensor(layout=Layout.SOA)
    g = Graph()
    g.split(_bump, t, writes=(0,), layout=Layout.AOS)
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.AOS


def test_user_pin_overrides_hint():
    t = _tensor(layout=Layout.SOA, pin_layout=True)
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOS), writes=(0,))
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.SOA


def test_infeasible_aosoa_pin_raises_at_construction():
    """layout.py's promise: a pin that forces an infeasible AoSoA raises
    at validation time — with or without a mesh."""
    t = _tensor(layout=Layout.AOSOA, pin_layout=True, halo=(1,))
    out = DistTensor("q", (256,), spec=SPEC)
    g = Graph()
    g.split(lambda a, b: b, concurrent_padded_access(t), out)
    with pytest.raises(ValueError, match="pinned AOSOA"):
        Executor(g)


def test_aosoa_hint_clamped_by_last_dim_halo():
    """A halo on the tiled dim is infeasible under AoSoA: clamp to SoA."""
    t = _tensor(layout=Layout.SOA, halo=(1,))
    out = DistTensor("q", (256,), spec=SPEC)
    g = Graph()
    g.split(lambda a, b: b, preferred_layout(
        concurrent_padded_access(t), Layout.AOSOA), out)
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.SOA


# -- relayout insertion -------------------------------------------------------

def test_relayout_inserted_exactly_on_disagreement():
    t = _tensor(layout=Layout.SOA)
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOS), writes=(0,))
    g.sync()
    g.split(_bump, preferred_layout(t, Layout.AOSOA), writes=(0,))
    ex = Executor(g)
    assert len(ex.plan.relayouts) == 1
    step = ex.plan.relayouts[0]
    assert (step.tensor, step.src, step.dst) == ("p", Layout.AOS,
                                                 Layout.AOSOA)
    # values flow through the boundary conversion; outside the call the
    # state is restored to the plan's initial layout (AoS here), keeping
    # state dicts interchangeable between calls
    st = ex.init_state()
    assert st["p"].shape == (256, 6)          # first consumer: AoS
    st = ex(st)
    assert st["p"].shape == (256, 6)          # restored on exit
    rec = ex.read(st, t)
    np.testing.assert_allclose(np.asarray(rec.field("x")), 2.0)


def test_state_dicts_interchangeable_across_reinit():
    """Regression: the executor must not misread a state produced before
    a second init_state() — physical layout is a property of the state's
    position in the plan, which is always 'initial' outside a call."""
    t = _tensor(layout=Layout.SOA)
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOS), writes=(0,))
    g.sync()
    g.split(_bump, preferred_layout(t, Layout.AOSOA), writes=(0,))
    ex = Executor(g)
    st_a = ex.init_state()
    st_a = ex(st_a)
    st_b = ex.init_state()          # resets nothing that st_a depends on
    st_a = ex(st_a)                 # +2 again on the old state
    st_b = ex(st_b)
    np.testing.assert_allclose(np.asarray(ex.read(st_a, t).field("x")), 4.0)
    np.testing.assert_allclose(np.asarray(ex.read(st_b, t).field("x")), 2.0)


def test_raw_override_reingests_executor_state():
    """Regression: init_state(p=<raw array from a previous run>) must
    recognize the solver's (initial) layout by storage shape, not blindly
    assume the declared layout."""
    t = _tensor(layout=Layout.SOA)  # declared SoA, solver will pick AoSoA
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOSOA), writes=(0,))
    ex = Executor(g)
    st = ex(ex.init_state())
    assert st["p"].shape == (2, 6, 128)       # AoSoA outside the call
    st2 = ex(ex.init_state(p=st["p"]))        # raw re-ingestion
    np.testing.assert_allclose(np.asarray(ex.read(st2, t).field("x")), 2.0)
    # an unrecognizable shape is rejected, not silently reinterpreted
    with pytest.raises(ValueError, match="matches no layout"):
        ex.init_state(p=jnp.zeros((7, 7)))


def test_raw_override_ambiguous_shape_rejected():
    """space (6,) with 6 components: AoS and SoA storage are both (6, 6)
    — guessing could scramble data, so a RecordArray is required."""
    spec = RecordSpec.create(Vector("a", 6))
    t = DistTensor("p", (6,), spec=spec, layout=Layout.SOA)
    g = Graph()
    g.split(_bump_a, preferred_layout(t, Layout.AOS), writes=(0,))
    ex = Executor(g)
    with pytest.raises(ValueError, match="ambiguous"):
        ex.init_state(p=jnp.zeros((6, 6)))


def _bump_a(r):
    return r.set_field("a", r.field("a") + 1.0)


def test_aosoa_vetoed_by_haloed_access_handle():
    """Halo widths are access-level: a haloed access on one handle must
    veto AoSoA for the shared storage even if another same-name handle
    (which wins the all_tensors dedup) carries no halo."""
    haloed = _tensor(layout=Layout.SOA, halo=(1,))
    plain = _tensor(layout=Layout.SOA)           # same name, no halo
    out = DistTensor("q", (256,), spec=SPEC)
    g = Graph()
    g.split(lambda a, b: b, preferred_layout(
        concurrent_padded_access(haloed), Layout.AOSOA), out)
    g.split(_bump, plain, writes=(0,))           # dedup keeps this handle
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is Layout.SOA
    ex(ex.init_state())                          # and it actually runs


def test_no_relayout_when_segments_agree():
    t = _tensor(layout=Layout.SOA)
    g = Graph()
    g.split(_bump, preferred_layout(t, Layout.AOS), writes=(0,))
    g.sync()
    g.split(_bump, preferred_layout(t, Layout.AOS), writes=(0,))
    ex = Executor(g)
    assert ex.plan.relayouts == []
    st = ex(ex.init_state())
    np.testing.assert_allclose(np.asarray(ex.read(st, t).field("x")), 2.0)


@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA, Layout.AOSOA])
def test_executor_results_identical_under_pinned_layouts(rng, layout):
    """The same graph produces the same numbers whatever layout the user
    pins — the executor's end of the paper's polymorphism claim."""
    t = _tensor(layout=layout, pin_layout=True)
    x0 = jnp.asarray(rng.standard_normal((256, 3), dtype=np.float32))
    v0 = jnp.asarray(rng.standard_normal((256, 3), dtype=np.float32))

    def step(r):
        return r.set_field("x", r.field("x") + 0.5 * r.field("v"))

    g = Graph()
    g.split(step, t, writes=(0,))
    ex = Executor(g)
    assert ex.plan.per_segment[0]["p"] is layout
    init = RecordArray.from_fields(SPEC, {"x": x0, "v": v0}, layout)
    st = ex(ex.init_state(p=init))
    got = np.asarray(ex.read(st, t).field("x"))
    np.testing.assert_allclose(got, np.asarray(x0 + 0.5 * v0), rtol=1e-6,
                               atol=1e-6)


# -- acceptance: kernels identical under all three layouts --------------------

LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)


def _assert_layouts_agree(outs, tol=0.0):
    base = outs[Layout.SOA]
    for lay, got in outs.items():
        if tol:
            np.testing.assert_allclose(got, base, rtol=tol, atol=tol,
                                       err_msg=str(lay))
        else:
            np.testing.assert_array_equal(got, base, err_msg=str(lay))


def test_saxpy_record_identical_under_all_layouts(rng):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record
    fields = {"x": jnp.asarray(rng.standard_normal(2048, dtype=np.float32)),
              "y": jnp.asarray(rng.standard_normal(2048, dtype=np.float32))}
    outs = {lay: np.asarray(saxpy_record(
        RecordArray.from_fields(SAXPY_SPEC, fields, lay), 2.5).field("y"))
        for lay in LAYOUTS}
    _assert_layouts_agree(outs)


def test_particle_identical_under_all_layouts(rng):
    from repro.kernels.particle.ops import PARTICLE_SPEC, particle_update
    fields = {
        "x": jnp.asarray(rng.standard_normal((1024, 3), dtype=np.float32)),
        "v": jnp.asarray(rng.standard_normal((1024, 3), dtype=np.float32))}
    outs = {lay: np.asarray(particle_update(
        RecordArray.from_fields(PARTICLE_SPEC, fields, lay), 0.25).field("x"))
        for lay in LAYOUTS}
    _assert_layouts_agree(outs)


def test_flux_identical_under_all_layouts():
    from repro.kernels.stencil.ops import flux_difference
    from repro.physics.euler import EULER_SPEC, shock_bubble_init
    d = shock_bubble_init(32, 16)
    for ax in (1, 2):
        d = pad_boundary_only(d, axis=ax, width=1,
                              boundary=Boundary.TRANSMISSIVE)
    soa = RecordArray(d, EULER_SPEC, Layout.SOA)
    outs = {}
    for lay in LAYOUTS:
        out = flux_difference(relayout(soa, lay), 0.1, 0.1)
        outs[lay] = np.asarray(out.field("rho"))
    _assert_layouts_agree(outs, tol=1e-5)
