"""Euler 2D (paper §8 application) integration: graph-driven solver is
stable, conserves mass exactly in the periodic case, and matches the
direct (non-graph) implementation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        MaxReducer, RecordArray, concurrent_padded_access,
                        make_reduction_result, pad_boundary_only)
from repro.physics.euler import (EULER_SPEC, GAMMA, RHO, max_wavespeed,
                                 pressure, shock_bubble_init, update_dim)


def _step_direct(U, dt, dx, dy, boundary):
    """Dimension-split FORCE update, direct implementation."""
    Ux = pad_boundary_only(U, axis=1, width=1, boundary=boundary)
    U = update_dim(Ux, 0, dt / dx)
    Uy = pad_boundary_only(U, axis=2, width=1, boundary=boundary)
    return update_dim(Uy, 1, dt / dy)


def test_shock_bubble_stable_and_physical():
    nx, ny = 64, 32
    dx, dy = 2.0 / nx, 1.0 / ny
    U = shock_bubble_init(nx, ny)
    for _ in range(20):
        s = float(max_wavespeed(U))
        dt = 0.4 * min(dx, dy) / s
        U = _step_direct(U, dt, dx, dy, Boundary.TRANSMISSIVE)
    U = np.asarray(U)
    assert np.isfinite(U).all()
    assert (U[RHO] > 0).all(), "density must stay positive"
    assert (np.asarray(pressure(jnp.asarray(U))) > 0).all()


def test_periodic_mass_conservation():
    nx, ny = 32, 16
    dx, dy = 1.0 / nx, 1.0 / ny
    rng = np.random.default_rng(0)
    rho = 1.0 + 0.1 * rng.random((nx, ny))
    p = 1.0 + 0.1 * rng.random((nx, ny))
    E = p / (GAMMA - 1)
    U = jnp.asarray(np.stack([rho, E, np.zeros_like(rho),
                              np.zeros_like(rho)]), jnp.float32)
    m0 = float(jnp.sum(U[RHO]))
    for _ in range(10):
        U = _step_direct(U, 1e-3, dx, dy, Boundary.PERIODIC)
    np.testing.assert_allclose(float(jnp.sum(U[RHO])), m0, rtol=1e-5)


def test_graph_solver_matches_direct():
    """The paper-Listing-12-style graph must reproduce the direct loop."""
    nx, ny = 32, 16
    dx, dy = 2.0 / nx, 1.0 / ny
    steps = 5

    U0 = shock_bubble_init(nx, ny)

    # direct
    U_direct = U0
    dts = []
    for _ in range(steps):
        s = float(max_wavespeed(U_direct))
        dt = 0.4 * min(dx, dy) / s
        dts.append(dt)
        U_direct = _step_direct(U_direct, dt, dx, dy, Boundary.TRANSMISSIVE)

    # graph (fixed dt per step for exact comparison).  One tensor handle
    # per halo profile (a Graph requires a unique handle per name).
    ux = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                    halo=(1, 0), boundary=Boundary.TRANSMISSIVE)
    uy = ux.with_(halo=(0, 1))
    U_graph = U0
    for dt in dts:
        gx = Graph()
        gx.split(lambda rec: RecordArray(update_dim(rec.data, 0, dt / dx),
                                         EULER_SPEC, Layout.SOA),
                 concurrent_padded_access(ux), writes=(0,))
        gy = Graph()
        gy.split(lambda rec: RecordArray(update_dim(rec.data, 1, dt / dy),
                                         EULER_SPEC, Layout.SOA),
                 concurrent_padded_access(uy), writes=(0,))
        for g in (gx, gy):
            ex = Executor(g, donate=False)
            state = ex.init_state(u=U_graph)
            state = ex(state)
            U_graph = state["u"]
    np.testing.assert_allclose(np.asarray(U_graph), np.asarray(U_direct),
                               rtol=1e-5, atol=1e-6)


def test_wavespeed_reduction_in_graph():
    nx, ny = 16, 8
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA)
    res = make_reduction_result("smax")
    g = Graph()
    g.reduce(u, res, MaxReducer(), field="rho")
    ex = Executor(g, donate=False)
    U0 = shock_bubble_init(nx, ny)
    state = ex.init_state(u=U0)
    state = ex(state)
    np.testing.assert_allclose(float(state["smax"]),
                               float(jnp.max(U0[RHO])), rtol=1e-6)
