"""Per-Pallas-kernel shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Boundary, Layout, RecordArray, pad_boundary_only,
                        relayout)


# -- saxpy --------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bounds_check", [True, False])
def test_saxpy_sweep(rng, n, dtype, bounds_check):
    from repro.kernels.saxpy.ops import saxpy
    from repro.kernels.saxpy.ref import saxpy_ref
    if not bounds_check and n % 1024:
        pytest.skip("NBC variant requires exact tiling (paper's point)")
    x = jnp.asarray(rng.standard_normal(n), dtype)
    y = jnp.asarray(rng.standard_normal(n), dtype)
    out = saxpy(2.5, x, y, bounds_check=bounds_check)
    ref = saxpy_ref(2.5, x, y)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol,
                               atol=tol)


# -- particle -----------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1024, 4096])
@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA, Layout.AOSOA])
def test_saxpy_record_sweep(rng, n, layout):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record
    from repro.kernels.saxpy.ref import saxpy_record_ref
    rec = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(rng.standard_normal(n), jnp.float32),
         "y": jnp.asarray(rng.standard_normal(n), jnp.float32)},
        layout)
    out = saxpy_record(rec, 2.5, block=min(n, 1024))
    ref = saxpy_record_ref(rec, 2.5)
    assert out.layout is layout
    np.testing.assert_allclose(np.asarray(out.data), np.asarray(ref.data),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,block", [(256, 128), (1024, 512), (1024, 256)])
@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA, Layout.AOSOA])
def test_particle_sweep(rng, n, block, layout):
    from repro.kernels.particle.ops import (PARTICLE_SPEC, particle_update,
                                            particle_update_ref)
    rec = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32)),
         "v": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32))},
        layout)
    out = particle_update(rec, 0.25, block=block)
    ref = particle_update_ref(rec, 0.25)
    np.testing.assert_allclose(np.asarray(out.data), np.asarray(ref.data),
                               rtol=1e-6, atol=1e-6)


# -- stencil (FORCE flux) ------------------------------------------------------

@pytest.mark.parametrize("shape", [(32, 16), (64, 64)])
@pytest.mark.parametrize("layout", [Layout.AOS, Layout.SOA, Layout.AOSOA])
def test_flux_sweep(shape, layout):
    from repro.kernels.stencil.ops import flux_difference, flux_difference_ref
    from repro.physics.euler import EULER_SPEC, shock_bubble_init
    U = shock_bubble_init(*shape)
    d = U
    for ax in (1, 2):
        d = pad_boundary_only(d, axis=ax, width=1,
                              boundary=Boundary.TRANSMISSIVE)
    hal = relayout(RecordArray(d, EULER_SPEC, Layout.SOA), layout)
    out = flux_difference(hal, 0.1, 0.1)
    ref = flux_difference_ref(hal, 0.1, 0.1)
    o = out.data if isinstance(out, RecordArray) else out
    r = ref.data if isinstance(ref, RecordArray) else ref
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=1e-4,
                               atol=1e-5)


# -- eikonal (FIM) --------------------------------------------------------------

@pytest.mark.parametrize("n", [32, 64])
def test_eikonal_sweep(n):
    from repro.kernels.eikonal.ops import eikonal_fim_ref, eikonal_fim_sweep
    phi = jnp.full((n, n), 1e3, jnp.float32)
    src = jnp.zeros((n, n), bool).at[n // 2, n // 2].set(True)
    phi = jnp.where(src, 0.0, phi)
    ph = pad_boundary_only(pad_boundary_only(phi, axis=0, width=1),
                           axis=1, width=1)
    o1 = eikonal_fim_sweep(ph, src, 1.0 / n)
    o2 = eikonal_fim_ref(ph, src, 1.0 / n)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-6)


def test_eikonal_converges_to_distance():
    """Iterated FIM sweeps approach the euclidean distance field near the
    source (the paper's reinitialization use-case)."""
    from repro.kernels.eikonal.ops import eikonal_fim_sweep
    n = 64
    h = 1.0 / n
    phi = jnp.full((n, n), 1e3, jnp.float32)
    src = jnp.zeros((n, n), bool).at[n // 2, n // 2].set(True)
    phi = jnp.where(src, 0.0, phi)
    for _ in range(40):
        ph = pad_boundary_only(pad_boundary_only(phi, axis=0, width=1),
                               axis=1, width=1)
        phi = eikonal_fim_sweep(ph, src, h)
    ii, jj = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    dist = np.hypot(ii - n // 2, jj - n // 2) * h
    band = dist < 0.2
    err = np.abs(np.asarray(phi) - dist)[band]
    assert err.max() < 3 * h, err.max()


# -- attention ------------------------------------------------------------------

@pytest.mark.parametrize("s,causal", [(128, True), (256, False)])
@pytest.mark.parametrize("hkv", [2, 4])
def test_flash_attention_sweep(rng, s, causal, hkv):
    from repro.kernels.attention.ops import flash_attention, mha_ref
    b, h, d = 2, 4, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d), dtype=np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d),
                                        dtype=np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d),
                                        dtype=np.float32)) * 0.3
    out = flash_attention(q, k, v, causal=causal)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_attention_decode_kernel(rng):
    from repro.kernels.attention.ops import attention_decode, decode_ref
    b, h, hkv, s, d = 2, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, h, 1, d),
                                        dtype=np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d),
                                        dtype=np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d),
                                        dtype=np.float32)) * 0.3
    lens = jnp.asarray([100, 64], jnp.int32)
    out = attention_decode(q, k, v, lens)
    ref = decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


# -- ssd -------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64)])
def test_ssd_sweep(rng, s, chunk):
    from repro.kernels.ssd.ops import ssd, ssd_chunked, ssd_naive
    b, h, dh, ds = 2, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, dh),
                                        dtype=np.float32)) * 0.3
    dt = jax.nn.softplus(jnp.asarray(
        rng.standard_normal((b, s, h), dtype=np.float32)))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(h, dtype=np.float32)))
    B = jnp.asarray(rng.standard_normal((b, s, ds), dtype=np.float32)) * 0.3
    C = jnp.asarray(rng.standard_normal((b, s, ds), dtype=np.float32)) * 0.3
    D = jnp.asarray(rng.standard_normal(h, dtype=np.float32))
    y1, s1 = ssd(x, dt, A, B, C, D, chunk=chunk)
    y2, s2 = ssd_naive(x, dt, A, B, C, D)
    y3, s3 = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3,
                               atol=2e-3)
