"""Graph-native serving stack: decode/prefill Ripple graphs, the
continuous-batching front end, and the zero-trace worker pattern.

Ground truth throughout is the legacy jit loop (``models.lm.prefill`` +
``decode_step``) — greedy decode is deterministic, so every comparison
is exact token equality, not closeness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core.layout import Layout
from repro.launch import steps
from repro.models import lm
from repro.runtime.batcher import Batcher
from repro.runtime.supervisor import TransientError

MAX_SEQ = 20


@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke("qwen3_8b")
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    ctx = steps.make_ctx(cfg, None)

    def legacy(prompt, n):
        """Per-request greedy chain through the legacy jit path."""
        logits, caches = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, ctx, max_seq=MAX_SEQ)
        )(params, {"tokens": jnp.asarray(prompt)[None]})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [int(tok[0])]
        dstep = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg, ctx))
        for _ in range(n - 1):
            lg, caches = dstep(params, caches, tok)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            out.append(int(tok[0]))
        return out

    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (3, 5, 3, 5)]
    want_n = [4, 3, 4, 2]
    refs = [legacy(p, n) for p, n in zip(prompts, want_n)]
    return cfg, params, prompts, want_n, refs, legacy


def test_batcher_matches_legacy_chains(served):
    """More requests than slots, ragged prompt lengths: every request's
    graph-native greedy chain is argmax-identical to its legacy chain,
    and the steady decode loop traced exactly once."""
    cfg, params, prompts, want_n, refs, _ = served
    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ)
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    done = b.run()
    assert len(done) == len(reqs)
    for req, ref in zip(reqs, refs):
        assert req.status == "done"
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert b.cache_stats()["decode"]["trace_events"] == 1
    # latency bookkeeping: one timestamp per generated token
    assert all(len(r.token_times) == len(r.generated) for r in reqs)


def test_fresh_worker_serves_with_zero_traces(served):
    """A re-instantiated Batcher over the SAME cfg/params objects gets an
    identical plan signature and serves from the process-wide executable
    cache — zero new traces."""
    cfg, params, prompts, want_n, refs, _ = served
    a = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ)
    for p, n in zip(prompts[:2], want_n[:2]):
        a.submit(p, max_new_tokens=n)
    a.run()
    before = a.executor.cache_stats()["trace_events"]

    w = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ)
    reqs = [w.submit(p, max_new_tokens=n)
            for p, n in zip(prompts[:2], want_n[:2])]
    w.run()
    assert w.executor.plan.signature == a.executor.plan.signature
    assert w.executor.cache_stats()["trace_events"] == before
    for req, ref in zip(reqs, refs[:2]):
        assert req.generated == ref


def test_aosoa_decode_plan_identical_tokens(served):
    """Force the decode plan's KV storage to AoSoA (the layout PR-6
    lifted): the vector-pos token writes and the admission scatter run
    through the tiled layout and the tokens stay argmax-identical."""
    cfg, params, prompts, want_n, refs, _ = served
    slots = steps.serving_cache_slots(cfg, 2, MAX_SEQ)
    overrides = {s.tensors[0].name: Layout.AOSOA
                 for s in slots if s.kind in ("A", "L")}
    assert overrides, "qwen3 smoke cfg must have attention layers"
    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ,
                executor_opts={"layout_overrides": overrides})
    for name, lay in overrides.items():
        assert b.executor.plan.initial[name] is Layout.AOSOA
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    b.run()
    for req, ref in zip(reqs, refs):
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_prefill_ahead_identical_tokens_and_consumed(served):
    """Admission overlap: prefills computed behind the dispatched decode
    step are cached per-request and consumed at admission — tokens are
    identical to the no-prefill-ahead path, and nothing leaks."""
    cfg, params, prompts, want_n, refs, _ = served
    for ahead in (False, True):
        b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ,
                    prefill_ahead=ahead)
        reqs = [b.submit(p, max_new_tokens=n)
                for p, n in zip(prompts, want_n)]
        done = b.run()
        assert len(done) == len(reqs)
        for req, ref in zip(reqs, refs):
            assert req.generated == ref, (ahead, req.rid)
        assert b._prepared == {}     # every prepared prefill was consumed


def test_prefill_ahead_never_reused_after_replay(served):
    """Recovery safety: a request replayed after a TransientError has
    generated tokens — its cached fresh-prompt prefill must NOT be
    reused (the replay re-prefills prompt + generated)."""
    cfg, params, prompts, want_n, refs, _ = served
    boom = {"at": 2}

    def hook(step):
        if step == boom["at"]:
            boom["at"] = -1
            raise TransientError("injected")

    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ, step_hook=hook,
                prefill_ahead=True, log=lambda *_: None)
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    b.run()
    assert b.failures == 1
    for req, ref in zip(reqs, refs):
        assert req.status == "done"
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_eviction_from_queue_and_live_slot(served):
    cfg, params, prompts, _, _, legacy = served
    b = Batcher(cfg, params, batch=1, max_seq=MAX_SEQ)
    r1 = b.submit(prompts[0], max_new_tokens=6)
    r2 = b.submit(prompts[1], max_new_tokens=3)
    b.step()
    assert b.evict(r2.rid) and r2.status == "evicted"   # still queued
    assert b.evict(r1.rid) and r1.status == "evicted"   # live slot
    assert b.evict(999) is False
    r3 = b.submit(prompts[2], max_new_tokens=3)
    b.run()
    assert r3.status == "done"
    assert r3.generated == legacy(prompts[2], 3)


def test_eos_retirement(served):
    cfg, params, prompts, _, refs, _ = served
    eos = refs[0][1]                    # second token of request 0
    b = Batcher(cfg, params, batch=1, max_seq=MAX_SEQ, eos_token=eos)
    r = b.submit(prompts[0], max_new_tokens=10)
    b.run()
    assert r.status == "done"
    assert r.generated == refs[0][:2] and r.generated[-1] == eos


def test_transient_failure_replays_request_log(served):
    """A TransientError mid-decode: the batcher re-prefills every
    in-flight request from its request log (prompt + generated) and the
    final chains are still exact — the log IS the checkpoint."""
    cfg, params, prompts, want_n, refs, _ = served
    boom = {"at": 2}

    def hook(step):
        if step == boom["at"]:
            boom["at"] = -1
            raise TransientError("injected")

    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ, step_hook=hook,
                log=lambda *_: None)
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    b.run()
    assert b.failures == 1
    for req, ref in zip(reqs, refs):
        assert req.status == "done"
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_injected_admission_and_step_faults_replay_identically(served):
    """Scheduled faults (repro.runtime.faults) at the batcher's real
    injection sites — an admission scatter failure and a mid-decode step
    failure — recover through the request-log replay with chains exactly
    equal to the fault-free references."""
    from repro.runtime.faults import Fault, FaultPlan, RetryPolicy, fault_scope

    cfg, params, prompts, want_n, refs, _ = served
    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ, log=lambda *_: None,
                retry=RetryPolicy(base_delay=0.0, sleep=lambda d: None))
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    plan = FaultPlan([Fault("batcher.admit", step=0),
                      Fault("batcher.step", step=1)])
    with fault_scope(plan):
        b.run()
    assert plan.exhausted(), plan.report()
    assert b.failures == 2
    for req, ref in zip(reqs, refs):
        assert req.status == "done"
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_fault_during_recovery_loses_no_requests(served):
    """Recovery itself takes a fault: the decode step fails at step 2 and
    the replay's re-admission fails too.  The second recovery attempt
    must still see every live request (slots are never cleared
    destructively) and finish all chains exactly."""
    from repro.runtime.faults import Fault, FaultPlan, RetryPolicy, fault_scope

    cfg, params, prompts, want_n, refs, _ = served
    b = Batcher(cfg, params, batch=2, max_seq=MAX_SEQ, log=lambda *_: None,
                retry=RetryPolicy(base_delay=0.0, sleep=lambda d: None))
    reqs = [b.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, want_n)]
    plan = FaultPlan([Fault("batcher.step", step=2),
                      Fault("batcher.admit", step=2)])   # fires mid-replay
    with fault_scope(plan):
        b.run()
    assert plan.exhausted(), plan.report()
    assert b.failures == 2
    for req, ref in zip(reqs, refs):
        assert req.status == "done", (req.rid, req.status)
        assert req.generated == ref, (req.rid, req.generated, ref)


def test_failure_budget_exhausted_raises(served):
    cfg, params, prompts, _, _, _ = served

    def hook(step):
        raise TransientError("always")

    b = Batcher(cfg, params, batch=1, max_seq=MAX_SEQ, step_hook=hook,
                max_retries_per_step=2, log=lambda *_: None)
    b.submit(prompts[0], max_new_tokens=4)
    with pytest.raises(RuntimeError, match="failed"):
        b.run()


def test_submit_validation(served):
    cfg, params, _, _, _, _ = served
    b = Batcher(cfg, params, batch=1, max_seq=MAX_SEQ)
    with pytest.raises(ValueError, match="empty"):
        b.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_seq"):
        b.submit(np.ones((MAX_SEQ,), np.int32))


def test_state_space_arch_matches_legacy():
    """The M-kind (SSM) layer node path: conv + state caches live as
    plain state tensors, scattered per-slot at admission."""
    cfg = configs.get_smoke("mamba2_130m")
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    ctx = steps.make_ctx(cfg, None)
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, (4,)).astype(np.int32)

    logits, caches = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg, ctx, max_seq=12)
    )(params, {"tokens": jnp.asarray(prompt)[None]})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    ref = [int(tok[0])]
    dstep = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg, ctx))
    for _ in range(2):
        lg, caches = dstep(params, caches, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        ref.append(int(tok[0]))

    b = Batcher(cfg, params, batch=2, max_seq=12)
    r = b.submit(prompt, max_new_tokens=3)
    b.run()
    assert r.generated == ref


def test_encdec_archs_rejected_by_graph_builders():
    cfg = configs.get_smoke("seamless_m4t_medium")
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    with pytest.raises(NotImplementedError):
        steps.make_decode_graph(cfg, params, batch=1, max_seq=8)
