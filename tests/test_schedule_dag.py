"""Dependency-DAG scheduler (core/schedule.py): structural semantics,
executor integration, and the property-tested dag == sequential
equivalence harness (multi-device equivalence runs in subprocesses)."""

import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DistTensor, ExecutionKind, Executor, Graph, Layout,
                        SumReducer, build_dag, dag_segments, execute,
                        make_reduction_result, node_access,
                        sequential_segments)

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _graph_gen import build_random_graph  # noqa: E402

from conftest import run_subprocess_devices  # noqa: E402

LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)


# -- access footprints ---------------------------------------------------------

def test_node_access_split_reads_all_args_writes_declared():
    a = DistTensor("a", (8,))
    b = DistTensor("b", (8,))
    g = Graph()
    g.split(lambda x, y: y, a, b)          # default: writes last tensor arg
    node = next(g.nodes())
    reads, writes = node_access(node)
    assert reads == {"a", "b"}
    assert writes == {"b"}


def test_node_access_reduce_writes_result():
    a = DistTensor("a", (8,))
    res = make_reduction_result("total")
    g = Graph()
    g.reduce(a, res, SumReducer())
    reads, writes = node_access(next(g.nodes()))
    assert (reads, writes) == ({"a"}, {"total"})


def test_node_access_host_node_never_writes():
    a = DistTensor("a", (8,))
    g = Graph()
    g.then(lambda x: None, exec_kind=ExecutionKind.Cpu, args=(a,),
           writes=(0,))
    reads, writes = node_access(next(g.nodes()))
    assert reads == {"a"}
    assert writes == frozenset()  # executor calls host fns for effects only


# -- DAG structure -------------------------------------------------------------

def _chain_graph():
    """u -> ws -> smax -> u (strict chain: nothing to fuse)."""
    u = DistTensor("u", (8, 8))
    ws = DistTensor("ws", (8, 8))
    smax = make_reduction_result("smax")
    g = Graph()
    g.split(lambda a, b: a * 2.0, u, ws)
    g.then_reduce(ws, smax, SumReducer())
    g.then_split(lambda a, s: a + s, u, smax, writes=(0,))
    return g


def test_dag_chain_has_no_antichain():
    ex = Executor(_chain_graph())
    assert [k for k, _ in ex._segments] == ["device"]
    assert ex.dag.fused_antichains() == []
    # raw edges carry the state key that created them
    reasons = {(e.reason, e.key) for e in ex.dag.edges}
    assert ("raw", "ws") in reasons and ("raw", "smax") in reasons


def test_dag_fuses_independent_levels_into_antichain():
    """A then-separated independent reduction hoists into wave 0 — the
    cross-level fusion program order would have serialized."""
    u = DistTensor("u", (8, 8))
    ws = DistTensor("ws", (8, 8))
    smax = make_reduction_result("smax")
    mass = make_reduction_result("mass")
    g = Graph()
    g.split(lambda a, b: a * 2.0, u, ws)
    g.then_reduce(ws, smax, SumReducer())
    g.then_reduce(u, mass, SumReducer())    # independent of ws/smax
    ex = Executor(g)
    fused = ex.dag.fused_antichains()
    assert len(fused) == 1 and len(fused[0]) == 2
    assert {u_.segment for u_ in fused[0]} == {0}
    assert "antichain x2" in ex.describe_dag()

    ex_seq = Executor(g, schedule="sequential")
    waves = [len(w) for w in ex_seq.dag.antichains()]
    assert waves == [1, 1, 1]               # program order: one per level


def test_dag_hoists_independent_device_node_past_host():
    """A device node with no dependency on a host callback fuses into the
    segment *before* it — the host node no longer cuts the jit in two."""
    a = DistTensor("a", (16,))
    b = DistTensor("b", (16,))
    seen = []
    g = Graph()
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda x: seen.append(float(x[0])), exec_kind=ExecutionKind.Cpu,
           args=(a,))
    g.then_split(lambda x: x * 3.0, b, writes=(0,))  # independent of a
    ex = Executor(g, donate=False)
    assert [k for k, _ in ex._segments] == ["device", "host"]
    ex_seq = Executor(g, donate=False, schedule="sequential")
    assert [k for k, _ in ex_seq._segments] == ["device", "host", "device"]
    st = ex(ex.init_state(b=jnp.ones(16)))
    assert seen == [1.0]
    np.testing.assert_array_equal(np.asarray(st["b"]), np.full(16, 3.0))


def test_sync_remains_full_barrier():
    """sync() orders against everything, even data-independent nodes."""
    a = DistTensor("a", (8,))
    b = DistTensor("b", (8,))
    g = Graph()
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.sync()
    g.then_split(lambda x: x + 1.0, b, writes=(0,))  # independent of a
    ex = Executor(g)
    assert [k for k, _ in ex._segments] == ["device", "host", "device"]


def test_host_nodes_keep_program_order():
    """Two data-independent host callbacks must fire in program order
    (side effects are invisible to the footprint analysis)."""
    a = DistTensor("a", (8,))
    b = DistTensor("b", (8,))
    seen = []
    g = Graph()
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.split(lambda x: x + 2.0, b, writes=(0,))
    g.then(lambda x: seen.append(("a", float(x[0]))),
           exec_kind=ExecutionKind.Cpu, args=(a,))
    g.then(lambda x: seen.append(("b", float(x[0]))),
           exec_kind=ExecutionKind.Cpu, args=(b,))
    ex = Executor(g, donate=False)
    ex(ex.init_state())
    assert seen == [("a", 1.0), ("b", 2.0)]


def test_opaque_host_callback_stays_put():
    """A host node with no tensor args has an invisible footprint: it is
    pinned as a barrier, not hoisted to the front."""
    a = DistTensor("a", (8,))
    seen = []
    g = Graph()
    g.split(lambda x: x + 1.0, a, writes=(0,))
    g.then(lambda: seen.append("cb"), exec_kind=ExecutionKind.Cpu)
    g.then_split(lambda x: x * 2.0, a, writes=(0,))
    ex = Executor(g, donate=False)
    assert [k for k, _ in ex._segments] == ["device", "host", "device"]
    st = ex(ex.init_state())
    np.testing.assert_array_equal(np.asarray(st["a"]), np.full(8, 2.0))
    assert seen == ["cb"]


def test_loop_vertex_orders_conservatively():
    """A conditional subgraph reads the whole state (opaque predicate):
    it must wait for every earlier writer and hold back later writers."""
    x = DistTensor("x", (8,))
    loop = Graph(name="dec")
    loop.split(lambda v: v - 1.0, x, writes=(0,))
    loop.conditional(lambda s: s["x"][0] > 0.0)
    g = Graph()
    g.split(lambda v: jnp.full_like(v, 3.0), x, writes=(0,))
    g.then(loop)
    g.then_split(lambda v: v + 10.0, x, writes=(0,))
    for mode in ("dag", "sequential"):
        ex = Executor(g, donate=False, schedule=mode)
        kinds = [k for k, _ in ex._segments]
        assert kinds == ["device", "loop", "device"], mode
        st = ex(ex.init_state())
        np.testing.assert_array_equal(np.asarray(st["x"]), np.full(8, 10.0))


def test_schedule_rejects_unknown_mode():
    g = Graph()
    g.split(lambda x: x, DistTensor("x", (4,)), writes=(0,))
    with pytest.raises(ValueError, match="schedule"):
        Executor(g, schedule="eager")


def test_describe_dag_lists_hoisted_transfers():
    from repro.core import concurrent_padded_access
    src = DistTensor("src", (8, 6), halo=(1, 1))
    dst = DistTensor("dst", (8, 6))
    g = Graph()
    g.split(lambda s, d: s[1:-1, 1:-1], concurrent_padded_access(src), dst)
    ex = Executor(g)
    out = ex.describe_dag()
    assert "seg0 transfers: src 8 blocks" in out
    assert "hoisted to segment entry" in out
    assert all(h.nbytes > 0 for h in ex.plan.halo_transfers)


# -- run() fast path (satellite: consult the scheduler) ------------------------

def test_run_fast_path_consults_scheduler():
    g = _chain_graph()
    ex = Executor(g)
    assert ex.dag.device_only
    st = ex.run(ex.init_state(u=jnp.ones((8, 8))), steps=3)
    assert len(ex._jitted) == 0  # fused fori path, no per-segment jits


def test_run_host_node_mid_graph_breaks_fusion():
    """Regression: a host node anywhere in the graph must run once per
    step — run() may not take the fused fori_loop path."""
    x = DistTensor("x", (8,))
    seen = []
    g = Graph()
    g.split(lambda v: v + 1.0, x, writes=(0,))
    g.then(lambda v: seen.append(float(v[0])), exec_kind=ExecutionKind.Cpu,
           args=(x,))
    g.then_split(lambda v: v * 2.0, x, writes=(0,))
    for mode in ("dag", "sequential"):
        seen.clear()
        ex = Executor(g, donate=False, schedule=mode)
        assert not ex.dag.device_only
        st = ex.run(ex.init_state(), steps=3)
        # x: 0 ->(+1) 1 ->(*2) 2 ->(+1) 3 ->(*2) 6 ->(+1) 7 ->(*2) 14
        assert seen == [1.0, 3.0, 7.0], mode
        np.testing.assert_array_equal(np.asarray(st["x"]), np.full(8, 14.0))


# -- property tests: schedule validity + value equivalence ---------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), layout=st.sampled_from(list(LAYOUTS)))
def test_prop_dag_schedule_is_valid(seed, layout):
    """Structural soundness on random graphs: every edge respects the
    (segment, wave) order, every unit is placed exactly once, and
    same-level conflicting device units share a wave."""
    g, _, _ = build_random_graph(seed, layout)
    dag = build_dag(g)
    segments = dag_segments(dag)
    pos = {u.uid: (u.segment, u.wave) for u in dag.units}
    assert all(p != (-1, -1) for p in pos.values())
    for e in dag.edges:
        assert pos[e.src] < pos[e.dst], (e, pos[e.src], pos[e.dst])
    placed = sum(len(w) for k, p in segments if k == "device" for w in p)
    placed += sum(1 for k, _ in segments if k != "device")
    assert placed == len(dag.units)
    # sequential placement covers the same nodes with the same semantics
    seq = sequential_segments(g)
    seq_nodes = [n for k, p in seq if k == "device" for w in p for n in w]
    dag_nodes = [n for k, p in segments if k == "device" for w in p
                 for n in w]
    assert {id(n) for n in seq_nodes} == {id(n) for n in dag_nodes}


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), layout=st.sampled_from(list(LAYOUTS)))
def test_prop_dag_equals_sequential(seed, layout):
    """The acceptance bar: identical final state under both schedules,
    for random graphs, across all three record layouts (single device;
    2/8-device meshes in the slow subprocess tests below)."""
    g, overrides, keys = build_random_graph(seed, layout)
    outs = {}
    for mode in ("dag", "sequential"):
        ex = Executor(g, donate=False, schedule=mode)
        outs[mode] = ex(ex.init_state(**overrides()))
    for k in keys:
        np.testing.assert_array_equal(
            np.asarray(outs["dag"][k]), np.asarray(outs["sequential"][k]),
            err_msg=f"seed={seed} layout={layout} key={k}")


def test_kernel_builders_compose_into_one_dag_segment():
    """make_*_graph(graph=...) appends to an existing builder: two flux
    kernels over disjoint tensors, written on separate program levels,
    fuse into one antichain and match their standalone results."""
    from repro.core import Boundary
    from repro.kernels.stencil.ops import make_flux_difference_graph
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    def mk(i):
        u = DistTensor(f"u{i}", (16, 8), spec=EULER_SPEC, layout=Layout.SOA,
                       halo=(1, 1), boundary=Boundary.TRANSMISSIVE)
        out = DistTensor(f"f{i}", (16, 8), spec=EULER_SPEC,
                         layout=Layout.SOA)
        return u, out

    (u0, f0), (u1, f1) = mk(0), mk(1)
    g = Graph(name="two_flux")
    make_flux_difference_graph(u0, f0, 0.1, 0.1, overlap=False, graph=g)
    g.then()  # second kernel one program level later
    make_flux_difference_graph(u1, f1, 0.2, 0.2, overlap=False, graph=g)
    ex = Executor(g, donate=False)
    fused = ex.dag.fused_antichains()
    assert fused and len(fused[0]) == 2
    init = shock_bubble_init(16, 8)
    st = ex(ex.init_state(u0=init, u1=2.0 * init))
    for i, (u, f, lam, scale) in enumerate(
            [(u0, f0, 0.1, 1.0), (u1, f1, 0.2, 2.0)]):
        solo = make_flux_difference_graph(u, f, lam, lam, overlap=False)
        ex1 = Executor(solo, donate=False)
        ref = ex1(ex1.init_state(**{f"u{i}": scale * init}))
        np.testing.assert_array_equal(np.asarray(st[f.name]),
                                      np.asarray(ref[f.name]))


# -- acceptance: the examples expose fused antichains --------------------------

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_euler2d_example_fused_antichain_and_equivalence():
    sys.path.insert(0, REPO)
    from examples.euler2d import build_solver
    from repro.physics.euler import shock_bubble_init
    ex, u = build_solver(32, 16)
    fused = ex.dag.fused_antichains()
    assert fused and any(len(w) >= 2 for w in fused)
    assert "antichain x2" in ex.describe_dag()
    outs = {}
    for mode in ("dag", "sequential"):
        e = Executor(ex.graph, donate=False, schedule=mode)
        st = e.init_state(u=shock_bubble_init(32, 16))
        outs[mode] = e.run(st, 3)
    for k in ("u", "ws", "smax", "mass"):
        np.testing.assert_array_equal(np.asarray(outs["dag"][k]),
                                      np.asarray(outs["sequential"][k]),
                                      err_msg=k)


def test_particles_example_fused_antichain():
    sys.path.insert(0, REPO)
    from examples.particles import build_sim
    ex, _tensors, _vmax = build_sim(1024)
    fused = ex.dag.fused_antichains()
    assert any(len(w) >= 3 for w in fused)
    assert "antichain x3" in ex.describe_dag()


_CHILD_EQUIV = r"""
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
from repro.core import Executor, Layout, make_mesh
from _graph_gen import build_random_graph

mesh = make_mesh(({n},), ("gx",))
for seed in range({seeds}):
    for layout in (Layout.AOS, Layout.SOA, Layout.AOSOA):
        g, overrides, keys = build_random_graph(seed, layout,
                                                partition=("gx",))
        outs = []
        for mode in ("dag", "sequential"):
            ex = Executor(g, mesh=mesh, donate=False, schedule=mode)
            outs.append(ex(ex.init_state(**overrides())))
        for k in keys:
            np.testing.assert_array_equal(
                np.asarray(outs[0][k]), np.asarray(outs[1][k]),
                err_msg=f"seed={{seed}} layout={{layout}} key={{k}}")
print("EQUIV-OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n_devices,seeds", [(2, 6), (8, 4)])
def test_dag_equals_sequential_multidevice(n_devices, seeds):
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_subprocess_devices(
        _CHILD_EQUIV.format(tests_dir=tests_dir, n=n_devices, seeds=seeds),
        n_devices=n_devices)
    assert "EQUIV-OK" in out
