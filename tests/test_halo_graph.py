"""C3/C4: halo padding policies and the graph DAG builder/executor
(single-device semantics; multi-device halos in test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Boundary, DistTensor, Executor, Graph, MaxReducer,
                        SumReducer, concurrent_padded_access, execute,
                        exclusive_padded_access, make_reduction_result,
                        pad_boundary_only, unpad)


# -- halo fill policies -------------------------------------------------------

def test_pad_transmissive():
    x = jnp.asarray([1.0, 2.0, 3.0])
    p = pad_boundary_only(x, axis=0, width=2, boundary=Boundary.TRANSMISSIVE)
    np.testing.assert_array_equal(np.asarray(p), [1, 1, 1, 2, 3, 3, 3])


def test_pad_linear():
    x = jnp.asarray([1.0, 2.0, 3.0])
    p = pad_boundary_only(x, axis=0, width=2, boundary=Boundary.LINEAR)
    np.testing.assert_array_equal(np.asarray(p), [-1, 0, 1, 2, 3, 4, 5])


def test_pad_periodic():
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    p = pad_boundary_only(x, axis=0, width=2, boundary=Boundary.PERIODIC)
    np.testing.assert_array_equal(np.asarray(p), [3, 4, 1, 2, 3, 4, 1, 2])


def test_pad_constant():
    x = jnp.asarray([1.0, 2.0])
    p = pad_boundary_only(x, axis=0, width=1, boundary=Boundary.CONSTANT,
                          constant=9.0)
    np.testing.assert_array_equal(np.asarray(p), [9, 1, 2, 9])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 16), w=st.integers(1, 3),
       boundary=st.sampled_from(list(Boundary)))
def test_prop_pad_unpad_roundtrip(n, w, boundary):
    x = jnp.arange(float(n))
    p = pad_boundary_only(x, axis=0, width=w, boundary=boundary)
    assert p.shape[0] == n + 2 * w
    np.testing.assert_array_equal(np.asarray(unpad(p, axis=0, width=w)),
                                  np.asarray(x))


# -- graph builder semantics ---------------------------------------------------

def test_graph_levels_match_paper_listing5():
    g = Graph()
    g.emplace(lambda: None, lambda: None, lambda: None)  # A, B, C level 0
    g.then(lambda: None)                                 # D? paper: then E
    assert len(g.levels) == 2
    assert len(g.levels[0]) == 3
    assert len(g.levels[1]) == 1


def test_graph_saxpy_split():
    size = 64
    x = DistTensor("x", (size,))
    y = DistTensor("y", (size,))
    g = Graph()
    g.split(lambda a, xs, ys: a * xs + ys, 2.0, x, y)
    state = execute(g, x=jnp.arange(size, dtype=jnp.float32),
                    y=jnp.ones(size, jnp.float32))
    np.testing.assert_allclose(np.asarray(state["y"]),
                               2 * np.arange(size) + 1)


def test_graph_reduce_paper_listing8():
    size = 32
    x = DistTensor("x", (size,))
    res = make_reduction_result("total")
    g = Graph()
    g.split(lambda xs: jnp.ones_like(xs), x, writes=(0,))
    g.then_reduce(x, res, SumReducer())
    state = execute(g)
    assert float(state["total"]) == size


def test_graph_conditional_map_reduce_paper_listing9():
    """Paper Listing 9: init to 4, subtract 1 until the sum hits 0.

    ``r`` starts nonzero so the while-semantics loop (predicate gates the
    first iteration) enters; each iteration recomputes it from ``x``."""
    size = 16
    x = DistTensor("x", (size,))
    res = make_reduction_result("r", init=1.0)

    init = Graph(name="init")
    init.split(lambda xs: jnp.full_like(xs, 4.0), x, writes=(0,))

    map_reduce = Graph(name="map_reduce")
    map_reduce.split(lambda xs: xs - 1.0, x, writes=(0,))
    map_reduce.then_reduce(x, res, SumReducer())
    map_reduce.conditional(lambda state: state["r"] != 0.0)

    g = Graph()
    g.emplace(init)
    g.then(map_reduce)
    state = execute(g)
    np.testing.assert_array_equal(np.asarray(state["x"]), np.zeros(size))
    assert float(state["r"]) == 0.0


def test_graph_conditional_false_on_entry_runs_zero_times():
    """While semantics regression: a conditional subgraph whose predicate
    is false on entry must not run its body even once (the old lowering
    seeded lax.while_loop with body_fn(state) — do-while)."""
    size = 8
    x = DistTensor("x", (size,))

    loop = Graph(name="never")
    loop.split(lambda xs: xs + 1.0, x, writes=(0,))
    loop.conditional(lambda state: state["go"] != 0.0)

    g = Graph()
    g.emplace(loop)
    ex = Executor(g)
    state = ex.init_state(x=jnp.full(size, 3.0))
    state["go"] = jnp.asarray(0.0)  # predicate false before first iteration
    state = ex(state)
    np.testing.assert_array_equal(np.asarray(state["x"]), np.full(size, 3.0))

    # and the same loop shape with a satisfiable predicate still iterates
    count = Graph(name="until_five")
    count.split(lambda xs: xs + 1.0, x, writes=(0,))
    count.conditional(lambda s: s["x"][0] < 5.0)
    ex2 = Executor(Graph().emplace(count))
    st = ex2.init_state(x=jnp.full(size, 3.0))
    st = ex2(st)
    np.testing.assert_array_equal(np.asarray(st["x"]), np.full(size, 5.0))


def test_graph_conditional_false_on_entry_host_loop():
    """Same while-semantics guarantee for the host-driven loop (a
    conditional subgraph containing a host node)."""
    size = 4
    x = DistTensor("x", (size,))
    seen = []

    loop = Graph(name="host_never")
    loop.split(lambda xs: xs + 1.0, x, writes=(0,))
    loop.sync(lambda: seen.append("ran"))
    loop.conditional(lambda state: state["go"] != 0.0)

    g = Graph()
    g.emplace(loop)
    ex = Executor(g)
    state = ex.init_state()
    state["go"] = jnp.asarray(0.0)
    state = ex(state)
    assert seen == []
    np.testing.assert_array_equal(np.asarray(state["x"]), np.zeros(size))


def test_graph_sync_and_host_node():
    size = 8
    x = DistTensor("x", (size,))
    seen = []
    g = Graph()
    g.split(lambda xs: xs + 1.0, x, writes=(0,))
    g.sync(lambda: seen.append("synced"))
    g.then_split(lambda xs: xs * 2.0, x, writes=(0,))
    state = execute(g)
    assert seen == ["synced"]
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.full(size, 2.0))


def test_graph_stencil_padded_access():
    size = 16
    src = DistTensor("src", (size,), halo=(1,),
                     boundary=Boundary.TRANSMISSIVE)
    dst = DistTensor("dst", (size,))
    g = Graph()
    g.split(lambda s, d: s[2:] - s[:-2], concurrent_padded_access(src), dst)
    x0 = jnp.arange(size, dtype=jnp.float32) ** 2
    state = execute(g, src=x0)
    xp = np.pad(np.arange(size, dtype=np.float64) ** 2, 1, mode="edge")
    np.testing.assert_allclose(np.asarray(state["dst"]), xp[2:] - xp[:-2])


def test_graph_exclusive_padded_access_inplace():
    size = 12
    x = DistTensor("x", (size,), halo=(1,), boundary=Boundary.PERIODIC)
    g = Graph()
    g.split(lambda s: 0.5 * (s[2:] + s[:-2]), exclusive_padded_access(x),
            writes=(0,))
    x0 = jnp.arange(size, dtype=jnp.float32)
    state = execute(g, x=x0)
    xp = np.concatenate([[size - 1], np.arange(size), [0]]).astype(np.float64)
    np.testing.assert_allclose(np.asarray(state["x"]),
                               0.5 * (xp[2:] + xp[:-2]))


def test_graph_run_steps_fori():
    size = 8
    x = DistTensor("x", (size,))
    g = Graph()
    g.split(lambda xs: xs + 1.0, x, writes=(0,))
    ex = Executor(g)
    state = ex.init_state()
    state = ex.run(state, steps=10)
    np.testing.assert_array_equal(np.asarray(state["x"]), np.full(size, 10.0))


def test_graph_tensor_name_conflict():
    a = DistTensor("t", (8,))
    b = DistTensor("t", (16,))
    g = Graph()
    g.split(lambda x: x, a, writes=(0,))
    g.then_split(lambda x: x, b, writes=(0,))
    with pytest.raises(ValueError):
        g.all_tensors()
