"""Shared tuning workload for cross-process cache tests.

The plan signature keys node functions by module/qualname + code, so a
cached tuning decision only matches across processes when both build the
graph from the SAME importable definitions — exactly the serving
pattern.  This module is that shared definition for the tests."""

from repro.core import DistTensor, Graph, Layout, RecordSpec

SPEC = RecordSpec.create("a", "b")


def mix(r):
    return r.set_field("a", r.field("a") * 1.5 + r.field("b"))


def make_graph(n: int = 1024, name: str = "px") -> Graph:
    p = DistTensor(name, (4, n), spec=SPEC, layout=Layout.AOS)
    g = Graph(name=f"tune_{name}")
    g.split(mix, p, writes=(0,))
    return g
