"""MoE routing/dispatch and SSM (Mamba2 / RG-LRU) block tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.common import ParamTree
from repro.models.moe import _dispatch_slots, init_moe, moe_block, moe_capacity
from repro.models.ssm import (causal_conv1d, conv_state_update, init_mamba2,
                              init_rglru, mamba2_decode, mamba2_forward,
                              rglru_decode, rglru_forward)


# -- MoE -----------------------------------------------------------------------

def _moe_params(E=8, d=32, f=64):
    pt = ParamTree(jax.random.PRNGKey(0))
    init_moe(pt, d_model=d, d_ff=f, n_experts=E, name="moe")
    return pt.params["moe"]


def test_moe_dropless_matches_per_token_loop(rng):
    p = _moe_params()
    x = jnp.asarray(rng.standard_normal((48, 32), dtype=np.float32)) * 0.5
    out, aux = moe_block(p, x, top_k=2, dropless=True)
    probs = jax.nn.softmax(x @ p["router"], -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    for t in range(0, 48, 7):
        acc = 0
        for j in range(2):
            e = int(idx[t, j])
            h = jnp.einsum("d,dtf->tf", x[t], p["wi"][e])
            h = jax.nn.silu(h[0]) * h[1]
            acc = acc + w[t, j] * (h @ p["wo"][e])
        np.testing.assert_allclose(np.asarray(out[t]), np.asarray(acc),
                                   rtol=2e-3, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops():
    """With capacity 8 and all tokens routed to one expert, outputs beyond
    capacity must be exactly zero (dropped)."""
    p = _moe_params(E=4)
    # bias router so every token picks expert 0 then 1
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(0.0)
    x = jnp.ones((64, 32), jnp.float32)
    out, _ = moe_block(p, x, top_k=2, capacity_factor=0.25)
    C = moe_capacity(64, 4, 2, 0.25)
    assert C < 64
    # identical tokens: first-C slots kept; others dropped -> zero rows exist
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert (norms == 0).sum() > 0


@settings(max_examples=20, deadline=None)
@given(T=st.integers(4, 64), E=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 10_000))
def test_prop_dispatch_slots(T, E, k, seed):
    """Dispatch invariants: kept slots unique, within capacity, and map to
    the right expert bucket."""
    rng = np.random.default_rng(seed)
    gate_idx = jnp.asarray(rng.integers(0, E, (T, k)).astype(np.int32))
    C = moe_capacity(T, E, k, 1.0)
    slot, keep, order = _dispatch_slots(gate_idx, E, C)
    slot, keep, order = map(np.asarray, (slot, keep, order))
    kept = slot[keep]
    assert len(np.unique(kept)) == len(kept)          # no collisions
    assert (kept < E * C).all()
    sorted_e = np.asarray(gate_idx).reshape(-1)[order]
    np.testing.assert_array_equal(kept // C, sorted_e[keep])  # right bucket


# -- Mamba2 ---------------------------------------------------------------------

def _mamba_params(d=32, N=16, H=4, P=8):
    pt = ParamTree(jax.random.PRNGKey(0))
    init_mamba2(pt, d_model=d, d_state=N, n_heads=H, head_dim=P, name="m")
    return pt.params["m"]


def test_mamba2_forward_equals_decode(rng):
    p = _mamba_params()
    B, S, d = 2, 24, 32
    x = jnp.asarray(rng.standard_normal((B, S, d), dtype=np.float32)) * 0.5
    y_full, (st_f, conv_f) = mamba2_forward(p, x, chunk=8)
    state = (jnp.zeros((B, 4, 8, 16)), jnp.zeros((B, 3, 4 * 8 + 2 * 16)))
    ys = []
    for t in range(S):
        yt, state = mamba2_decode(p, x[:, t], state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_f), np.asarray(state[0]),
                               rtol=2e-3, atol=2e-4)


def test_mamba2_nondivisible_seq_padding(rng):
    """S not divisible by chunk: padded path must equal naive decode."""
    p = _mamba_params()
    B, S, d = 1, 13, 32
    x = jnp.asarray(rng.standard_normal((B, S, d), dtype=np.float32)) * 0.5
    y, (st, _) = mamba2_forward(p, x, chunk=8)
    state = (jnp.zeros((B, 4, 8, 16)), jnp.zeros((B, 3, 4 * 8 + 2 * 16)))
    ys = []
    for t in range(S):
        yt, state = mamba2_decode(p, x[:, t], state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state[0]),
                               rtol=2e-3, atol=2e-4)


def test_mamba2_padded_heads_exact(rng):
    """Zero-init padded SSD heads must not change the output."""
    B, S, d = 1, 16, 32
    x = jnp.asarray(rng.standard_normal((B, S, d), dtype=np.float32)) * 0.5
    pt = ParamTree(jax.random.PRNGKey(0))
    init_mamba2(pt, d_model=d, d_state=16, n_heads=4, head_dim=8, name="m")
    y_ref, _ = mamba2_forward(pt.params["m"], x, chunk=8)
    pt2 = ParamTree(jax.random.PRNGKey(0))
    init_mamba2(pt2, d_model=d, d_state=16, n_heads=6, head_dim=8,
                pad_heads=2, name="m")
    p2 = dict(pt2.params["m"])
    # graft the unpadded weights into the first 4 head slots
    for nm in ("wz", "wx", "wdt"):
        p2[nm] = p2[nm].at[..., :4, :].set(pt.params["m"][nm]) \
            if nm != "wdt" else p2[nm].at[..., :4].set(pt.params["m"][nm])
    p2["wo"] = p2["wo"].at[:4].set(pt.params["m"]["wo"])
    p2["dt_bias"] = p2["dt_bias"].at[:4].set(pt.params["m"]["dt_bias"])
    p2["A_log"] = p2["A_log"].at[:4].set(pt.params["m"]["A_log"])
    p2["D"] = p2["D"].at[:4].set(pt.params["m"]["D"])
    p2["norm"] = p2["norm"].at[:4].set(pt.params["m"]["norm"])
    p2["conv_x"] = p2["conv_x"].at[: 4 * 8].set(pt.params["m"]["conv_x"])
    p2["conv_B"] = pt.params["m"]["conv_B"]
    p2["conv_C"] = pt.params["m"]["conv_C"]
    y_pad, _ = mamba2_forward(p2, x, chunk=8)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-4)


def test_causal_conv_matches_rolled(rng):
    B, S, C, K = 2, 10, 6, 4
    x = jnp.asarray(rng.standard_normal((B, S, C), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((C, K), dtype=np.float32))
    y = causal_conv1d(x, w)
    xp = np.pad(np.asarray(x), ((0, 0), (K - 1, 0), (0, 0)))
    ref = sum(xp[:, k : k + S] * np.asarray(w)[:, k] for k in range(K))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-6)


# -- RG-LRU ---------------------------------------------------------------------

def test_rglru_forward_equals_decode(rng):
    pt = ParamTree(jax.random.PRNGKey(1))
    init_rglru(pt, d_model=32, lru_width=32, n_blocks=4, name="r")
    p = pt.params["r"]
    B, S = 2, 20
    x = jnp.asarray(rng.standard_normal((B, S, 32), dtype=np.float32)) * 0.5
    y_full, (h_f, conv_f) = rglru_forward(p, x)
    state = (jnp.zeros((B, 32)), jnp.zeros((B, 3, 32)))
    ys = []
    for t in range(S):
        yt, state = rglru_decode(p, x[:, t], state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-4)


def test_rglru_decay_bounded(rng):
    """RG-LRU states must stay bounded (|a| < 1 by construction)."""
    pt = ParamTree(jax.random.PRNGKey(1))
    init_rglru(pt, d_model=16, lru_width=16, n_blocks=2, name="r")
    p = pt.params["r"]
    x = jnp.asarray(rng.standard_normal((1, 512, 16), dtype=np.float32))
    y, (h, _) = rglru_forward(p, x)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(h)).max() < 1e3
