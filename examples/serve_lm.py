"""Batched LM serving demo: prefill + greedy decode with ragged request
lengths (per-request stop), built from the graph-scheduling philosophy of
the paper: prefill and decode are two phases of one program, the KV cache
is the polymorphic-layout record (C1), and per-request completion is the
conditional-execution pattern (paper §5.3.6).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --smoke
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models.blocks import ShardCtx
from repro.models.lm import decode_step, init_lm, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    ctx = ShardCtx()
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    rng = np.random.default_rng(0)
    B = args.batch
    eos = 0  # token 0 acts as EOS for the demo

    batch = {"tokens": jnp.asarray(rng.integers(
        1, cfg.vocab_size, (B, args.prompt_len)).astype(np.int32))}
    kw = {}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, 16, cfg.frontend_dim)).astype(np.float32))
        kw["enc_len"] = 16
    elif cfg.frontend_dim:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32))

    extra = cfg.frontend_tokens if (cfg.frontend_dim
                                    and not cfg.is_encdec) else 0
    max_seq = args.prompt_len + args.max_gen + extra

    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, ctx, max_seq=max_seq))(params, batch)
    t_prefill = time.perf_counter() - t0

    @jax.jit
    def step(params, caches, toks, done):
        logits, caches = decode_step(params, caches, toks, cfg, ctx, **kw)
        nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
        nxt = jnp.where(done, eos, nxt).astype(jnp.int32)
        done = done | (nxt == eos)
        return caches, nxt, done

    toks = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    done = toks == eos
    rows = [np.asarray(toks)]
    t1 = time.perf_counter()
    n_steps = 0
    for _ in range(args.max_gen - 1):
        caches, toks, done = step(params, caches, toks, done)
        rows.append(np.asarray(toks))
        n_steps += 1
        if bool(done.all()):  # conditional stop (paper §5.3.6, host side)
            break
    t_dec = time.perf_counter() - t1

    gen = np.stack(rows, axis=1)
    lens = (gen != eos).sum(axis=1)
    print(f"[serve_lm] arch={cfg.name} batch={B} "
          f"prompt={args.prompt_len} max_gen={args.max_gen}")
    print(f"[serve_lm] prefill {t_prefill*1e3:.0f} ms; "
          f"{t_dec / max(n_steps, 1) * 1e3:.1f} ms/decode-step; "
          f"request lengths {lens.tolist()}")
    for b in range(min(B, 3)):
        print(f"  req{b}: {gen[b][:lens[b]].tolist()[:12]}...")


if __name__ == "__main__":
    main()
