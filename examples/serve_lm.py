"""Continuous-batching LM serving demo on the Ripple executor.

Requests with ragged prompt lengths and per-request EOS stream through
``runtime.Batcher``: prefill and batched greedy decode are Ripple graphs
(one node per layer), the KV cache is a layout-polymorphic RecordArray
state tensor whose storage the layout solver picks, and retired slots are
immediately re-filled from the queue — more requests than batch slots is
the normal case, not an error.  Encoder-decoder / VLM archs fall back to
the legacy jit loop (see repro/launch/serve.py).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --smoke
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax
import numpy as np

import repro.configs as configs
from repro.models.lm import init_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch slots (requests = 2x this)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    if cfg.is_encdec or cfg.frontend_dim:
        print(f"[serve_lm] {cfg.name} is encoder-decoder/VLM; use "
              f"`python -m repro.launch.serve --legacy` for this arch")
        return

    from repro.runtime import Batcher

    rng = np.random.default_rng(0)
    eos = 0  # token 0 acts as EOS for the demo
    n_req = 2 * args.batch
    max_seq = args.prompt_len + args.max_gen

    batcher = Batcher(cfg, params, batch=args.batch, max_seq=max_seq,
                      eos_token=eos)
    t0 = time.perf_counter()
    reqs = []
    for i in range(n_req):
        # ragged prompts: lengths vary per request
        L = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(batcher.submit(prompt, max_new_tokens=args.max_gen))
    batcher.run()
    dt = time.perf_counter() - t0

    n_tok = sum(len(r.generated) for r in reqs)
    lens = [len(r.generated) for r in reqs]
    stats = batcher.cache_stats()["decode"]
    print(f"[serve_lm] arch={cfg.name} slots={args.batch} "
          f"requests={n_req} max_gen={args.max_gen}")
    print(f"[serve_lm] {batcher.steps} decode steps, {n_tok} tokens in "
          f"{dt*1e3:.0f} ms ({n_tok/max(dt,1e-9):.1f} tok/s); "
          f"decode traces={stats['trace_events']}; "
          f"request lengths {lens}")
    for r in reqs[:3]:
        print(f"  req{r.rid} (prompt {len(r.prompt)}): "
              f"{r.generated[:12]}{'...' if len(r.generated) > 12 else ''}")


if __name__ == "__main__":
    main()
