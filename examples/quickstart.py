"""Quickstart: the Ripple core API in five minutes (paper Listings 1-9).

  PYTHONPATH=src python examples/quickstart.py

The block between the ``--8<-- [start:readme]`` markers is embedded
verbatim in README.md; ``tests/test_docstrings.py`` asserts the two stay
in sync (a tested doc-example).
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        RecordArray, RecordSpec, SumReducer, Vector,
                        concurrent_padded_access, execute,
                        make_reduction_result, preferred_layout, relayout)

# ---------------------------------------------------------------------------
# 1. Polymorphic layout (paper Listing 2): one record type, two layouts
# ---------------------------------------------------------------------------
State = RecordSpec.create("density", "pressure", Vector("vel", 2))

fields = {"density": jnp.ones((4, 4)),
          "pressure": jnp.full((4, 4), 2.0),
          "vel": jnp.zeros((4, 4, 2))}
aos = RecordArray.from_fields(State, fields, Layout.AOS)   # (*space, C)
soa = aos.with_layout(Layout.SOA)                           # (C, *space)
print("AoS storage:", aos.data.shape, "| SoA storage:", soa.data.shape)
assert float(soa.field("pressure")[0, 0]) == 2.0  # accessors hide layout

# ---------------------------------------------------------------------------
# 2. Tensors + graphs (paper Listing 7): SAXPY as a split node
#    (this block is the README's tested quickstart snippet)
# ---------------------------------------------------------------------------
# --8<-- [start:readme]
import jax.numpy as jnp
import numpy as np

from repro.core import DistTensor, Executor, Graph

size = 1024
x = DistTensor("x", (size,))
y = DistTensor("y", (size,))

g = Graph()
g.split(lambda a, xs, ys: a * xs + ys, 2.0, x, y)   # writes y (last arg)

ex = Executor(g)            # tune="auto" would measure layouts/tiles too
state = ex.init_state(x=jnp.arange(size, dtype=jnp.float32),
                      y=jnp.ones(size, jnp.float32))
state = ex.run(state, steps=1)
assert (np.asarray(state["y"]) == 2 * np.arange(size) + 1).all()
print(ex.plan.describe())   # schedule + regions + cache + tuning report
# --8<-- [end:readme]

# ---------------------------------------------------------------------------
# 3. Reduction + conditional (paper Listings 8/9): map-reduce loop
# ---------------------------------------------------------------------------
t = DistTensor("t", (256,))
total = make_reduction_result("total")

init = Graph(name="init")
init.split(lambda v: jnp.full_like(v, 3.0), t, writes=(0,))

loop = Graph(name="map_reduce")
loop.split(lambda v: v - 1.0, t, writes=(0,))
loop.then_reduce(t, total, SumReducer())
loop.conditional(lambda s: s["total"] != 0.0)

main = Graph()
main.emplace(init)
main.then(loop)
state = execute(main)
print("map-reduce converged: total =", float(state["total"]))

# ---------------------------------------------------------------------------
# 4. Stencils with halo (paper Listing 10): padded concurrent access
# ---------------------------------------------------------------------------
src = DistTensor("src", (64,), halo=(1,), boundary=Boundary.TRANSMISSIVE)
dst = DistTensor("dst", (64,))
g = Graph()
g.split(lambda s, d: s[2:] - s[:-2], concurrent_padded_access(src), dst)
state = execute(g, src=jnp.arange(64.0) ** 2)
print("central difference[1:4] =", np.asarray(state["dst"][1:4]))

# ---------------------------------------------------------------------------
# 5. Layout selection: user pin vs solver-chosen (paper §4.2)
# ---------------------------------------------------------------------------
# Three layouts now exist: AOS (*space, C), SOA (C, *space), and the tiled
# AOSOA (*space[:-1], n_tiles, C, tile).  relayout() converts exactly.
rec = RecordArray.from_fields(State, fields, Layout.SOA)
print("AoSoA storage:", relayout(rec, Layout.AOSOA).data.shape)

# (a) User pin: pin_layout=True forces the executor to keep your layout.
p = DistTensor("p", (4, 256), spec=State, layout=Layout.AOS, pin_layout=True)
g = Graph()
g.split(lambda r: r.set_field("density", r.field("density") + 1.0), p,
        writes=(0,))
ex = Executor(g)
print("pinned choice:", ex.plan.per_segment[0]["p"])       # Layout.AOS

# (b) Solver-chosen: annotate a node with the kernel's preferred layout
# (preferred_layout(...) or layout= on split/emplace) and the per-segment
# layout solver honors it, inserting relayout nodes at jit-segment
# boundaries when producer and consumer segments disagree.
q = DistTensor("q", (4, 256), spec=State)                   # declared SOA
g = Graph()
g.split(lambda r: r.set_field("density", r.field("density") * 2.0),
        preferred_layout(q, Layout.AOSOA), writes=(0,))
ex = Executor(g)
print("solver choice:", ex.plan.per_segment[0]["q"])        # Layout.AOSOA
print("relayout steps:", ex.plan.relayouts)                 # [] (one segment)

# (c) Measured: Executor(tune="auto") benchmarks the halo-feasible
# layouts per state key (x each kernel's tile_candidates()) with real
# timed executions, commits the argmin, and persists the decision in
# ~/.cache/repro-tune (or $REPRO_TUNE_CACHE) so the next process loads
# it with zero re-measurement:
ex = Executor(g, tune="auto")
print(ex.plan.describe_tuning())

print("\nOn a mesh, DistTensor(partition=('data',)) shards the space and")
print("the same graph runs SPMD with ppermute halo exchange - see")
print("tests/test_distributed.py and examples/euler2d.py.")
