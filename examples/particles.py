"""Two independent particle populations on one Ripple graph (paper §7.2).

Program order writes the pusher/field/diagnostic nodes on separate
levels, but none of them share a tensor — the dependency-DAG scheduler
(``core/schedule.py``) discovers the independence and fuses them into a
single antichain inside one jit segment, so XLA overlaps all three.
Layout polymorphism rides along: the ions store AoS, the electrons
AoSoA, and the same Pallas kernel body updates both.

  PYTHONPATH=src python examples/particles.py [--n 4096] [--steps 100]
  PYTHONPATH=src python examples/particles.py --show-dag
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (DistTensor, Executor, Graph, Layout, MaxReducer,
                        make_reduction_result)
from repro.kernels.particle.ops import PARTICLE_SPEC, particle_update
from repro.kernels.saxpy.kernel import SAXPY_SPEC
from repro.kernels.saxpy.ops import saxpy_record

DT = 0.01


def build_sim(n: int, block: int = 512):
    ions = DistTensor("ions", (n,), spec=PARTICLE_SPEC, layout=Layout.AOS)
    electrons = DistTensor("electrons", (n,), spec=PARTICLE_SPEC,
                           layout=Layout.AOSOA)
    field = DistTensor("field", (n,), spec=SAXPY_SPEC, layout=Layout.SOA)
    vmax = make_reduction_result("vmax")

    g = Graph(name="particle_step")
    # four levels in program order: the three pushers share no tensors,
    # so the DAG schedule fuses them into one antichain; the vmax reduce
    # reads the updated ions (RAW edge) and lands in the next wave
    g.split(lambda r: particle_update(r, DT, block=block), ions, writes=(0,))
    g.then_split(lambda r: particle_update(r, DT, block=block), electrons,
                 writes=(0,))
    g.then_split(lambda r: saxpy_record(r, DT, block=block), field,
                 writes=(0,))
    g.then_reduce(ions, vmax, MaxReducer(), field="v")
    return Executor(g), (ions, electrons, field), vmax


def init_fields(rng, n):
    return {
        "x": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "v": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
    }


def run(n: int, steps: int, show_dag: bool = False):
    from repro.core import RecordArray

    rng = np.random.default_rng(0)
    ex, (ions, electrons, field), vmax = build_sim(n)
    fused = ex.dag.fused_antichains()
    print(f"schedule: {len(ex._segments)} segment(s), "
          f"{len(fused)} fused antichain(s) "
          f"{[[u.label for u in w] for w in fused]}")
    if show_dag:
        print(ex.describe_dag())

    ion0, ele0 = init_fields(rng, n), init_fields(rng, n)
    fld0 = {"x": jnp.asarray(rng.standard_normal(n), jnp.float32),
            "y": jnp.zeros(n, jnp.float32)}
    state = ex.init_state(
        ions=RecordArray.from_fields(PARTICLE_SPEC, ion0, Layout.AOS),
        electrons=RecordArray.from_fields(PARTICLE_SPEC, ele0,
                                          Layout.AOSOA),
        field=RecordArray.from_fields(SAXPY_SPEC, fld0, Layout.SOA))

    t0 = time.perf_counter()
    state = ex.run(state, steps)
    wall = time.perf_counter() - t0

    # drift-free kinematics: x_t = x_0 + t*dt*v, so verify both species
    # against the closed form (and the field against its saxpy series)
    for name, init in (("ions", ion0), ("electrons", ele0)):
        t = ions if name == "ions" else electrons
        got = np.asarray(ex.read(state, t).field("x"))
        want = np.asarray(init["x"]) + steps * DT * np.asarray(init["v"])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    got_y = np.asarray(ex.read(state, field).field("y"))
    np.testing.assert_allclose(
        got_y, steps * DT * np.asarray(fld0["x"]), rtol=1e-4, atol=1e-4)
    print(f"vmax={float(state['vmax']):.3f}; {steps} steps x {n} "
          f"particles/species ok in {wall:.2f}s "
          f"({wall / steps * 1e3:.2f} ms/step)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--show-dag", action="store_true")
    args = ap.parse_args()
    run(args.n, args.steps, show_dag=args.show_dag)
