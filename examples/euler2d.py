"""Euler 2D shock-bubble (the paper's §8 scaling application), built on
the Ripple graph API exactly as paper Listing 12: per-step wavespeed
field -> max-reduction -> CFL dt -> dimension-split FORCE updates with
halo exchange — ONE graph, built once, executed many times.

``--px`` splits the mesh over BOTH grid dims (paper Fig. 7's
multi-dimensional transfer space) and ``--overlap`` hides the halo
ppermutes behind each update's interior program; ``--unsplit`` swaps the
dimension-split updates for one 2-D-stencil node so a single node's halo
schedule spans both axes (corner blocks included).

  PYTHONPATH=src python examples/euler2d.py --nx 128 --ny 64 --steps 50
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/euler2d.py --devices 8 --px 2 --overlap
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        MaxReducer, RecordArray, SumReducer,
                        exclusive_padded_access, make_mesh,
                        make_reduction_result)
from repro.physics.euler import (EULER_SPEC, RHO, pressure,
                                 shock_bubble_init, sound_speed, update_dim,
                                 update_full)


def build_solver(nx: int, ny: int, n_devices: int = 1, cfl: float = 0.4,
                 px: int = 1, overlap: bool = False, unsplit: bool = False):
    dx, dy = 2.0 / nx, 1.0 / ny
    mesh = None
    partition = (None, None)
    if n_devices > 1:
        if px > 1:
            if n_devices % px:
                raise ValueError(f"--px {px} must divide --devices {n_devices}")
            mesh = make_mesh((px, n_devices // px), ("gx", "gy"))
            partition = ("gx", "gy")  # 2-D decomposition
        else:
            mesh = make_mesh((n_devices,), ("gy",))
            partition = (None, "gy")  # paper: split the higher dim

    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                   partition=partition, halo=(1, 1),
                   boundary=Boundary.TRANSMISSIVE)
    ux = u.with_(halo=(1, 0))
    uy = u.with_(halo=(0, 1))
    ws = DistTensor("ws", (nx, ny), partition=partition)
    smax = make_reduction_result("smax", init=1.0)
    mass = make_reduction_result("mass")

    def set_wavespeeds(rec, _ws):
        U = rec.data
        c = sound_speed(U)
        return jnp.maximum(jnp.abs(U[2] / U[0]) + c,
                           jnp.abs(U[3] / U[0]) + c)

    def update_x(rec, s):
        dt = cfl * min(dx, dy) / s
        return RecordArray(update_dim(rec.data, 0, dt / dx), EULER_SPEC,
                           Layout.SOA)

    def update_y(rec, s):
        dt = cfl * min(dx, dy) / s
        return RecordArray(update_dim(rec.data, 1, dt / dy), EULER_SPEC,
                           Layout.SOA)

    def update_xy(rec, s):
        # unsplit scheme: both directional fluxes share one dt bound
        dt = cfl / (s * (1.0 / dx + 1.0 / dy))
        return RecordArray(update_full(rec.data, dt / dx, dt / dy),
                           EULER_SPEC, Layout.SOA)

    # paper Listing 12: one graph per step, reduction feeds the dt.  The
    # mass diagnostic only reads u, so the DAG schedule fuses it into the
    # same antichain as the wavespeed node (describe_dag shows the wave)
    # even though program order puts it two levels later.
    g = Graph(name="euler_step")
    g.split(set_wavespeeds, u, ws)
    g.then_reduce(ws, smax, MaxReducer())
    g.then_reduce(u, mass, SumReducer(), field="rho")
    if unsplit:
        g.then_split(update_xy, exclusive_padded_access(u), smax,
                     writes=(0,), overlap=overlap)
    else:
        g.then_split(update_x, exclusive_padded_access(ux), smax,
                     writes=(0,), overlap=overlap)
        g.then_split(update_y, exclusive_padded_access(uy), smax,
                     writes=(0,), overlap=overlap)
    return Executor(g, mesh=mesh), u


def run(nx: int, ny: int, steps: int, n_devices: int = 1, px: int = 1,
        overlap: bool = False, unsplit: bool = False,
        show_dag: bool = False):
    dx, dy = 2.0 / nx, 1.0 / ny
    ex, u = build_solver(nx, ny, n_devices, px=px, overlap=overlap,
                         unsplit=unsplit)
    fused = ex.dag.fused_antichains()
    print(f"schedule: {len(ex._segments)} segment(s), "
          f"{len(fused)} fused antichain(s) "
          f"{[[un.label for un in w] for w in fused]}")
    if show_dag:
        print(ex.describe_dag())
    if overlap:
        ht = ex.plan.halo_transfers
        print(f"halo schedule: {len(ht)} blocks "
              f"({sum(1 for h in ht if h.overlapped)} overlapped, "
              f"{sum(1 for h in ht if h.mesh_axis)} ppermutes); "
              f"fallbacks: {len(ex.plan.overlap_fallbacks)}")
        for h in ht[:6]:
            print("  " + h.describe())
    U0 = shock_bubble_init(nx, ny)
    mass0 = float(jnp.sum(U0[RHO])) * dx * dy
    state = ex.init_state(u=U0)

    # warmup/compile
    t0 = time.perf_counter()
    state = ex(state)
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    chunk = 10
    for i in range(0, steps - 1, chunk):
        state = ex.run(state, steps=min(chunk, steps - 1 - i))
        U = state["u"]
        # graph-level mass reduction: it reads u in wave 0 (that's what
        # lets it fuse into the wavespeed antichain), so the value is the
        # mass at the START of the last step — labelled accordingly
        mass = float(state["mass"]) * dx * dy
        print(f"step {i + chunk:4d}: smax={float(state['smax']):.3f} "
              f"rho in [{float(U[RHO].min()):.3f}, "
              f"{float(U[RHO].max()):.3f}] "
              f"mass drift (step start) {abs(mass - mass0) / mass0:.2e}")
    wall = time.perf_counter() - t0

    U = state["u"]
    assert np.isfinite(np.asarray(U)).all()
    assert (np.asarray(U[RHO]) > 0).all()
    assert (np.asarray(pressure(U)) > 0).all()
    print(f"\n{steps} steps on {nx}x{ny} ({n_devices} device(s)): "
          f"first-step(+compile) {compile_s:.2f}s, then "
          f"{wall / max(steps - 1, 1) * 1e3:.1f} ms/step")
    return U


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=128)
    ap.add_argument("--ny", type=int, default=64)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--px", type=int, default=1,
                    help="mesh extent along x (2-D decomposition when > 1)")
    ap.add_argument("--overlap", action="store_true",
                    help="hide halo ppermutes behind interior compute")
    ap.add_argument("--unsplit", action="store_true",
                    help="one 2-D-stencil update node instead of "
                         "dimension-split x/y nodes")
    ap.add_argument("--show-dag", action="store_true",
                    help="print the full dependency-DAG schedule "
                         "(describe_dag) before running")
    args = ap.parse_args()
    run(args.nx, args.ny, args.steps, args.devices, px=args.px,
        overlap=args.overlap, unsplit=args.unsplit,
        show_dag=args.show_dag)
