"""Euler 2D shock-bubble (the paper's §8 scaling application), built on
the Ripple graph API exactly as paper Listing 12: per-step wavespeed
field -> max-reduction -> CFL dt -> dimension-split FORCE updates with
halo exchange — ONE graph, built once, executed many times.

  PYTHONPATH=src python examples/euler2d.py --nx 128 --ny 64 --steps 50
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/euler2d.py --devices 4
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        MaxReducer, RecordArray, exclusive_padded_access,
                        make_mesh, make_reduction_result)
from repro.physics.euler import (EULER_SPEC, RHO, pressure,
                                 shock_bubble_init, sound_speed, update_dim)


def build_solver(nx: int, ny: int, n_devices: int = 1, cfl: float = 0.4):
    dx, dy = 2.0 / nx, 1.0 / ny
    mesh = None
    partition = (None, None)
    if n_devices > 1:
        mesh = make_mesh((n_devices,), ("gy",))
        partition = (None, "gy")  # paper: split the higher dim

    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                   partition=partition, halo=(1, 1),
                   boundary=Boundary.TRANSMISSIVE)
    ux = u.with_(halo=(1, 0))
    uy = u.with_(halo=(0, 1))
    ws = DistTensor("ws", (nx, ny), partition=partition)
    smax = make_reduction_result("smax", init=1.0)

    def set_wavespeeds(rec, _ws):
        U = rec.data
        c = sound_speed(U)
        return jnp.maximum(jnp.abs(U[2] / U[0]) + c,
                           jnp.abs(U[3] / U[0]) + c)

    def update_x(rec, s):
        dt = cfl * min(dx, dy) / s
        return RecordArray(update_dim(rec.data, 0, dt / dx), EULER_SPEC,
                           Layout.SOA)

    def update_y(rec, s):
        dt = cfl * min(dx, dy) / s
        return RecordArray(update_dim(rec.data, 1, dt / dy), EULER_SPEC,
                           Layout.SOA)

    # paper Listing 12: one graph per step, reduction feeds the dt
    g = Graph(name="euler_step")
    g.split(set_wavespeeds, u, ws)
    g.then_reduce(ws, smax, MaxReducer())
    g.then_split(update_x, exclusive_padded_access(ux), smax, writes=(0,))
    g.then_split(update_y, exclusive_padded_access(uy), smax, writes=(0,))
    return Executor(g, mesh=mesh), u


def run(nx: int, ny: int, steps: int, n_devices: int = 1):
    dx, dy = 2.0 / nx, 1.0 / ny
    ex, u = build_solver(nx, ny, n_devices)
    U0 = shock_bubble_init(nx, ny)
    mass0 = float(jnp.sum(U0[RHO])) * dx * dy
    state = ex.init_state(u=U0)

    # warmup/compile
    t0 = time.perf_counter()
    state = ex(state)
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    chunk = 10
    for i in range(0, steps - 1, chunk):
        state = ex.run(state, steps=min(chunk, steps - 1 - i))
        U = state["u"]
        mass = float(jnp.sum(U[RHO])) * dx * dy
        print(f"step {i + chunk:4d}: smax={float(state['smax']):.3f} "
              f"rho in [{float(U[RHO].min()):.3f}, "
              f"{float(U[RHO].max()):.3f}] "
              f"mass drift {abs(mass - mass0) / mass0:.2e}")
    wall = time.perf_counter() - t0

    U = state["u"]
    assert np.isfinite(np.asarray(U)).all()
    assert (np.asarray(U[RHO]) > 0).all()
    assert (np.asarray(pressure(U)) > 0).all()
    print(f"\n{steps} steps on {nx}x{ny} ({n_devices} device(s)): "
          f"first-step(+compile) {compile_s:.2f}s, then "
          f"{wall / max(steps - 1, 1) * 1e3:.1f} ms/step")
    return U


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=128)
    ap.add_argument("--ny", type=int, default=64)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=1)
    args = ap.parse_args()
    run(args.nx, args.ny, args.steps, args.devices)
