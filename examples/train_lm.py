"""End-to-end LM training driver: data pipeline -> sharded train step ->
fault-tolerant supervisor with async checkpointing.

Default: a ~12M-param qwen3-family model for 200 steps (CPU-feasible,
~5 min).  ``--big`` trains a ~100M-param model (same code path; budget
accordingly on CPU).  On TPU hardware the same driver scales to the
production mesh via --mesh.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --big --steps 300
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.lm import init_lm, param_count
from repro.optim import cosine_schedule
from repro.runtime import Supervisor


def model_config(big: bool):
    base = configs.get("qwen3-8b")  # family: GQA + qk-norm + swiglu
    if big:
        return base.with_(n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
                          head_dim=64, d_ff=2048, vocab_size=32000,
                          param_dtype="float32", compute_dtype="float32",
                          attn_impl="tri", q_chunk=128, k_chunk=128,
                          remat="none")
    return base.with_(n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                      head_dim=32, d_ff=1024, vocab_size=8192,
                      param_dtype="float32", compute_dtype="float32",
                      attn_impl="tri", q_chunk=128, k_chunk=128,
                      remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_config(args.big)
    print(f"[train_lm] params: {param_count(cfg):,} "
          f"({'~100M' if args.big else '~12M'} config)")

    step_fn, opt = make_train_step(
        cfg, None, lr=cosine_schedule(3e-4, 20, args.steps))
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    jstep = jax.jit(step_fn, donate_argnums=0)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    losses = []

    def wrapped(state, batch):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
        return state

    def batch_at(i):
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    sup = Supervisor(step_fn=wrapped, ckpt=CheckpointManager(args.ckpt_dir),
                     ckpt_every=100)
    state = sup.run(state, batch_at, start_step=0, num_steps=args.steps,
                    on_step=lambda s, _: print(
                        f"step {s:4d}  loss {losses[-1]:.4f}  "
                        f"({sup.stats.last*1e3:.0f} ms)")
                    if s % 20 == 0 else None)
    print(f"[train_lm] loss {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{args.steps} steps; final ppl ~ {2.718 ** losses[-1]:.1f}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
