"""Paper Table 4 — 2-D FORCE flux difference: stencil + layout + VMEM
staging.  Layout effect measured on the pure-jnp path (HLO bytes) and the
Pallas path block-shape knob (the paper's one-line memory-space config).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import analyze_hlo
from repro.core import Boundary, Layout, RecordArray, pad_boundary_only
from repro.kernels.stencil.ops import flux_difference
from repro.physics.euler import EULER_SPEC, shock_bubble_init
from .common import Csv, gbps, time_fn_split


def _haloed(nx, ny, layout):
    U = shock_bubble_init(nx, ny)
    d = U
    for ax in (1, 2):
        d = pad_boundary_only(d, axis=ax, width=1,
                              boundary=Boundary.TRANSMISSIVE)
    rec = RecordArray(d, EULER_SPEC, Layout.SOA)
    return rec if layout is Layout.SOA else rec.with_layout(Layout.AOS)


def main(sizes=((256, 256), (512, 512))) -> list[dict]:
    csv = Csv("size", "layout", "pallas_first_ms", "pallas_cpu_ms",
              "jnp_first_ms", "jnp_cpu_ms", "hlo_bytes", "hlo_flops",
              "jnp_gbps", "pallas_gbps")
    for nx, ny in sizes:
        for layout in (Layout.SOA,):
            hal = _haloed(nx, ny, layout)
            fp, tp = time_fn_split(flux_difference, hal, 0.1, 0.1, iters=3)
            fj, tj = time_fn_split(flux_difference, hal, 0.1, 0.1,
                                   use_pallas=False, iters=3)
            comp = jax.jit(
                lambda h: flux_difference(h, 0.1, 0.1, use_pallas=False)
            ).lower(hal).compile()
            a = analyze_hlo(comp.as_text())
            csv.row(f"{nx}x{ny}", layout.name, fp, tp, fj, tj,
                    int(a["bytes"]), int(a["flops"]),
                    gbps(a["bytes"], tj), gbps(a["bytes"], tp))
    return csv.dicts()


if __name__ == "__main__":
    main()
