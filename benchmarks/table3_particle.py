"""Paper Table 3 — 3-D particle update: polymorphic layout effect.

Strided (SoA) vs contiguous (AoS) for the 6-component particle record.
The transferable metric is the HLO bytes each layout moves (loop-aware
analysis): on TPU the SoA storage streams contiguously while AoS pays a
gather/transpose — same conclusion as the paper's coalescing argument.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import analyze_hlo
from repro.core import Layout, RecordArray
from repro.kernels.particle.ops import PARTICLE_SPEC, particle_update
from .common import Csv, gbps, time_fn_split


def main(sizes=(100_000, 1_000_000)) -> list[dict]:
    csv = Csv("size", "layout", "first_call_ms", "cpu_ms", "hlo_bytes",
              "hlo_flops", "achieved_gbps")
    rng = np.random.default_rng(0)
    for n in sizes:
        fields = {"x": jnp.asarray(rng.standard_normal((n, 3),
                                                       dtype=np.float32)),
                  "v": jnp.asarray(rng.standard_normal((n, 3),
                                                       dtype=np.float32))}
        for layout in (Layout.SOA, Layout.AOS):
            rec = RecordArray.from_fields(PARTICLE_SPEC, fields, layout)
            first, t = time_fn_split(particle_update, rec, 0.1, block=4096)
            comp = jax.jit(
                lambda r: particle_update(r, 0.1, use_pallas=False)
            ).lower(rec).compile()
            a = analyze_hlo(comp.as_text())
            csv.row(n, layout.name, first, t, int(a["bytes"]),
                    int(a["flops"]), gbps(a["bytes"], t))
    return csv.dicts()


if __name__ == "__main__":
    main()
