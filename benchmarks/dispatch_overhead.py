"""Per-step dispatch overhead: region-compiled execution vs per-segment
dispatch (paper §5.3 / Fig. 13 — graphs are built once, executed many).

Measures, for 1/4/16-segment relayout-heavy graphs:

* ``base_ms_per_step`` — the pre-region serving loop: one
  ``Executor(schedule="sequential", regions=False)`` call per step, i.e.
  one jit dispatch per segment plus eager Python relayout glue between
  segments;
* ``region_ms_per_step`` — ``Executor.run(steps)`` with the region
  compiler (default): one cached executable per region per step, the
  relayouts traced inside, and the fused dynamic-``steps`` fori path for
  the device-only 1-segment graph;
* trace counts — steady-state ``run()`` must add ZERO traces (hard
  assertion; this is the CI perf-smoke gate), and a re-instantiated
  Executor over an identical graph must reuse every cached executable
  with zero new traces (the plan-signature cache serving pattern).

``--json BENCH_4.json`` writes the row data — the first entry in the
tracked BENCH trajectory.
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import DistTensor, Executor, Graph, Layout, RecordSpec

from .common import Csv

SPEC = RecordSpec.create("a", "b")


def _bump_a(r):
    return r.set_field("a", r.field("a") + 1.0)


def _accum_b(r):
    return r.set_field("b", r.field("b") + 0.5 * r.field("a"))


def _reset_flag(f):
    return jnp.zeros_like(f)


def _set_flag(f):
    return jnp.ones_like(f)


def build_chain(n_segments: int, n: int = 4096) -> Graph:
    """A relayout-heavy ``device, loop, device, loop, ...`` chain of
    ``n_segments`` jit segments over one record tensor: device segments
    prefer AoS, loop bodies prefer SoA, so every segment boundary carries
    an explicit relayout step.  Each loop is flag-gated to run exactly
    once per pass (its preceding device segment resets the flag), which
    keeps the loop vertices in the schedule without changing semantics.
    All functions are module-level, so a rebuilt graph has an identical
    plan signature (the serving re-instantiation pattern)."""
    r = DistTensor("r", (n,), spec=SPEC, layout=Layout.AOS)
    g = Graph(name=f"chain{n_segments}")
    if n_segments == 1:
        g.split(_bump_a, r, writes=(0,), layout=Layout.AOS)
        g.then_split(_accum_b, r, writes=(0,), layout=Layout.AOS)
        return g
    assert n_segments % 2 == 0, "multi-segment chains alternate device/loop"
    for i in range(n_segments // 2):
        f = DistTensor(f"f{i}", (1,))
        g.then_split(_bump_a, r, writes=(0,), layout=Layout.AOS)
        g.split(_reset_flag, f, writes=(0,))
        loop = Graph(name=f"loop{i}")
        loop.split(_accum_b, r, writes=(0,), layout=Layout.SOA)
        loop.split(_set_flag, f, writes=(0,))
        loop.conditional((lambda nm: lambda s: s[nm][0] < 0.5)(f"f{i}"))
        g.then(loop)
    return g


def _time_loop(step_fn, state, steps: int):
    """(ms_per_step, final_state) for a warmed step driver."""
    t0 = time.perf_counter()
    state = step_fn(state, steps)
    jax.block_until_ready(jax.tree_util.tree_leaves(state))
    return (time.perf_counter() - t0) / steps * 1e3, state


def bench_one(n_segments: int, steps: int, n: int = 4096) -> dict:
    # -- baseline: per-segment dispatch, one __call__ per step --------------
    ex_b = Executor(build_chain(n_segments, n), donate=False,
                    schedule="sequential", regions=False)

    def base_step(state, k):
        for _ in range(k):
            state = ex_b(state)
        return state

    st = ex_b.init_state()
    t0 = time.perf_counter()
    st = base_step(st, 1)
    jax.block_until_ready(jax.tree_util.tree_leaves(st))
    base_first = (time.perf_counter() - t0) * 1e3
    base_ms, st = _time_loop(base_step, st, steps)

    # -- region compiler: run(steps) over cached executables ----------------
    ex_r = Executor(build_chain(n_segments, n), donate=False)
    st = ex_r.init_state()
    t0 = time.perf_counter()
    st = ex_r.run(st, 1)
    jax.block_until_ready(jax.tree_util.tree_leaves(st))
    region_first = (time.perf_counter() - t0) * 1e3
    st = ex_r.run(st, 2)                   # warm the steady entry layouts
    warm = ex_r.cache_stats()
    region_ms, st = _time_loop(ex_r.run, st, steps)
    # a second run with a DIFFERENT step count must not retrace either
    # (regression: the fused fori path used to close over ``steps``)
    st = ex_r.run(st, steps + 3)
    steady_traces = ex_r.cache_stats()["trace_events"] - warm["trace_events"]

    # -- serving pattern: a re-instantiated Executor reuses everything ------
    before = ex_r.cache_stats()
    ex_2 = Executor(build_chain(n_segments, n), donate=False)
    st2 = ex_2.run(ex_2.init_state(), 3)
    jax.block_until_ready(jax.tree_util.tree_leaves(st2))
    after = ex_2.cache_stats()
    reinst_traces = after["trace_events"] - before["trace_events"]
    reinst_hits = after["hits"] - before["hits"]

    return dict(
        segments=n_segments, steps=steps,
        base_first_ms=base_first, base_ms_per_step=base_ms,
        region_first_ms=region_first, region_ms_per_step=region_ms,
        speedup=base_ms / max(region_ms, 1e-9),
        steady_new_traces=steady_traces,
        reinstantiation_new_traces=reinst_traces,
        reinstantiation_cache_hits=reinst_hits,
    )


def main(sizes=(1, 4, 16), steps: int = 30, n: int = 4096,
         json_path=None) -> list[dict]:
    csv = Csv("segments", "base_first_ms", "base_ms_per_step",
              "region_first_ms", "region_ms_per_step", "speedup",
              "steady_new_traces", "reinst_new_traces", "reinst_hits")
    rows = []
    for n_segments in sizes:
        r = bench_one(n_segments, steps, n)
        rows.append(r)
        csv.row(r["segments"], r["base_first_ms"], r["base_ms_per_step"],
                r["region_first_ms"], r["region_ms_per_step"], r["speedup"],
                r["steady_new_traces"], r["reinstantiation_new_traces"],
                r["reinstantiation_cache_hits"])
    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"steps": steps, "n": n, "rows": rows,
                       "unix_time": time.time()}, fh, indent=2)
        print(f"[dispatch_overhead] wrote {json_path}")
    # hard gates (CI perf-smoke): retrace-free steady state + full
    # executable reuse across re-instantiated executors
    bad = [r for r in rows if r["steady_new_traces"] != 0]
    assert not bad, f"steady-state run() retraced: {bad}"
    bad = [r for r in rows if r["reinstantiation_new_traces"] != 0]
    assert not bad, f"re-instantiated Executor retraced: {bad}"
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="larger tensor + more steps")
    args = ap.parse_args()
    try:
        main(steps=args.steps if not args.full else 100,
             n=4096 if not args.full else 1 << 20,
             json_path=args.json)
    except AssertionError as exc:
        print(f"[dispatch_overhead] FAILED: {exc}", file=sys.stderr)
        sys.exit(1)
