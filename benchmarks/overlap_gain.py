"""Async-region overlap gain: host callbacks hidden behind device work.

The event-driven executor (``Executor(async_regions=True)``, the
default) submits host-callback regions to a worker pool and keeps
dispatching device regions instead of blocking on each callback.  On a
host-callback-interleaved chain whose host time per step is calibrated
to roughly equal its device time per step, the sync path pays
``device + host`` per step while the async path pays ``max(device,
host)`` — a ~2x headroom, gated here at >= 1.3x.

This is the BENCH_7 perf-smoke gate (hard asserts, see ``main``):

* async steady-state per-step >= ``min_speedup`` x faster than
  ``async_regions=False`` on the same graph over the 8-device CPU mesh;
* async and sync final states are BITWISE equal (same cached
  executables, same device dispatch order — the async runtime may only
  move *host* work, never change values).

Runs in a subprocess (fig13 idiom) so the 8-virtual-device XLA flag is
set before jax imports regardless of what ``benchmarks.run`` already
imported.

  PYTHONPATH=src python -m benchmarks.overlap_gain [--json BENCH_7.json]
"""

import argparse
import json
import os
import subprocess
import sys
import time

from .common import Csv

_CHILD = r"""
import os, sys, json, time
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp, numpy as np
from repro.core import (DistTensor, Executor, ExecutionKind, Graph,
                        make_mesh)

K_HOST = 4          # host callbacks interleaved per step
N = 1 << 22         # f32 elements, sharded 8 ways
STEPS = 12
SLEEP_MS = [0.0]    # mutable so calibration does not change the graph


def _bump(r):
    # enough flops per segment that device time is measurable on CPU
    return r * 1.0001 + jnp.sin(r) * 1e-3


def _probe(r, m):
    return m + jnp.mean(r[: 1024])[None]


def _host_read(m):
    # read via numpy, NOT an eager jnp op: eager ops enqueue a device
    # computation BEHIND everything already dispatched, which would
    # serialize the callback with the whole in-flight frontier
    float(np.asarray(m)[0])
    time.sleep(SLEEP_MS[0] * 1e-3)    # stand-in for logging/metrics IO


def build():
    mesh = make_mesh((8,), ("d",))
    r = DistTensor("r", (N,), partition=("d",))
    m = DistTensor("m", (1,))
    g = Graph(name="overlap-chain")
    for _ in range(K_HOST):
        g.then_split(_bump, r, writes=(0,))
        g.then_split(_probe, r, m, writes=(1,))
        g.then(_host_read, exec_kind=ExecutionKind.Cpu, args=(m,))
    return g, mesh


def bench(async_regions, steps=STEPS):
    g, mesh = build()
    ex = Executor(g, mesh=mesh, donate=False, async_regions=async_regions)
    st = ex.run(ex.init_state(), 2)   # warm: trace/compile + entry layouts
    jax.block_until_ready(jax.tree.leaves(st))
    t0 = time.perf_counter()
    st = ex.run(st, steps)
    jax.block_until_ready(jax.tree.leaves(st))
    return (time.perf_counter() - t0) / steps * 1e3, ex


# calibrate: host work per step ~= device work per step — the point of
# maximum headroom (sync pays 2x device, async ~1x device + overhead)
device_ms, _ = bench(False)
SLEEP_MS[0] = max(device_ms / K_HOST, 0.2)

sync_ms, _ = bench(False)
async_ms, _ = bench(True)

# bitwise equality: identical step counts from identical init
outs = {}
for mode in (False, True):
    g, mesh = build()
    ex = Executor(g, mesh=mesh, donate=False, async_regions=mode)
    st = ex.run(ex.init_state(), 3)
    jax.block_until_ready(jax.tree.leaves(st))
    outs[mode] = {k: np.asarray(v) for k, v in st.items()}
for k in outs[False]:
    np.testing.assert_array_equal(outs[True][k], outs[False][k],
                                  err_msg=f"async != sync on {k!r}")

print("JSON" + json.dumps(dict(
    n_devices=jax.device_count(), n=N, k_host=K_HOST, steps=STEPS,
    device_ms_per_step=device_ms, sleep_ms_per_cb=SLEEP_MS[0],
    sync_ms_per_step=sync_ms, async_ms_per_step=async_ms,
    speedup=sync_ms / max(async_ms, 1e-9), bitwise_equal=True)))
"""


def main(min_speedup: float = 1.3, json_path=None) -> list[dict]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=1800)
    if res.returncode != 0:
        print(res.stdout)
        print(res.stderr)
        raise RuntimeError("overlap_gain child failed")
    r = json.loads(res.stdout.split("JSON", 1)[1])
    csv = Csv("devices", "host_cbs_per_step", "device_ms_per_step",
              "sleep_ms_per_cb", "sync_ms_per_step", "async_ms_per_step",
              "speedup", "bitwise_equal")
    csv.row(r["n_devices"], r["k_host"], r["device_ms_per_step"],
            r["sleep_ms_per_cb"], r["sync_ms_per_step"],
            r["async_ms_per_step"], r["speedup"], r["bitwise_equal"])
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(dict(r, min_speedup=min_speedup,
                           unix_time=time.time()), fh, indent=2)
        print(f"[overlap_gain] wrote {json_path}")
    # hard gates (CI perf-smoke): the async runtime must actually hide
    # host time, and must never change values
    assert r["bitwise_equal"], "async/sync state mismatch"
    assert r["speedup"] >= min_speedup, (
        f"async overlap gain {r['speedup']:.2f}x < {min_speedup}x "
        f"(sync {r['sync_ms_per_step']:.2f}ms, "
        f"async {r['async_ms_per_step']:.2f}ms)")
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--min-speedup", type=float, default=1.3)
    args = ap.parse_args()
    try:
        main(min_speedup=args.min_speedup, json_path=args.json)
    except AssertionError as exc:
        print(f"[overlap_gain] FAILED: {exc}", file=sys.stderr)
        sys.exit(1)
