"""Run every paper-table benchmark (small default sizes; CPU-feasible).

  PYTHONPATH=src python -m benchmarks.run [--full] [--json PATH]

``--json`` writes machine-readable per-suite results (wall seconds,
status, and each suite's CSV rows) so benchmark trajectories can be
tracked across commits instead of scraping stdout.
"""

import argparse
import json
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write per-suite timings/rows as JSON")
    args = ap.parse_args(argv)

    from . import (chaos_recovery, dispatch_overhead, fig13_scaling,
                   overlap_gain, roofline, serve_load, table2_saxpy,
                   table3_particle, table4_flux, table5_eikonal,
                   table_layout, table_tuned)
    jobs = [
        ("Chaos recovery (injected faults: replay cost + latency)",
         lambda: chaos_recovery.main(
             num_steps=40 if not args.full else 200)),
        ("Dispatch overhead (region compiler vs per-segment)",
         lambda: dispatch_overhead.main(
             steps=30 if not args.full else 100,
             n=4096 if not args.full else 1 << 20)),
        ("Async overlap gain (event-driven host callbacks)",
         overlap_gain.main),
        ("Roofline (achieved vs peak GB/s)", lambda: roofline.main(
            n=1 << 20 if not args.full else 1 << 24)),
        ("Serving load (continuous batching)",
         lambda: serve_load.main(
             slots=2, n_requests=6, prompt_len=10, gen=8,
             tuned=args.full)),
        ("Tuned vs heuristic (measured autotuner)", table_tuned.main),
        ("Layout table (AoS/SoA/AoSoA)", lambda: table_layout.main(
            saxpy_n=1 << 18 if not args.full else 1 << 22,
            particle_n=65_536 if not args.full else 1_048_576,
            flux_shape=(128, 128) if not args.full else (1024, 1024))),
        ("Table 2 (SAXPY)", lambda: table2_saxpy.main(
            sizes=(1 << 18, 1 << 20) if not args.full
            else (1 << 20, 10 << 20, 100 << 20))),
        ("Table 3 (particle)", lambda: table3_particle.main(
            sizes=(65_536, 262_144) if not args.full
            else (100_000, 1_000_000, 10_000_000))),
        ("Table 4 (FORCE flux)", lambda: table4_flux.main(
            sizes=((128, 128),) if not args.full
            else ((1024, 1024), (2048, 2048)))),
        ("Table 5 (eikonal FIM)", lambda: table5_eikonal.main(
            sizes=(128,) if not args.full else (1024, 2048))),
        ("Fig 13 (Euler scaling + 2D overlap)", fig13_scaling.main),
    ]
    failed = 0
    results = []
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        t0 = time.perf_counter()
        rows, err = None, None
        try:
            rows = fn()
        except Exception:
            failed += 1
            err = traceback.format_exc()
            traceback.print_exc()
        results.append({
            "suite": name,
            "ok": err is None,
            "seconds": round(time.perf_counter() - t0, 3),
            "rows": rows if isinstance(rows, (list, dict)) else None,
            "error": err,
        })
    print(f"\n[benchmarks] {len(jobs) - failed}/{len(jobs)} suites OK")
    if args.json:
        payload = {"full": args.full, "unix_time": time.time(),
                   "suites": results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[benchmarks] wrote {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
