"""Run every paper-table benchmark (small default sizes; CPU-feasible).

  PYTHONPATH=src python -m benchmarks.run [--full]
"""

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    args = ap.parse_args(argv)

    from . import (fig13_scaling, table2_saxpy, table3_particle, table4_flux,
                   table5_eikonal, table_layout)
    jobs = [
        ("Layout table (AoS/SoA/AoSoA)", lambda: table_layout.main(
            saxpy_n=1 << 18 if not args.full else 1 << 22,
            particle_n=65_536 if not args.full else 1_048_576,
            flux_shape=(128, 128) if not args.full else (1024, 1024))),
        ("Table 2 (SAXPY)", lambda: table2_saxpy.main(
            sizes=(1 << 18, 1 << 20) if not args.full
            else (1 << 20, 10 << 20, 100 << 20))),
        ("Table 3 (particle)", lambda: table3_particle.main(
            sizes=(65_536, 262_144) if not args.full
            else (100_000, 1_000_000, 10_000_000))),
        ("Table 4 (FORCE flux)", lambda: table4_flux.main(
            sizes=((128, 128),) if not args.full
            else ((1024, 1024), (2048, 2048)))),
        ("Table 5 (eikonal FIM)", lambda: table5_eikonal.main(
            sizes=(128,) if not args.full else (1024, 2048))),
        ("Fig 13 (Euler scaling)", fig13_scaling.main),
    ]
    failed = 0
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        try:
            fn()
        except Exception:
            failed += 1
            traceback.print_exc()
    print(f"\n[benchmarks] {len(jobs) - failed}/{len(jobs)} suites OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
