"""Pallas flash-attention substitution projection (EXPERIMENTS §Perf).

Measures — from a freshly compiled cell — the HBM bytes attributable to
the jnp chunked-attention scans (innermost-while tagging on the attention
einsum labels), then substitutes the Pallas kernel's analytic DMA traffic:

  per pass:  (q + o) read/write once  +  (k + v) streamed once per
             q-block (causal: (nq+1)/(2*nq) of the blocks)
  per step:  x3.5  (forward + remat recompute + flash backward)

The kernel itself is `repro.kernels.attention` (validated vs the oracle
in tests/test_kernels.py); this projects its traffic into the roofline
without needing TPU hardware.

  python -m benchmarks.flash_projection --arch qwen3-8b --shape train_4k \
      [--fsdp] [--tri]
"""

import argparse
import os


def main(argv=None) -> None:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--tri", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    import repro.configs as configs
    from repro.analysis.hlo import HloCostModel
    from repro.launch.dryrun import build_cell
    from repro.models.config import SHAPES

    arch = configs.ALIASES.get(args.arch, args.arch)
    mod = __import__(f"repro.configs.{arch}", fromlist=["config"])
    orig = mod.config
    kw = {}
    if args.fsdp:
        kw["train_sharding"] = "fsdp"
    if args.tri:
        kw["attn_impl"] = "tri"
    mod.config = lambda: orig().with_(**kw)
    try:
        cfg, shape, mesh, fn, cell_args = build_cell(arch, args.shape,
                                                     args.multi_pod)
        compiled = fn.lower(*cell_args).compile()
        m = HloCostModel(compiled.as_text())
        total = m.bytes_accessed()
        attn = m.tagged_while_bytes(r"hgqk")

        # analytic kernel traffic (bf16) per device per step
        n_dev = mesh.devices.size
        dp = n_dev // mesh.shape.get("model", 1)
        if cfg.train_sharding == "fsdp":
            dp = n_dev
        B_l = max(shape.global_batch // dp, 1)
        S = shape.seq_len
        H = cfg.padded_heads(1 if cfg.train_sharding == "fsdp"
                             else mesh.shape.get("model", 1))
        if cfg.train_sharding != "fsdp":
            H = max(H // mesh.shape.get("model", 1), 1)
        Hkv, D, qc = cfg.n_kv_heads, cfg.head_dim, cfg.q_chunk
        nq = max(S // qc, 1)
        dt = 2  # bf16
        q_o = 2 * B_l * S * H * D * dt
        kv = 2 * B_l * S * Hkv * D * dt * (nq + 1) / 2
        passes = 3.5 if shape.kind == "train" else 1.0
        n_attn_layers = sum(1 for i in range(cfg.n_layers)
                            if cfg.pattern[i % len(cfg.pattern)] in ("A", "L"))
        flash = (q_o + kv) * passes * n_attn_layers
        proj = total - attn + flash

        print(f"cell: {arch} x {args.shape} ({'fsdp ' if args.fsdp else ''}"
              f"{'tri' if args.tri else ''})")
        print(f"  measured bytes/dev:        {total:.3e}  "
              f"(memory term {total/819e9:.2f}s)")
        print(f"  attention-scan bytes/dev:  {attn:.3e}  "
              f"({attn/total*100:.1f}%)")
        print(f"  flash-kernel bytes/dev:    {flash:.3e}  (analytic)")
        print(f"  projected bytes/dev:       {proj:.3e}  "
              f"(memory term {proj/819e9:.2f}s)")
    finally:
        mod.config = orig


if __name__ == "__main__":
    main()
