"""Paper Figs 12/13 — Euler 2D shock-bubble weak/strong scaling.

On this container all fake devices share ONE CPU core, so wall time does
NOT show parallel speedup; the transferable metrics are (a) the per-device
collective bytes (halo traffic) as the device count grows and (b) the
halo-to-compute byte ratio, which determines the TPU scaling efficiency
(halo bytes / ICI bw vs compute bytes / HBM bw).  Runs in a subprocess
with 8 virtual devices.
"""

import json
import os
import subprocess
import sys

from .common import Csv

_CHILD = r"""
import os, sys, json, time
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp, numpy as np
from repro.analysis import analyze_hlo
from repro.core import (Boundary, DistTensor, Executor, Graph, Layout,
                        MaxReducer, RecordArray, SumReducer,
                        concurrent_padded_access, exclusive_padded_access,
                        make_mesh, make_reduction_result)
from repro.physics.euler import (EULER_SPEC, shock_bubble_init, sound_speed,
                                 update_dim, update_full)

def build(nx, ny, n_dev, steps):
    mesh = make_mesh((n_dev,), ("gy",))
    ux = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                    partition=(None, "gy"), halo=(1, 0),
                    boundary=Boundary.TRANSMISSIVE)
    uy = ux.with_(halo=(0, 1))
    lam = 1e-3
    gx = Graph(); gy_ = Graph()
    gx.split(lambda rec: RecordArray(update_dim(rec.data, 0, lam),
                                     EULER_SPEC, Layout.SOA),
             concurrent_padded_access(ux), writes=(0,))
    gy_.split(lambda rec: RecordArray(update_dim(rec.data, 1, lam),
                                      EULER_SPEC, Layout.SOA),
              concurrent_padded_access(uy), writes=(0,), overlap=True)
    g = Graph(); g.emplace(gx); g.then(gy_)
    ex = Executor(g, mesh=mesh)
    return ex

def build2d(nx, ny, px, py, overlap):
    # 2-D decomposition, one unsplit 2-D-stencil node: the halo schedule
    # spans both mesh axes (edge strips + corner blocks)
    mesh = make_mesh((px, py), ("gx", "gy"))
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                   partition=("gx", "gy"), halo=(1, 1),
                   boundary=Boundary.TRANSMISSIVE)
    lam = 1e-3
    g = Graph()
    g.split(lambda rec: RecordArray(update_full(rec.data, lam, lam),
                                    EULER_SPEC, Layout.SOA),
            concurrent_padded_access(u), writes=(0,), overlap=overlap)
    return Executor(g, mesh=mesh)

def build_sched(nx, ny, n_dev, schedule):
    # full euler step (wavespeed -> smax/mass reductions -> update): the
    # DAG schedule fuses the independent mass reduction into the
    # wavespeed antichain; sequential runs the four levels in order
    mesh = make_mesh((n_dev,), ("gy",))
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.SOA,
                   partition=(None, "gy"), halo=(0, 1),
                   boundary=Boundary.TRANSMISSIVE)
    ws = DistTensor("ws", (nx, ny), partition=(None, "gy"))
    smax = make_reduction_result("smax", init=1.0)
    mass = make_reduction_result("mass")

    def wavespeeds(rec, _ws):
        U = rec.data
        c = sound_speed(U)
        return jnp.maximum(jnp.abs(U[2] / U[0]) + c,
                           jnp.abs(U[3] / U[0]) + c)

    def upd(rec, s):
        return RecordArray(update_dim(rec.data, 1, 4e-4 / s), EULER_SPEC,
                           Layout.SOA)

    g = Graph()
    g.split(wavespeeds, u, ws)
    g.then_reduce(ws, smax, MaxReducer())
    g.then_reduce(u, mass, SumReducer(), field="rho")
    g.then_split(upd, exclusive_padded_access(u), smax, writes=(0,))
    return Executor(g, mesh=mesh, schedule=schedule)

def measure(ex, state, reps=5):
    t0 = time.perf_counter()
    state = ex(state)  # warm/compile: trace + compile + first run
    jax.block_until_ready(jax.tree.leaves(state))
    first = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    for _ in range(reps):
        state = ex(state)
    jax.block_until_ready(jax.tree.leaves(state))
    dt = (time.perf_counter() - t0) / reps * 1e3
    # the region compiler's executable for the (single) device region
    a = analyze_hlo(ex.region_hlo(state))
    return state, first, dt, a

out = []
base = 128
for mode in ("weak", "strong"):
    for n_dev in (1, 2, 4, 8):
        if mode == "weak":
            nx, ny = base, base * n_dev   # constant cells per device
        else:
            nx, ny = base, base * 8       # fixed global problem
        ex = build(nx, ny, n_dev, 1)
        state = ex.init_state(u=shock_bubble_init(nx, ny))
        state, first, dt, a = measure(ex, state)
        out.append(dict(mode=mode, n_dev=n_dev, nx=nx, ny=ny,
                        first_call_ms=first, ms_per_step=dt,
                        halo_bytes_per_dev=a["collective_link_bytes"],
                        hlo_bytes_per_dev=a["bytes"]))

# 2-D mesh: overlapped vs synchronous halo scheduling on the same problem
nx = ny = 2 * base
ref = None
for overlap in (False, True):
    ex = build2d(nx, ny, 2, 4, overlap)
    state = ex.init_state(u=shock_bubble_init(nx, ny))
    state, first, dt, a = measure(ex, state)
    u_out = np.asarray(state["u"])
    if ref is None:
        ref = u_out
    else:
        np.testing.assert_allclose(u_out, ref, rtol=1e-5, atol=1e-6)
    out.append(dict(mode="2d-overlap" if overlap else "2d-sync",
                    n_dev=8, nx=nx, ny=ny, first_call_ms=first,
                    ms_per_step=dt,
                    halo_bytes_per_dev=a["collective_link_bytes"],
                    hlo_bytes_per_dev=a["bytes"]))

# DAG vs sequential scheduling on the full euler step: value-equal
# (bitwise) by construction, but the DAG fuses the independent mass
# reduction into the wavespeed antichain (one fewer serialized wave)
nx, ny = base, 2 * base
ref = None
for schedule in ("sequential", "dag"):
    ex = build_sched(nx, ny, 8, schedule)
    state = ex.init_state(u=shock_bubble_init(nx, ny))
    state, first, dt, a = measure(ex, state)
    u_out = np.asarray(state["u"])
    if ref is None:
        ref = u_out
    else:
        np.testing.assert_array_equal(u_out, ref)
    n_fused = len(ex.plan.dag.fused_antichains())
    assert (n_fused >= 1) == (schedule == "dag"), (schedule, n_fused)
    out.append(dict(mode=f"sched-{schedule}", n_dev=8, nx=nx, ny=ny,
                    first_call_ms=first, ms_per_step=dt,
                    halo_bytes_per_dev=a["collective_link_bytes"],
                    hlo_bytes_per_dev=a["bytes"]))
print("JSON" + json.dumps(out))
"""


def main() -> list[dict]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=1800)
    if res.returncode != 0:
        print(res.stdout)
        print(res.stderr)
        raise RuntimeError("fig13 child failed")
    data = json.loads(res.stdout.split("JSON", 1)[1])
    csv = Csv("mode", "devices", "grid", "first_call_ms",
              "ms_per_step(1-core-caveat)",
              "halo_bytes_per_dev", "hlo_bytes_per_dev", "halo_fraction")
    for r in data:
        frac = r["halo_bytes_per_dev"] / max(r["hlo_bytes_per_dev"], 1)
        csv.row(r["mode"], r["n_dev"], f"{r['nx']}x{r['ny']}",
                r["first_call_ms"], r["ms_per_step"],
                int(r["halo_bytes_per_dev"]),
                int(r["hlo_bytes_per_dev"]), frac)
    return csv.dicts()


if __name__ == "__main__":
    main()
