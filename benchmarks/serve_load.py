"""Serving load benchmark: continuous batching on the Ripple executor.

A synthetic many-user load (more requests than decode slots, ragged
prompt lengths) streams through ``runtime.Batcher`` and reports the
serving numbers the paper's executor story promises:

* ``req_per_s`` / ``tok_per_s`` — end-to-end throughput over the wall
  clock of the whole drain (prefills, admissions and decode steps);
* ``p50_tok_ms`` / ``p99_tok_ms`` — per-token latency percentiles over
  every generated token (a token's latency is the gap to the previous
  token of the same request; the first token's is measured from
  ``submit``, so queueing shows up in the tail);
* ``achieved_gbps`` — achieved bandwidth of the steady decode step from
  known bytes-moved (read every parameter once, read+write every state
  tensor — the cache-bound decode roofline estimate) over the measured
  mean step time;
* trace discipline — the steady decode loop traces ONCE per plan, and a
  freshly constructed worker ``Batcher`` (same cfg/params objects)
  serves with ZERO new traces straight from the process-wide executable
  cache.  Both are hard-asserted; this is the CI serve-smoke gate.

Two variants per run: ``heuristic`` (the layout solver's static picks)
and ``tuned`` (``Executor(tune="auto")`` — the measured autotuner,
which after the PR-6 donation fix benches candidates under the decode
plan's real donating executables).

  PYTHONPATH=src python -m benchmarks.serve_load --json BENCH_6.json
"""

import argparse
import json
import sys
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models.lm import init_lm

from .common import Csv, gbps

# ragged prompt lengths cycle over a few values so the prefill-graph
# cache stays small (one trace per distinct length)
PROMPT_FRACS = (0.5, 0.75, 1.0)


def _known_bytes_per_step(params, state) -> int:
    """Known bytes-moved by one decode step: every parameter is read
    once, every state tensor (KV caches dominate) is read and written."""
    p = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))
    s = sum(v.nbytes for v in state.values())
    return p + 2 * s


def _submit_load(batcher, cfg, rng, n_requests, prompt_len, gen):
    reqs = []
    for i in range(n_requests):
        L = max(1, int(prompt_len * PROMPT_FRACS[i % len(PROMPT_FRACS)]))
        prompt = rng.integers(1, cfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(batcher.submit(prompt, max_new_tokens=gen))
    return reqs


def _token_latencies_ms(reqs) -> np.ndarray:
    lat = []
    for r in reqs:
        if not r.token_times:
            continue
        lat.append(r.token_times[0] - r.t_submit)
        lat.extend(np.diff(r.token_times))
    return np.asarray(lat) * 1e3


def bench_variant(cfg, params, *, variant, tune, slots, n_requests,
                  prompt_len, gen, seed=0) -> dict:
    from repro.runtime.batcher import Batcher

    opts = {"tune": tune}
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    batcher = Batcher(cfg, params, batch=slots, max_seq=prompt_len + gen,
                      executor_opts=opts)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    reqs = _submit_load(batcher, cfg, rng, n_requests, prompt_len, gen)
    batcher.run()
    wall = time.perf_counter() - t0

    lat = _token_latencies_ms(reqs)
    n_tok = int(sum(len(r.generated) for r in reqs))
    stats = batcher.cache_stats()["decode"]
    nbytes = _known_bytes_per_step(params, batcher.state)
    step_ms = batcher.stats.mean * 1e3

    # a fresh worker (same cfg/params objects => same plan signature)
    # must serve the same load with ZERO new traces
    before = stats["trace_events"]
    worker = Batcher(cfg, params, batch=slots, max_seq=prompt_len + gen,
                     executor_opts=opts)
    wreqs = _submit_load(worker, cfg, np.random.default_rng(seed),
                         n_requests, prompt_len, gen)
    worker.run()
    fresh_new = worker.executor.cache_stats()["trace_events"] - before
    assert worker.executor.plan.signature == batcher.executor.plan.signature
    assert [r.generated for r in wreqs] == [r.generated for r in reqs], \
        "fresh worker generated different tokens"

    return dict(
        variant=variant, slots=slots, requests=n_requests,
        prompt_len=prompt_len, gen=gen,
        build_s=build_s, wall_s=wall,
        req_per_s=n_requests / max(wall, 1e-9),
        tok_per_s=n_tok / max(wall, 1e-9),
        p50_tok_ms=float(np.percentile(lat, 50)),
        p99_tok_ms=float(np.percentile(lat, 99)),
        step_ms=step_ms,
        known_bytes_per_step=nbytes,
        achieved_gbps=gbps(nbytes, step_ms),
        decode_steps=batcher.steps,
        decode_traces=stats["trace_events"],
        fresh_worker_new_traces=int(fresh_new),
    )


def main(arch="qwen3_8b", slots=3, n_requests=8, prompt_len=12, gen=12,
         tuned=True, json_path=None) -> list[dict]:
    cfg = configs.get_smoke(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)

    csv = Csv("variant", "slots", "requests", "wall_s", "req_per_s",
              "tok_per_s", "p50_tok_ms", "p99_tok_ms", "step_ms",
              "achieved_gbps", "decode_traces", "fresh_new_traces")
    variants = [("heuristic", "off")] + ([("tuned", "auto")] if tuned
                                         else [])
    rows = []
    for variant, tune in variants:
        r = bench_variant(cfg, params, variant=variant, tune=tune,
                          slots=slots, n_requests=n_requests,
                          prompt_len=prompt_len, gen=gen)
        rows.append(r)
        csv.row(r["variant"], r["slots"], r["requests"], r["wall_s"],
                r["req_per_s"], r["tok_per_s"], r["p50_tok_ms"],
                r["p99_tok_ms"], r["step_ms"], r["achieved_gbps"],
                r["decode_traces"], r["fresh_worker_new_traces"])

    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"arch": arch, "slots": slots,
                       "requests": n_requests, "prompt_len": prompt_len,
                       "gen": gen, "rows": rows,
                       "unix_time": time.time()}, fh, indent=2)
        print(f"[serve_load] wrote {json_path}")

    # hard gates (CI serve-smoke): the steady decode loop traced once for
    # the first (heuristic) plan, and every fresh worker re-served its
    # load from the executable cache with zero new traces
    assert rows[0]["decode_traces"] == 1, rows[0]
    bad = [r for r in rows if r["fresh_worker_new_traces"] != 0]
    assert not bad, f"fresh worker retraced: {bad}"
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--no-tuned", action="store_true",
                    help="skip the tune=\"auto\" variant")
    args = ap.parse_args()
    try:
        main(arch=args.arch, slots=args.slots, n_requests=args.requests,
             prompt_len=args.prompt_len, gen=args.gen,
             tuned=not args.no_tuned, json_path=args.json)
    except AssertionError as exc:
        print(f"[serve_load] FAILED: {exc}", file=sys.stderr)
        sys.exit(1)
