"""Per-kernel roofline: achieved GB/s vs. estimated peak bandwidth.

Every Ripple kernel in this repo is memory-bound at benchmark sizes, so
the honest performance number is *achieved bandwidth from known
bytes-moved* against an *estimated peak* — not milliseconds (the
related Triton exemplar reports exactly this, see ROADMAP §benchmarks).
Two peaks are reported:

* ``copy_peak_gbps`` — MEASURED on this machine: a large ``jnp.copy``
  stream (read + write every byte) is the practical ceiling any kernel
  here could reach; each kernel row reports the fraction of it
  achieved.  This is the number that transfers across hosts/backends.
* reference-hardware constants (:data:`HBM_BW` etc., TPU v5e class)
  stay exported for the cross-table roofline arithmetic other modules
  and docs refer to (``common.gbps`` fractions, flash_projection).

Bytes-moved per kernel is analytic, never scraped from timings:

=============  =====================================================
kernel         known bytes per invocation
=============  =====================================================
saxpy          read x, read y, write out — 3 f32 streams
saxpy_record   read + write the whole 2-field record storage
particle       read + write the whole {x,v} record storage
flux           read the padded record, write the interior record
=============  =====================================================

Record kernels run through the XLA path (``use_pallas=False``): on CPU
the Pallas backend is interpret-mode emulation whose wall-clock
measures the emulator, not memory traffic.

  PYTHONPATH=src python -m benchmarks.roofline [--json PATH]

Wired into ``benchmarks.run`` (suite "Roofline (achieved vs peak
GB/s)") whose nightly CI artifact tracks the trajectory.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time

import jax.numpy as jnp
import numpy as np

import jax

from repro.analysis import analyze_hlo

from .common import Csv, gbps, time_fn

# reference hardware constants (TPU v5e class, per the brief) — the
# cross-table roofline terms other benchmarks/docs compare against
PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s/link (ICI); pod axis rides DCN (slower)


def measure_copy_peak(n_floats: int = 1 << 21) -> float:
    """Measured streaming-copy bandwidth of THIS machine in GB/s: one
    ``jnp.copy`` of ``n_floats`` f32 reads and writes every byte, so
    ``2 * 4 * n_floats`` bytes over the median time is the practical
    ceiling for any memory-bound kernel here.  The default working set
    matches the kernel rows' so cache residency cancels out of the
    fraction; fractions can still drift past 1 on CPU (copy is one
    stream, saxpy is three — more of it re-hits cache)."""
    x = jnp.arange(n_floats, dtype=jnp.float32)
    ms = time_fn(jnp.copy, x)
    return gbps(2 * x.nbytes, ms)


def _model_bytes(fn, *args) -> float:
    """Traffic predicted by the loop-aware HLO cost model
    (``repro.analysis.analyze_hlo``) for the jitted kernel — the same
    model the joint autotuner prunes candidates with, reported here next
    to the analytic ``known_bytes`` so the roofline documents how far
    the pruning model sits from the hand-counted minimum per kernel."""
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())["bytes"]


def _row(csv, kernel, n_label, ms, nbytes, peak, model_bytes=0.0):
    achieved = gbps(nbytes, ms)
    csv.row(kernel, n_label, ms, nbytes, achieved, peak,
            achieved / max(peak, 1e-9),
            achieved / (HBM_BW / 1e9),
            model_bytes, model_bytes / max(nbytes, 1))
    return achieved


def main(n=1 << 20, particle_n=262_144, flux_shape=(256, 256),
         json_path=None) -> list[dict]:
    """Per-kernel achieved GB/s against the measured copy peak (and the
    reference-TPU HBM fraction).  Returns the CSV rows; hard-asserts
    only sanity (positive bandwidths), not fractions — CPU CI noise
    would make fraction gates flaky."""
    csv = Csv("kernel", "size", "steady_ms", "known_bytes",
              "achieved_gbps", "copy_peak_gbps", "frac_of_copy_peak",
              "frac_of_ref_hbm", "hlo_model_bytes", "model_vs_known")
    rng = np.random.default_rng(0)
    peak = measure_copy_peak()

    # -- saxpy (array form: the 3-stream classic) ---------------------------
    from repro.kernels.saxpy.ops import saxpy

    x = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    y = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    ms = time_fn(saxpy, 2.0, x, y, use_pallas=False)
    _row(csv, "saxpy", n, ms, 3 * n * 4, peak,
         _model_bytes(lambda a, b: saxpy(2.0, a, b, use_pallas=False),
                      x, y))

    # -- saxpy (record form: layout-polymorphic storage) --------------------
    from repro.core import Layout, RecordArray
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    rec = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(rng.standard_normal(n, dtype=np.float32)),
         "y": jnp.asarray(rng.standard_normal(n, dtype=np.float32))},
        Layout.SOA)
    ms = time_fn(lambda r: saxpy_record(r, 2.0, use_pallas=False).data, rec)
    _row(csv, "saxpy_record", n, ms, 2 * rec.data.nbytes, peak,
         _model_bytes(lambda r: saxpy_record(r, 2.0,
                                             use_pallas=False).data, rec))

    # -- particle motion ----------------------------------------------------
    from repro.kernels.particle.ops import PARTICLE_SPEC, particle_update

    prec = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(
            rng.standard_normal((particle_n, 3), dtype=np.float32)),
         "v": jnp.asarray(
             rng.standard_normal((particle_n, 3), dtype=np.float32))},
        Layout.SOA)
    ms = time_fn(lambda r: particle_update(r, 0.25, use_pallas=False).data,
                 prec)
    _row(csv, "particle", particle_n, ms, 2 * prec.data.nbytes, peak,
         _model_bytes(lambda r: particle_update(r, 0.25,
                                                use_pallas=False).data,
                      prec))

    # -- stencil (FORCE flux over the Euler record) -------------------------
    from repro.core import Boundary, pad_boundary_only
    from repro.kernels.stencil.ops import flux_difference
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    d = shock_bubble_init(*flux_shape)
    for ax in (1, 2):
        d = pad_boundary_only(d, axis=ax, width=1,
                              boundary=Boundary.TRANSMISSIVE)
    frec = RecordArray(d, EULER_SPEC, Layout.SOA)
    ms = time_fn(lambda r: flux_difference(r, 0.1, 0.1).data, frec)
    interior = frec.data.nbytes * math.prod(flux_shape) / \
        math.prod(s + 2 for s in flux_shape)
    _row(csv, "flux", f"{flux_shape[0]}x{flux_shape[1]}", ms,
         int(frec.data.nbytes + interior), peak,
         _model_bytes(lambda r: flux_difference(r, 0.1, 0.1).data, frec))

    rows = csv.dicts()
    assert peak > 0, "copy-peak measurement failed"
    assert all(float(r["achieved_gbps"]) > 0 for r in rows), rows
    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"copy_peak_gbps": peak,
                       "ref_hbm_gbps": HBM_BW / 1e9,
                       "rows": rows, "unix_time": time.time()},
                      fh, indent=2)
        print(f"[roofline] wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--n", type=int, default=1 << 20)
    args = ap.parse_args()
    try:
        main(n=args.n, json_path=args.json)
    except AssertionError as exc:
        print(f"[roofline] FAILED: {exc}", file=sys.stderr)
        sys.exit(1)
