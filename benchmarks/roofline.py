"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Reads the JSON written by ``python -m repro.launch.dryrun --all --out X``
and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / peak_FLOP/s              (per chip)
  memory term     = HLO_bytes / HBM_bw                   (per chip)
  collective term = collective_link_bytes / link_bw      (per chip)

Hardware constants (TPU v5e class, per the brief): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.  The dominant term is the bottleneck;
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train cells gives
the useful-compute ratio.
"""

from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s/link (ICI); pod axis rides DCN (slower)

# active params per token (N or N_active), from configs at import time
def _active_params():
    import repro.configs as C
    from repro.models.lm import param_count
    out = {}
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        n = param_count(cfg, tp=1)
        if cfg.n_experts:
            # active = total - (all experts) + (top_k experts + dense)
            per_expert = 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
            n_active = n - cfg.n_experts * per_expert \
                + cfg.top_k * per_expert
            out[arch] = (n, n_active)
        else:
            out[arch] = (n, n)
    return out


def terms(rec: dict) -> dict:
    t_c = rec["flops"] / PEAK_FLOPS
    t_m = rec["bytes_accessed"] / HBM_BW
    t_l = rec["collective_link_bytes"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])
    bound = max(t_c, t_m, t_l)
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
            "dominant": dom[0], "step_lower_bound_s": bound,
            "roofline_fraction": t_c / bound if bound > 0 else 0.0}


def model_flops(arch: str, shape_name: str, devices: int,
                active: dict) -> float:
    from repro.models.config import SHAPES
    shape = SHAPES[shape_name]
    n, n_active = active[arch]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / devices
    return 2.0 * n_active * shape.global_batch / devices  # decode: 1 token


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="dryrun_results.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    with open(args.records) as f:
        recs = json.load(f)
    active = _active_params()
    rows = []
    hdr = (f"{'arch':24s} {'shape':11s} {'mesh':8s} {'compute_s':>9s} "
           f"{'memory_s':>9s} {'collect_s':>9s} {'bound':>10s} "
           f"{'MF/HLO':>7s} {'roofl%':>7s}")
    print(hdr)
    for rec in recs:
        if not rec.get("ok"):
            print(f"{rec['arch']:24s} {rec['shape']:11s} {rec['mesh']:8s} "
                  f"FAILED: {rec.get('error', '?')[:60]}")
            continue
        t = terms(rec)
        mf = model_flops(rec["arch"], rec["shape"], rec["devices"], active)
        ratio = mf / rec["flops"] if rec["flops"] else 0.0
        rows.append({**rec, **t, "model_flops": mf, "useful_ratio": ratio})
        print(f"{rec['arch']:24s} {rec['shape']:11s} {rec['mesh']:8s} "
              f"{t['compute_s']:9.3f} {t['memory_s']:9.3f} "
              f"{t['collective_s']:9.3f} {t['dominant']:>10s} "
              f"{ratio:7.2f} {t['roofline_fraction']*100:6.1f}%")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
