"""Measured autotuner acceptance table — heuristic vs tuned per workload.

For each workload the same graph is executed through (a) the heuristic
plan (``tune="off"``: the PR-1 layout solver + default kernel tiles) and
(b) the measured-tuned plan (``tune="auto"``: the argmin over the
halo-feasible layout set × each kernel's ``tile_candidates()``, timed as
real region-executable executions).  Steady-state per-call medians come
from the shared ``time_fn_split`` harness.

Every workload declares its record storage AoS — the layout the paper's
measurements show losing on vector hardware — so the heuristic default
is deliberately beatable and the table demonstrates the tuner earning
its keep.  Hard acceptance asserts: tuned is never worse than heuristic
beyond noise on ANY workload, and strictly faster on at least one.

  PYTHONPATH=src python -m benchmarks.table_tuned [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import DistTensor, Executor, Graph, Layout, RecordArray
from .common import Csv, time_fn_split

STEPS = 4            # graph steps per timed call
NOISE = 1.25         # "never worse beyond noise" multiplier
STRICT = 0.95        # "strictly faster" threshold on >= 1 workload


def _saxpy_workload(n=1 << 14):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    rng = np.random.default_rng(0)
    r = DistTensor("r", (n,), spec=SAXPY_SPEC, layout=Layout.AOS)
    g = Graph(name="tuned_saxpy")
    g.split(lambda rec: saxpy_record(rec, 2.0), r, writes=(0,))
    init = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(rng.standard_normal(n, dtype=np.float32)),
         "y": jnp.asarray(rng.standard_normal(n, dtype=np.float32))},
        Layout.AOS)
    return g, {"r": init}


def _particle_workload(n=16_384):
    from repro.kernels.particle.kernel import PARTICLE_SPEC
    from repro.kernels.particle.ops import particle_update

    rng = np.random.default_rng(1)
    p = DistTensor("p", (n,), spec=PARTICLE_SPEC, layout=Layout.AOS)
    g = Graph(name="tuned_particle")
    g.split(lambda rec: particle_update(rec, 0.25), p, writes=(0,))
    init = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32)),
         "v": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32))},
        Layout.AOS)
    return g, {"p": init}


def _flux_workload(shape=(64, 128)):
    from repro.kernels.stencil.ops import make_flux_difference_graph
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    nx, ny = shape
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.AOS,
                   halo=(1, 1))
    out = DistTensor("flux_out", (nx, ny), spec=EULER_SPEC,
                     layout=Layout.AOS)
    g = make_flux_difference_graph(u, out, 0.1, 0.1, overlap=False,
                                   use_pallas=True)
    init = RecordArray(shock_bubble_init(nx, ny), EULER_SPEC, Layout.SOA)
    return g, {"u": init}


WORKLOADS = [
    ("saxpy-record", _saxpy_workload),
    ("particle", _particle_workload),
    ("flux-stencil", _flux_workload),
]


def _bench(graph, inputs):
    """(heuristic steady ms, tuned steady ms, tuned Executor)."""
    heur = Executor(graph, donate=False)
    s0 = heur.init_state(**inputs)
    _, heur_ms = time_fn_split(lambda: heur.run(dict(s0), STEPS))

    tuned = Executor(graph, donate=False, tune="auto", tune_inputs=inputs)
    s1 = tuned.init_state(**inputs)
    _, tuned_ms = time_fn_split(lambda: tuned.run(dict(s1), STEPS))
    return heur_ms, tuned_ms, tuned


def main() -> list[dict]:
    from repro.tuning import STATS

    csv = Csv("workload", "heuristic_ms", "tuned_ms", "speedup",
              "tuned_layouts", "tuned_tiles", "n_measured")
    ratios = {}
    with tempfile.TemporaryDirectory(prefix="repro-tune-bench-") as tmp:
        # hermetic cache: the table measures tuning, not a stale cache
        prev = os.environ.get("REPRO_TUNE_CACHE")
        os.environ["REPRO_TUNE_CACHE"] = tmp
        try:
            for name, make in WORKLOADS:
                graph, inputs = make()
                before = STATS["measurements"]
                heur_ms, tuned_ms, tuned = _bench(graph, inputs)
                dec = tuned.plan.tuning
                lays = ";".join(f"{k}={v.name}"
                                for k, v in sorted(dec.layouts.items())) \
                    or "-"
                tiles = ";".join(f"{k}={v}"
                                 for k, v in sorted(dec.tiles.items())) \
                    or "-"
                csv.row(name, heur_ms, tuned_ms,
                        heur_ms / max(tuned_ms, 1e-9), lays, tiles,
                        STATS["measurements"] - before)
                ratios[name] = tuned_ms / max(heur_ms, 1e-9)
        finally:
            if prev is None:
                os.environ.pop("REPRO_TUNE_CACHE", None)
            else:
                os.environ["REPRO_TUNE_CACHE"] = prev

    # acceptance: never worse beyond noise, strictly faster somewhere
    worse = {k: r for k, r in ratios.items() if r > NOISE}
    assert not worse, (
        f"tuned config slower than heuristic beyond noise: {worse}")
    assert any(r < STRICT for r in ratios.values()), (
        f"tuned config not strictly faster on any workload: {ratios}")
    print(f"[table_tuned] acceptance OK: ratios (tuned/heuristic) "
          f"{ {k: round(v, 3) for k, v in ratios.items()} }")
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as JSON")
    args = ap.parse_args()
    rows = main()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suite": "table_tuned", "rows": rows}, f, indent=2)
        print(f"[table_tuned] wrote {args.json}")
    sys.exit(0)
