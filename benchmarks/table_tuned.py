"""Measured autotuner acceptance table — heuristic vs tuned per workload.

For each workload the same graph is executed through (a) the heuristic
plan (``tune="off"``: the PR-1 layout solver + default kernel tiles) and
(b) the measured-tuned plan (``tune="auto"``: the joint search over
per-record-key layouts x per-kernel tiles, HLO-cost-ranked so only the
top of the proposed space is ever timed — see ``repro/tuning/search.py``
and docs/tuning.md).

Determinism over raw speed asserts: every workload is seeded (the same
initial arrays every run), and the heuristic/tuned comparison is the
MEDIAN of ``REPEATS`` interleaved steady-state measurements rather than
a single ``time_fn_split`` sample, so a one-off scheduler hiccup cannot
flip the acceptance gate.  Every workload declares its record storage
AoS — the layout the paper's measurements show losing on vector
hardware — so the heuristic default is deliberately beatable and the
table demonstrates the tuner earning its keep.

Hard acceptance asserts:

* tuned is never worse than heuristic beyond noise on ANY workload and
  strictly faster on at least one;
* the pruned joint search measures at most ``MAX_MEASURE_FRAC`` (40%)
  of the proposed candidate space overall — the HLO cost ranking is
  really pruning, not rubber-stamping.

Rows report the search-space accounting (proposed / pruned / measured)
straight from each workload's ``TuningDecision`` — the same numbers
``describe_tuning()`` prints — so the JSON artifact documents how much
measurement the cost model saved.

  PYTHONPATH=src python -m benchmarks.table_tuned [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import DistTensor, Executor, Graph, Layout, RecordArray
from .common import Csv, time_fn_split

STEPS = 4            # graph steps per timed call
REPEATS = 5          # median-of-k steady samples per executor
NOISE = 1.25         # "never worse beyond noise" multiplier
STRICT = 0.95        # "strictly faster" threshold on >= 1 workload
MAX_MEASURE_FRAC = 0.40   # pruning gate: measured / proposed overall


def _saxpy_workload(n=1 << 14):
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    rng = np.random.default_rng(0)
    r = DistTensor("r", (n,), spec=SAXPY_SPEC, layout=Layout.AOS)
    g = Graph(name="tuned_saxpy")
    g.split(lambda rec: saxpy_record(rec, 2.0), r, writes=(0,))
    init = RecordArray.from_fields(
        SAXPY_SPEC,
        {"x": jnp.asarray(rng.standard_normal(n, dtype=np.float32)),
         "y": jnp.asarray(rng.standard_normal(n, dtype=np.float32))},
        Layout.AOS)
    return g, {"r": init}


def _particle_workload(n=16_384):
    from repro.kernels.particle.kernel import PARTICLE_SPEC
    from repro.kernels.particle.ops import particle_update

    rng = np.random.default_rng(1)
    p = DistTensor("p", (n,), spec=PARTICLE_SPEC, layout=Layout.AOS)
    g = Graph(name="tuned_particle")
    g.split(lambda rec: particle_update(rec, 0.25), p, writes=(0,))
    init = RecordArray.from_fields(
        PARTICLE_SPEC,
        {"x": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32)),
         "v": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32))},
        Layout.AOS)
    return g, {"p": init}


def _flux_workload(shape=(64, 128)):
    from repro.kernels.stencil.ops import make_flux_difference_graph
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    nx, ny = shape
    u = DistTensor("u", (nx, ny), spec=EULER_SPEC, layout=Layout.AOS,
                   halo=(1, 1))
    out = DistTensor("flux_out", (nx, ny), spec=EULER_SPEC,
                     layout=Layout.AOS)
    g = make_flux_difference_graph(u, out, 0.1, 0.1, overlap=False,
                                   use_pallas=True)
    init = RecordArray(shock_bubble_init(nx, ny), EULER_SPEC, Layout.SOA)
    return g, {"u": init}


WORKLOADS = [
    ("saxpy-record", _saxpy_workload),
    ("particle", _particle_workload),
    ("flux-stencil", _flux_workload),
]


def _bench(graph, inputs):
    """(heuristic median ms, tuned median ms, tuned Executor).

    The tuned executor is built first (its construction runs the joint
    search); then REPEATS interleaved heuristic/tuned steady samples are
    taken so slow clock drift hits both sides equally, and each side
    reports its median."""
    heur = Executor(graph, donate=False)
    tuned = Executor(graph, donate=False, tune="auto", tune_inputs=inputs)
    s0 = heur.init_state(**inputs)
    s1 = tuned.init_state(**inputs)
    heur_ms, tuned_ms = [], []
    for _ in range(REPEATS):
        _, h = time_fn_split(lambda: heur.run(dict(s0), STEPS))
        _, t = time_fn_split(lambda: tuned.run(dict(s1), STEPS))
        heur_ms.append(h)
        tuned_ms.append(t)
    return float(np.median(heur_ms)), float(np.median(tuned_ms)), tuned


def main() -> list[dict]:
    csv = Csv("workload", "heuristic_ms", "tuned_ms", "speedup",
              "tuned_layouts", "tuned_tiles", "proposed", "pruned",
              "measured")
    ratios = {}
    totals = {"proposed": 0, "measured": 0}
    with tempfile.TemporaryDirectory(prefix="repro-tune-bench-") as tmp:
        # hermetic cache: the table measures tuning, not a stale cache
        prev = os.environ.get("REPRO_TUNE_CACHE")
        os.environ["REPRO_TUNE_CACHE"] = tmp
        try:
            for name, make in WORKLOADS:
                graph, inputs = make()
                heur_ms, tuned_ms, tuned = _bench(graph, inputs)
                dec = tuned.plan.tuning
                lays = ";".join(f"{k}={v.name}"
                                for k, v in sorted(dec.layouts.items())) \
                    or "-"
                tiles = ";".join(f"{k}={v}"
                                 for k, v in sorted(dec.tiles.items())) \
                    or "-"
                csv.row(name, heur_ms, tuned_ms,
                        heur_ms / max(tuned_ms, 1e-9), lays, tiles,
                        dec.proposed, dec.pruned, dec.measured)
                ratios[name] = tuned_ms / max(heur_ms, 1e-9)
                totals["proposed"] += dec.proposed
                totals["measured"] += dec.measured
        finally:
            if prev is None:
                os.environ.pop("REPRO_TUNE_CACHE", None)
            else:
                os.environ["REPRO_TUNE_CACHE"] = prev

    # acceptance: never worse beyond noise, strictly faster somewhere
    worse = {k: r for k, r in ratios.items() if r > NOISE}
    assert not worse, (
        f"tuned config slower than heuristic beyond noise: {worse}")
    assert any(r < STRICT for r in ratios.values()), (
        f"tuned config not strictly faster on any workload: {ratios}")
    # acceptance: the cost model really pruned the joint space
    frac = totals["measured"] / max(totals["proposed"], 1)
    assert frac <= MAX_MEASURE_FRAC, (
        f"pruned search measured {totals['measured']}/{totals['proposed']} "
        f"= {frac:.1%} of the proposed space (gate: "
        f"{MAX_MEASURE_FRAC:.0%})")
    print(f"[table_tuned] acceptance OK: ratios (tuned/heuristic) "
          f"{ {k: round(v, 3) for k, v in ratios.items()} }, measured "
          f"{totals['measured']}/{totals['proposed']} = {frac:.1%} of "
          f"proposed space")
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as JSON")
    args = ap.parse_args()
    rows = main()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suite": "table_tuned", "rows": rows}, f, indent=2)
        print(f"[table_tuned] wrote {args.json}")
    sys.exit(0)
