"""Benchmark utilities: timing + CSV emission.

CPU wall-clock numbers are DIRECTIONAL ONLY (the paper measured V100s;
this container is one CPU core) — every table also emits the structural
metric that transfers to TPU (bytes moved / FLOPs / layout effect ratios),
derived from the loop-aware HLO analysis where relevant.
"""

from __future__ import annotations

import time

import jax


def time_fn_split(fn, *args, iters: int = 5, warmup: int = 2,
                  **kw) -> tuple[float, float]:
    """``(first_ms, steady_ms)`` — the first call (which pays trace +
    compile) timed separately from the steady-state median, so tables
    never mix one-off compilation cost into per-step numbers.

    ``warmup`` counts total pre-measurement calls (the first, timed one
    included); ``steady_ms`` is the median of ``iters`` calls after it."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    first = (time.perf_counter() - t0) * 1e3
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return first, times[len(times) // 2]


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw) -> float:
    """Median steady-state wall-time per call in ms (jit-compatible:
    blocks on result; compilation excluded — see :func:`time_fn_split`)."""
    return time_fn_split(fn, *args, iters=iters, warmup=warmup, **kw)[1]


class Csv:
    def __init__(self, *cols: str):
        self.cols = cols
        self.rows: list[tuple] = []
        print(",".join(cols), flush=True)

    def row(self, *vals) -> None:
        vals = tuple(f"{v:.4f}" if isinstance(v, float) else str(v)
                     for v in vals)
        self.rows.append(vals)
        print(",".join(vals), flush=True)

    def dicts(self) -> list[dict]:
        """Rows as JSON-ready records (``benchmarks.run --json``)."""
        return [dict(zip(self.cols, r)) for r in self.rows]
