"""Benchmark utilities: timing + CSV emission.

CPU wall-clock numbers are DIRECTIONAL ONLY (the paper measured V100s;
this container is one CPU core) — every table also emits the structural
metric that transfers to TPU (bytes moved / FLOPs / layout effect ratios),
derived from the loop-aware HLO analysis where relevant.

The timing harness itself lives in ``repro.tuning.timing`` — the SAME
split-timing implementation the measured autotuner uses, re-exported
here so every table and the tuner report comparable numbers.
"""

from __future__ import annotations

from repro.tuning.timing import time_fn, time_fn_split  # noqa: F401

__all__ = ["time_fn", "time_fn_split", "Csv", "gbps"]


def gbps(nbytes: float, ms: float) -> float:
    """Achieved bandwidth in GB/s from known bytes-moved and measured
    milliseconds — the roofline-comparable number every table row
    reports next to its wall-clock (see roofline.HBM_BW for the peak
    the fraction is taken against on the reference TPU)."""
    return nbytes / max(ms * 1e-3, 1e-12) / 1e9


class Csv:
    def __init__(self, *cols: str):
        self.cols = cols
        self.rows: list[tuple] = []
        print(",".join(cols), flush=True)

    def row(self, *vals) -> None:
        vals = tuple(f"{v:.4f}" if isinstance(v, float) else str(v)
                     for v in vals)
        self.rows.append(vals)
        print(",".join(vals), flush=True)

    def dicts(self) -> list[dict]:
        """Rows as JSON-ready records (``benchmarks.run --json``)."""
        return [dict(zip(self.cols, r)) for r in self.rows]
