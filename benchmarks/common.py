"""Benchmark utilities: timing + CSV emission.

CPU wall-clock numbers are DIRECTIONAL ONLY (the paper measured V100s;
this container is one CPU core) — every table also emits the structural
metric that transfers to TPU (bytes moved / FLOPs / layout effect ratios),
derived from the loop-aware HLO analysis where relevant.
"""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall-time per call in ms (jit-compatible: blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return times[len(times) // 2]


class Csv:
    def __init__(self, *cols: str):
        self.cols = cols
        self.rows: list[tuple] = []
        print(",".join(cols), flush=True)

    def row(self, *vals) -> None:
        vals = tuple(f"{v:.4f}" if isinstance(v, float) else str(v)
                     for v in vals)
        self.rows.append(vals)
        print(",".join(vals), flush=True)

    def dicts(self) -> list[dict]:
        """Rows as JSON-ready records (``benchmarks.run --json``)."""
        return [dict(zip(self.cols, r)) for r in self.rows]
