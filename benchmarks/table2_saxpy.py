"""Paper Table 2 — SAXPY: iterator (bounds-check) overhead.

The paper compares Ripple vs Ripple-NBC (no boundary check) vs cuBLAS /
Kokkos.  Here: Pallas kernel (interpret) with and without the masked tail
vs the pure-jnp oracle (the 'cuBLAS' stand-in), plus the structural
metric: bytes moved per element is identical, so any delta IS the check.
"""

import jax.numpy as jnp
import numpy as np

from repro.kernels.saxpy.ops import saxpy
from .common import Csv, gbps, time_fn, time_fn_split


def main(sizes=(1 << 20, 4 << 20, 16 << 20)) -> list[dict]:
    csv = Csv("size", "first_call_ms", "ref_ms", "pallas_checked_ms",
              "pallas_nbc_ms", "check_overhead_pct", "ref_gbps", "nbc_gbps")
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
        y = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
        t_ref = time_fn(saxpy, 2.0, x, y, use_pallas=False)
        first, t_chk = time_fn_split(saxpy, 2.0, x, y, bounds_check=True)
        t_nbc = time_fn(saxpy, 2.0, x, y, bounds_check=False)
        over = (t_chk - t_nbc) / max(t_nbc, 1e-9) * 100
        # known bytes per pass: read x, read y, write out — 3 f32 streams
        nbytes = 3 * n * 4
        csv.row(n, first, t_ref, t_chk, t_nbc, over,
                gbps(nbytes, t_ref), gbps(nbytes, t_nbc))
    return csv.dicts()


if __name__ == "__main__":
    main()
