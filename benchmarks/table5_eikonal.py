"""Paper Table 5 — Eikonal FIM: compute-bound kernel, VMEM-staged sweeps.

The paper's knob is shared-memory staging + layout; ours is the Pallas
block shape and the number of inner sweep iterations per block (the
'cells in the band' analogue).
"""

import jax.numpy as jnp
import numpy as np

from repro.core import pad_boundary_only
from repro.kernels.eikonal.ops import eikonal_fim_sweep
from .common import Csv, gbps, time_fn_split


def main(sizes=(256, 512), inners=(2, 4, 8)) -> list[dict]:
    csv = Csv("size", "inner_sweeps", "first_call_ms", "cpu_ms",
              "achieved_gbps")
    for n in sizes:
        phi = jnp.full((n, n), 1e3, jnp.float32)
        src = jnp.zeros((n, n), bool).at[n // 2, n // 2].set(True)
        phi = jnp.where(src, 0.0, phi)
        ph = pad_boundary_only(pad_boundary_only(phi, axis=0, width=1),
                               axis=1, width=1)
        # known bytes per sweep: read+write padded phi, read the source mask
        nbytes = 2 * ph.nbytes + src.nbytes
        for inner in inners:
            first, t = time_fn_split(eikonal_fim_sweep, ph, src, 1.0 / n,
                                     inner=inner, iters=3)
            csv.row(n, inner, first, t, gbps(nbytes, t))
    return csv.dicts()


if __name__ == "__main__":
    main()
