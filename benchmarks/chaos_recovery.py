"""Chaos recovery benchmark: what does a fault actually COST?

Injects deterministic transient faults (``repro.runtime.faults``) into
the two fault-tolerant loops and measures the recovery bill:

* **supervisor** — a checkpointed training loop takes faults at three
  step coordinates (before the first checkpoint, mid-interval, and just
  after a save).  Reported: steps replayed (re-executed after restores)
  and the per-fault recovery latency (``Supervisor.recoveries``: wall
  time from the failure until the failed step completes), mean and p99;
* **serving** — a continuous-batching load takes admission + mid-decode
  faults; the Batcher's request-log replay must produce token streams
  exactly equal to the fault-free run.  Reported: injected failures and
  the wall-clock overhead vs the clean run of the same load.

``--json BENCH_9.json`` writes the row data — the chaos entry in the
tracked BENCH trajectory.
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import Csv


def bench_supervisor(tmpdir, num_steps=40, ckpt_every=10) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.runtime import Supervisor
    from repro.runtime.faults import Fault, FaultPlan, RetryPolicy, fault_scope

    executed = {"n": 0}

    @jax.jit
    def _step(x, b):
        return x + b

    def step_fn(state, batch):
        executed["n"] += 1
        return {"x": _step(state["x"], batch)}

    # fault coordinates: before any checkpoint exists (in-place replay),
    # mid-interval (replays ckpt_every-ish steps), right after a save
    plan = FaultPlan([Fault("supervisor.step", step=7),
                      Fault("supervisor.step", step=18),
                      Fault("supervisor.step", step=31)])
    sup = Supervisor(step_fn=step_fn,
                     ckpt=CheckpointManager(str(tmpdir / "ck")),
                     ckpt_every=ckpt_every, log=lambda *_: None,
                     retry=RetryPolicy(base_delay=0.005, max_delay=0.05))
    t0 = time.perf_counter()
    with fault_scope(plan):
        state = sup.run({"x": jnp.zeros(())}, lambda i: jnp.asarray(1.0),
                        0, num_steps)
    wall = time.perf_counter() - t0
    assert plan.exhausted(), plan.report()
    assert float(state["x"]) == float(num_steps), float(state["x"])
    assert len(sup.recoveries) == len(plan.faults), sup.recoveries

    rec_ms = np.asarray([ms for _, _, ms in sup.recoveries])
    return dict(
        scenario="supervisor", steps=num_steps, ckpt_every=ckpt_every,
        faults=len(plan.faults), failures=sup.failures,
        steps_replayed=executed["n"] - num_steps,
        mean_recovery_ms=float(rec_ms.mean()),
        p99_recovery_ms=float(np.percentile(rec_ms, 99)),
        wall_s=wall,
    )


def bench_serving(arch="qwen3_8b", slots=2, n_requests=4,
                  prompt_len=8, gen=8) -> dict:
    import repro.configs as configs
    from repro.models.lm import init_lm
    from repro.runtime.batcher import Batcher
    from repro.runtime.faults import Fault, FaultPlan, RetryPolicy, fault_scope

    cfg = configs.get_smoke(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    retry = RetryPolicy(base_delay=0.005, max_delay=0.05)

    def serve(plan=None):
        rng = np.random.default_rng(0)
        b = Batcher(cfg, params, batch=slots, max_seq=prompt_len + gen,
                    log=lambda *_: None, retry=retry)
        reqs = [b.submit(rng.integers(1, cfg.vocab_size,
                                      (prompt_len,)).astype(np.int32),
                         max_new_tokens=gen) for _ in range(n_requests)]
        t0 = time.perf_counter()
        if plan is None:
            b.run()
        else:
            with fault_scope(plan):
                b.run()
        return time.perf_counter() - t0, [r.generated for r in reqs], b

    # warm the executable cache, then time a clean reference run
    serve()
    clean_s, want, _ = serve()

    plan = FaultPlan([Fault("batcher.admit", step=0),
                      Fault("batcher.step", step=2, times=2),
                      Fault("batcher.step", step=5)])
    faulted_s, got, b = serve(plan)
    assert plan.exhausted(), plan.report()
    assert got == want, "faulted token streams diverged"

    return dict(
        scenario="serving", slots=slots, requests=n_requests,
        prompt_len=prompt_len, gen=gen,
        faults=len(plan.faults), failures=b.failures,
        clean_wall_s=clean_s, faulted_wall_s=faulted_s,
        recovery_overhead_ms=(faulted_s - clean_s) * 1e3,
    )


def main(num_steps=40, json_path=None) -> list[dict]:
    import tempfile
    from pathlib import Path

    with tempfile.TemporaryDirectory() as td:
        sup = bench_supervisor(Path(td), num_steps=num_steps)
    srv = bench_serving()
    rows = [sup, srv]

    csv = Csv("scenario", "faults", "failures", "steps_replayed",
              "mean_recovery_ms", "p99_recovery_ms",
              "recovery_overhead_ms")
    csv.row(sup["scenario"], sup["faults"], sup["failures"],
            sup["steps_replayed"], sup["mean_recovery_ms"],
            sup["p99_recovery_ms"], "")
    csv.row(srv["scenario"], srv["faults"], srv["failures"], "",
            "", "", srv["recovery_overhead_ms"])

    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"rows": rows, "unix_time": time.time()},
                      fh, indent=2)
        print(f"[chaos_recovery] wrote {json_path}")

    # hard gates (CI chaos-smoke): every scheduled fault fired and was
    # recovered (asserted above); replay never exceeds one checkpoint
    # interval per restore-based recovery
    assert sup["steps_replayed"] <= sup["faults"] * sup["ckpt_every"], sup
    return csv.dicts()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    try:
        main(num_steps=args.steps, json_path=args.json)
    except AssertionError as exc:
        print(f"[chaos_recovery] FAILED: {exc}", file=sys.stderr)
        sys.exit(1)
