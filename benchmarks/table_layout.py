"""Paper Tables 2/3 layout axis — AoS vs SoA vs AoSoA per kernel.

The paper's headline measurement: the SAME kernel body over the three
storage layouts, so any timing delta is purely data movement.  Reported
per kernel: median ms per layout, the AoS/SoA gap ratio, and the one-off
relayout cost (what the executor's layout solver pays when it inserts a
boundary conversion).

CPU wall-clock is directional only (see common.py) — the structural
result that transfers to TPU is the *ordering* and the relayout cost
relative to one kernel invocation.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Boundary, Layout, RecordArray, pad_boundary_only, relayout
from .common import Csv, gbps, time_fn, time_fn_split

LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)


def _bench_kernel(csv, kernel_name, n_label, make_rec, run):
    base = make_rec(Layout.SOA)
    times, firsts, outs = {}, {}, {}
    for lay in LAYOUTS:
        rec = relayout(base, lay)
        # nothing ran this layout yet, so 'first' is a genuinely cold
        # trace+compile call for every column (the SoA reference is
        # computed afterwards, from the already-warm kernel)
        firsts[lay], times[lay] = time_fn_split(run, rec)
        outs[lay] = run(rec).to_fields()
    ref = {k: np.asarray(v) for k, v in outs[Layout.SOA].items()}
    for lay in LAYOUTS:
        for name, want in ref.items():  # every field, incl. the written one
            np.testing.assert_allclose(np.asarray(outs[lay][name]), want,
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{lay}:{name}")
    t_relayout = time_fn(lambda r: relayout(r, Layout.AOS).data, base)
    # known bytes per invocation: read + write the whole record storage
    nbytes = 2 * base.data.nbytes
    csv.row(kernel_name, n_label,
            firsts[Layout.AOS], firsts[Layout.SOA], firsts[Layout.AOSOA],
            times[Layout.AOS], times[Layout.SOA], times[Layout.AOSOA],
            times[Layout.AOS] / max(times[Layout.SOA], 1e-9), t_relayout,
            gbps(nbytes, min(times.values())))


def main(saxpy_n=1 << 18, particle_n=65_536, flux_shape=(128, 128)) -> list[dict]:
    csv = Csv("kernel", "size", "aos_first_ms", "soa_first_ms",
              "aosoa_first_ms", "aos_ms", "soa_ms", "aosoa_ms",
              "aos_over_soa", "relayout_ms", "best_gbps")
    rng = np.random.default_rng(0)

    # -- saxpy (record form) -------------------------------------------------
    from repro.kernels.saxpy.kernel import SAXPY_SPEC
    from repro.kernels.saxpy.ops import saxpy_record

    def make_saxpy(layout):
        return RecordArray.from_fields(
            SAXPY_SPEC,
            {"x": jnp.asarray(rng.standard_normal(saxpy_n, dtype=np.float32)),
             "y": jnp.asarray(rng.standard_normal(saxpy_n,
                                                  dtype=np.float32))},
            layout)

    _bench_kernel(csv, "saxpy", saxpy_n, make_saxpy,
                  lambda r: saxpy_record(r, 2.0))

    # -- particle motion -----------------------------------------------------
    from repro.kernels.particle.ops import PARTICLE_SPEC, particle_update

    def make_particle(layout):
        return RecordArray.from_fields(
            PARTICLE_SPEC,
            {"x": jnp.asarray(
                rng.standard_normal((particle_n, 3), dtype=np.float32)),
             "v": jnp.asarray(
                 rng.standard_normal((particle_n, 3), dtype=np.float32))},
            layout)

    _bench_kernel(csv, "particle", particle_n, make_particle,
                  lambda r: particle_update(r, 0.25))

    # -- stencil (FORCE flux) ------------------------------------------------
    from repro.kernels.stencil.ops import flux_difference
    from repro.physics.euler import EULER_SPEC, shock_bubble_init

    def make_flux(layout):
        d = shock_bubble_init(*flux_shape)
        for ax in (1, 2):
            d = pad_boundary_only(d, axis=ax, width=1,
                                  boundary=Boundary.TRANSMISSIVE)
        return relayout(RecordArray(d, EULER_SPEC, Layout.SOA), layout)

    _bench_kernel(csv, "flux", f"{flux_shape[0]}x{flux_shape[1]}", make_flux,
                  lambda r: flux_difference(r, 0.1, 0.1))
    return csv.dicts()


if __name__ == "__main__":
    main()
