"""Dependency-aware DAG scheduling of Ripple graphs (paper §5.3/§6).

The paper's central claim is that a simple graph description lets the
runtime schedule work and transfers from *real data dependencies* rather
than program order.  The :class:`~repro.core.graph.Graph` builder records
a level structure (program order); this module recovers the true
dependency DAG from each node's access footprint and re-schedules it:

* :func:`build_dag` flattens a graph (inlining non-conditional
  subgraphs, keeping conditional subgraphs as single ``loop`` vertices)
  into :class:`DagUnit` s and derives :class:`DagEdge` s from the
  read/write state-key sets — RAW (true dependency), WAW (output
  ordering) and WAR (anti-dependency, because the executor updates state
  buffers in place).  Nodes on the same builder level are independent by
  the paper's contract (they execute against a shared snapshot), so no
  edges are created between them.
* :func:`dag_segments` list-schedules the DAG into executor segments:
  every *antichain* of ready device units becomes one wave, consecutive
  waves fuse into a single jitted segment (XLA's latency-hiding scheduler
  then overlaps the independent nodes and their halo collectives), and
  host / sync / loop vertices are emitted only where a dependency path
  actually forces a jit break.  Relayout steps and halo-transfer blocks
  attach at segment entry, so fusing two program levels into one segment
  hoists a consumer's transfers to the earliest point its producer is
  ready.
* :func:`sequential_segments` is the legacy program-order segmentation
  (every level boundary is a barrier, every host node splits the chain)
  — the ``schedule="sequential"`` escape hatch and the reference
  semantics the property tests compare against.

Conservative footprints keep the schedule sound where the graph cannot
be introspected:

* a ``conditional`` subgraph's predicate is an opaque callable over the
  state dict, so loop vertices read *everything* (:data:`READS_ANY`);
* ``sync()`` is a full barrier by contract;
* a host node without tensor args has an invisible footprint and is
  pinned as a barrier too;
* host vertices keep their relative program order (side effects).
"""

from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass, field as dfield
from typing import Optional

from .graph import ExecutionKind, Graph, Node, TensorArg
from .tensor import DistTensor, ReductionResult

__all__ = [
    "READS_ANY",
    "DagUnit",
    "DagEdge",
    "Region",
    "RegionEdge",
    "ScheduleDag",
    "node_access",
    "graph_access",
    "build_dag",
    "dag_segments",
    "group_regions",
    "region_access",
    "region_dag",
    "region_waves",
    "sequential_segments",
    "place_units",
]

# Sentinel state key: the unit may read ANY state entry (opaque predicate
# or callback); it conflicts with every writer.
READS_ANY = "<any>"


def node_access(node: Node) -> tuple[frozenset, frozenset]:
    """The (reads, writes) state-key footprint of one non-subgraph node.

    Reads are every tensor / reduction-result argument (a written tensor
    is also passed to the node fn, so it counts as read — conservative
    and correct for pure-output args).  Writes are the ``writes``
    argument indices for device op/split nodes and the result slot for
    reduce nodes.  Host nodes never store writes (the executor calls
    their fn for its side effects only), so their write set is empty.
    """
    if node.kind == "reduce":
        t, _field = node.args
        return frozenset({t.name}), frozenset({node.result.name})
    reads = set()
    for a in node.args:
        if isinstance(a, TensorArg):
            reads.add(a.tensor.name)
        elif isinstance(a, DistTensor):
            reads.add(a.name)
        elif isinstance(a, ReductionResult):
            reads.add(a.name)
    writes = set()
    host = node.exec_kind is ExecutionKind.Cpu or node.kind == "sync"
    if not host and node.fn is not None:
        for i in node.default_writes():
            a = node.args[i]
            t = a.tensor if isinstance(a, TensorArg) else a
            if isinstance(t, DistTensor):
                writes.add(t.name)
    return frozenset(reads), frozenset(writes)


def graph_access(g: Graph) -> tuple[frozenset, frozenset]:
    """Union footprint of every node in ``g`` (subgraphs included)."""
    reads, writes = set(), set()
    for node in g.nodes():
        if node.subgraph is not None:
            r, w = graph_access(node.subgraph)
        else:
            r, w = node_access(node)
        reads |= r
        writes |= w
    return frozenset(reads), frozenset(writes)


@dataclass
class DagUnit:
    """One schedulable vertex: a device node, a host/sync node, or a
    whole conditional subgraph (``loop`` / ``host_loop``).

    ``level`` is the flattened builder level — units sharing it execute
    against a common snapshot (the paper's same-level parallelism), so
    they never get edges between each other.  ``segment`` / ``wave`` are
    filled in by the scheduler (or :func:`place_units` for the
    sequential schedule) for introspection.
    """

    uid: int
    kind: str                    # 'device' | 'host' | 'sync' | 'loop' | 'host_loop'
    level: int
    reads: frozenset
    writes: frozenset
    node: Optional[Node] = None
    subgraph: Optional[Graph] = None
    barrier: bool = False        # orders against *everything* (sync, opaque host)
    segment: int = -1
    wave: int = -1

    @property
    def label(self) -> str:
        if self.node is not None:
            return f"{self.node.name}[{self.node.kind}]"
        return f"{self.subgraph.name}[{self.kind}]"

    def _fmt_keys(self, keys) -> str:
        return ",".join(sorted(k if k is not READS_ANY else "*"
                               for k in keys)) or "-"

    def describe(self) -> str:
        return (f"{self.label} reads({self._fmt_keys(self.reads)}) "
                f"writes({self._fmt_keys(self.writes)})")


@dataclass(frozen=True)
class DagEdge:
    """A scheduling constraint ``src -> dst`` (uids, program order).

    ``reason`` is 'raw' (dst reads what src wrote), 'waw', 'war' (dst
    overwrites what src reads — state updates are in place), 'barrier'
    (sync / opaque host node) or 'host-order' (host side effects keep
    program order).  ``key`` names the state entry that carries the
    dependency where one exists.
    """

    src: int
    dst: int
    reason: str
    key: Optional[str] = None


def _conflict(u: DagUnit, v: DagUnit) -> Optional[tuple[str, Optional[str]]]:
    """Data conflict between ``u`` (earlier) and ``v`` (later), if any."""
    def hit(ws, rs):
        if not ws:
            return None
        if READS_ANY in rs:
            return next(iter(sorted(ws)))
        inter = ws & rs
        return next(iter(sorted(inter))) if inter else None

    k = hit(u.writes, v.reads)
    if k is not None:
        return ("raw", k)
    inter = u.writes & v.writes
    if inter:
        return ("waw", next(iter(sorted(inter))))
    k = hit(v.writes, u.reads)
    if k is not None:
        return ("war", k)
    return None


class ScheduleDag:
    """The dependency DAG of one graph plus its (mode-dependent)
    placement into executor segments.

    ``units`` are in program order; ``edges`` always point forward.
    After :func:`dag_segments` or :func:`place_units` each unit carries
    its ``(segment, wave)`` placement and ``segment_kinds`` names each
    segment's kind, which :meth:`describe` renders.
    """

    def __init__(self, graph: Graph, units: list[DagUnit],
                 edges: list[DagEdge]):
        self.graph = graph
        self.units = units
        self.edges = edges
        self.preds: dict[int, set[int]] = {u.uid: set() for u in units}
        self.succs: dict[int, set[int]] = {u.uid: set() for u in units}
        for e in edges:
            self.preds[e.dst].add(e.src)
            self.succs[e.src].add(e.dst)
        self.segment_kinds: list[str] = []

    @property
    def device_only(self) -> bool:
        """True iff every vertex is a device node — the whole graph can
        be fused into one jitted program (and ``Executor.run`` may wrap
        all steps in a single fori_loop)."""
        return all(u.kind == "device" for u in self.units)

    def antichains(self) -> list[list[DagUnit]]:
        """The scheduled waves (unit groups that share a segment+wave),
        in execution order."""
        by_pos: dict[tuple[int, int], list[DagUnit]] = defaultdict(list)
        for u in self.units:
            by_pos[(u.segment, u.wave)].append(u)
        return [sorted(by_pos[k], key=lambda u: u.uid)
                for k in sorted(by_pos)]

    def fused_antichains(self) -> list[list[DagUnit]]:
        """Waves holding >= 2 independent nodes — the fusion the DAG
        schedule found that program order would have serialized (or, for
        same-level nodes, kept but in separate jit dispatches)."""
        return [w for w in self.antichains() if len(w) >= 2]

    # -- rendering ---------------------------------------------------------
    def describe(self, plan=None) -> str:
        """Human-readable schedule: segments -> waves -> units, then the
        dependency edges, then (given a LayoutPlan) the relayout steps
        and halo-transfer blocks hoisted to each segment's entry."""
        nseg = len(self.segment_kinds)
        lines = [f"DAG schedule for graph {self.graph.name!r}: "
                 f"{len(self.units)} units, {len(self.edges)} edges, "
                 f"{nseg} segments"]
        by_seg: dict[int, dict[int, list[DagUnit]]] = defaultdict(
            lambda: defaultdict(list))
        for u in self.units:
            by_seg[u.segment][u.wave].append(u)
        for si in sorted(by_seg):
            kind = (self.segment_kinds[si]
                    if 0 <= si < nseg else "?")
            lines.append(f"segment {si} ({kind}):")
            for wi in sorted(by_seg[si]):
                wave = sorted(by_seg[si][wi], key=lambda u: u.uid)
                tag = f"  wave {wi}"
                if len(wave) >= 2:
                    tag += f" [antichain x{len(wave)}]"
                lines.append(tag + ":")
                lines.extend(f"    {u.describe()}" for u in wave)
        if self.edges:
            lines.append("edges:")
            by_uid = {u.uid: u for u in self.units}
            for e in self.edges:
                via = f" via {e.key}" if e.key else ""
                lines.append(f"  {by_uid[e.src].label} -> "
                             f"{by_uid[e.dst].label} ({e.reason}{via})")
        if plan is not None:
            for st in plan.relayouts:
                lines.append(f"relayout before seg{st.segment}: "
                             f"{st.tensor} {st.src.name}->{st.dst.name}")
            by_ht: dict[tuple[int, str], list] = defaultdict(list)
            for h in plan.halo_transfers:
                by_ht[(h.segment, h.tensor)].append(h)
            for (si, tensor), hs in sorted(by_ht.items()):
                sends = sum(1 for h in hs if h.mesh_axis)
                nbytes = sum(h.nbytes for h in hs)
                mode = ("overlapped" if any(h.overlapped for h in hs)
                        else "sync")
                lines.append(
                    f"seg{si} transfers: {tensor} {len(hs)} blocks "
                    f"({sends} ppermutes, {nbytes} bytes, {mode}) "
                    f"hoisted to segment entry")
            if getattr(plan, "regions", None):
                lines.append("regions (fused executables):")
                lines.extend("  " + r.describe() for r in plan.regions)
                redges = getattr(plan, "region_edges", None)
                if redges is not None:
                    by_idx = {r.index: r for r in plan.regions}
                    lines.append("region ready waves (async dispatch "
                                 "order):")
                    for wi, wave in enumerate(
                            region_waves(plan.regions, redges)):
                        tag = (f" [x{len(wave)} overlappable]"
                               if len(wave) >= 2 else "")
                        lines.append(
                            f"  wave {wi}{tag}: " + ", ".join(
                                f"region {i} ({by_idx[i].kind})"
                                for i in wave))
                    for e in redges:
                        via = f" via {e.key}" if e.key else ""
                        lines.append(
                            f"  region {e.src} -> region {e.dst} "
                            f"({e.reason}{via})")
            tuning = getattr(plan, "tuning", None)
            if tuning is not None:
                seg_layouts = getattr(tuning, "segment_layouts", {}) or {}
                for si in sorted(seg_layouts):
                    for name in sorted(seg_layouts[si]):
                        lines.append(
                            f"tuned segment {si}: {name} -> "
                            f"{seg_layouts[si][name].name} "
                            f"(per-segment joint-search decision)")
                proposed = getattr(tuning, "proposed", 0)
                if proposed:
                    lines.append(
                        f"tuner search space: {proposed} proposed, "
                        f"{getattr(tuning, 'pruned', 0)} pruned by HLO "
                        f"cost ranking, {getattr(tuning, 'measured', 0)} "
                        f"measured")
            if getattr(plan, "signature", ""):
                cache = getattr(plan, "cache", None)
                line = f"plan signature {plan.signature}"
                if cache is not None:
                    line += (f" — executable cache: "
                             f"{len(cache.executables)} executables, "
                             f"{cache.builds} builds, {cache.hits} reuse "
                             f"hits, {cache.trace_events} traces")
                lines.append(line)
        return "\n".join(lines)


def build_dag(graph: Graph) -> ScheduleDag:
    """Flatten ``graph`` into units and derive every dependency edge.

    Mirrors the sequential walk's flattening: non-conditional subgraphs
    are inlined (their levels become fresh levels — same-level snapshot
    semantics never spans a subgraph boundary), conditional subgraphs
    become single ``loop`` / ``host_loop`` vertices.
    """
    units: list[DagUnit] = []
    level_counter = itertools.count()

    def walk(g: Graph) -> None:
        for level in g.levels:
            lid = next(level_counter)
            for node in level:
                if node.kind == "subgraph":
                    walk(node.subgraph)
                    lid = next(level_counter)
                elif node.kind == "loop":
                    r, w = graph_access(node.subgraph)
                    kind = ("loop" if node.subgraph.is_device_only()
                            else "host_loop")
                    # the while predicate is an opaque callable over the
                    # full state dict: conservatively reads everything
                    units.append(DagUnit(
                        len(units), kind, next(level_counter),
                        reads=frozenset(r | {READS_ANY}), writes=w,
                        subgraph=node.subgraph))
                    lid = next(level_counter)
                else:
                    r, w = node_access(node)
                    if node.kind == "sync":
                        units.append(DagUnit(
                            len(units), "sync", lid, reads=r, writes=w,
                            node=node, barrier=True))
                    elif node.exec_kind is ExecutionKind.Cpu:
                        # a host callback with no tensor args has an
                        # invisible footprint: keep it where it is
                        units.append(DagUnit(
                            len(units), "host", lid, reads=r, writes=w,
                            node=node, barrier=not r))
                    else:
                        units.append(DagUnit(
                            len(units), "device", lid, reads=r, writes=w,
                            node=node))

    walk(graph)

    edges: list[DagEdge] = []
    for j, v in enumerate(units):
        for i in range(j):
            u = units[i]
            same_level = u.level == v.level
            both_device = u.kind == "device" and v.kind == "device"
            if same_level and both_device:
                # paper contract: same-level device nodes execute against
                # a shared snapshot — grouped into one wave, never edged
                continue
            c = _conflict(u, v)
            if c is not None:
                edges.append(DagEdge(u.uid, v.uid, c[0], c[1]))
            elif u.barrier or v.barrier:
                edges.append(DagEdge(u.uid, v.uid, "barrier"))
    # host side effects (checkpoint callbacks, prints) keep program order
    hosts = [u for u in units if u.kind in ("host", "sync", "host_loop")]
    edged = {(e.src, e.dst) for e in edges}
    for a, b in zip(hosts, hosts[1:]):
        if (a.uid, b.uid) not in edged:
            edges.append(DagEdge(a.uid, b.uid, "host-order"))
    edges.sort(key=lambda e: (e.src, e.dst))
    return ScheduleDag(graph, units, edges)


def dag_segments(dag: ScheduleDag) -> list[tuple]:
    """List-schedule the DAG into executor segments.

    Greedy maximal-antichain packing: while any device unit is ready,
    all ready device units form one wave and the segment keeps growing
    (cross-level fusion — one jit dispatch instead of one per level);
    only when no device unit is ready does a host / loop vertex run,
    breaking the segment exactly where a dependency path demands it.

    Same-level device units with conflicting footprints are pre-grouped
    so they always land in one wave: the executor lowers a wave against
    a shared snapshot, which is the semantics their level promised.
    """
    units = dag.units
    parent = list(range(len(units)))

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    by_level: dict[int, list[DagUnit]] = defaultdict(list)
    for u in units:
        if u.kind == "device":
            by_level[u.level].append(u)
    for level_units in by_level.values():
        for a, b in itertools.combinations(level_units, 2):
            if _conflict(a, b) is not None:
                parent[find(a.uid)] = find(b.uid)

    groups: dict[int, list[DagUnit]] = defaultdict(list)
    for u in units:
        groups[find(u.uid)].append(u)
    gid_of = {u.uid: find(u.uid) for u in units}
    gpreds: dict[int, set[int]] = {g: set() for g in groups}
    for e in dag.edges:
        gs, gd = gid_of[e.src], gid_of[e.dst]
        if gs != gd:
            gpreds[gd].add(gs)

    segments: list[tuple] = []
    kinds: list[str] = []
    waves: list[list[DagUnit]] = []
    pending = set(groups)

    def flush() -> None:
        nonlocal waves
        if not waves:
            return
        si = len(segments)
        for wi, wave in enumerate(waves):
            for u in wave:
                u.segment, u.wave = si, wi
        segments.append(("device", [[u.node for u in wave]
                                    for wave in waves]))
        kinds.append("device")
        waves = []

    while pending:
        ready = [g for g in pending
                 if all(p not in pending for p in gpreds[g])]
        dev = [g for g in ready if groups[g][0].kind == "device"]
        if dev:
            wave = sorted((u for g in dev for u in groups[g]),
                          key=lambda u: u.uid)
            waves.append(wave)
            pending -= set(dev)
            continue
        flush()
        g = min(ready, key=lambda g: groups[g][0].uid)
        u = groups[g][0]
        u.segment, u.wave = len(segments), 0
        if u.kind in ("host", "sync"):
            segments.append(("host", u.node))
        elif u.kind == "loop":
            segments.append(("loop", u.subgraph))
        else:
            segments.append(("host_loop", u.subgraph))
        kinds.append(u.kind if u.kind != "sync" else "host")
        pending.discard(g)
    flush()
    dag.segment_kinds = kinds
    return segments


@dataclass(frozen=True)
class Region:
    """A maximal run of consecutive segments the region compiler fuses
    into ONE jitted executable (``kind == 'device'``: device and device
    ``loop`` segments, with their boundary relayouts and halo glue traced
    inside), or a single host-side segment that must run eagerly between
    executables (``'host'`` — a callback/sync; ``'host_loop'`` — a
    conditional subgraph containing host nodes).

    ``start``/``stop`` are the half-open segment-index span in the
    executor's segment list."""

    index: int
    kind: str            # 'device' | 'host' | 'host_loop'
    start: int
    stop: int

    def __len__(self) -> int:
        return self.stop - self.start

    @property
    def segments(self) -> range:
        return range(self.start, self.stop)

    def describe(self) -> str:
        span = (f"seg{self.start}" if len(self) == 1
                else f"seg{self.start}..seg{self.stop - 1}")
        n = len(self)
        return (f"region {self.index} ({self.kind}): {span} "
                f"({n} segment{'s' if n != 1 else ''}"
                f"{' -> 1 executable' if self.kind == 'device' else ''})")


def group_regions(segment_kinds: list[str]) -> list[Region]:
    """Group a segment-kind list into maximal fusable regions.

    Consecutive ``device`` / ``loop`` segments form one ``device`` region
    (the region compiler lowers the whole run — segment bodies, boundary
    relayouts, while-loops — to a single jitted program, so repeated
    execution pays one dispatch per region instead of one per segment
    plus eager Python relayout glue).  ``host`` and ``host_loop``
    segments are hard breaks: each is its own region and runs eagerly."""
    regions: list[Region] = []
    i = 0
    while i < len(segment_kinds):
        if segment_kinds[i] in ("device", "loop"):
            j = i
            while j < len(segment_kinds) and \
                    segment_kinds[j] in ("device", "loop"):
                j += 1
            regions.append(Region(len(regions), "device", i, j))
            i = j
        else:
            regions.append(Region(len(regions), segment_kinds[i], i, i + 1))
            i += 1
    return regions


@dataclass(frozen=True)
class RegionEdge:
    """A scheduling constraint between two regions (by region index).

    Lifted from the unit-level :class:`DagEdge` s: a region edge exists
    wherever any unit placed in ``src`` constrains any unit placed in
    ``dst``.  ``reason`` keeps the strongest lifted reason (data reasons
    beat ordering reasons) and ``key`` the state entry carrying it, so
    ``plan.describe()`` can explain WHY the async dispatcher must wait.
    Regions without an edge (direct or transitive) are independent: the
    event-driven runtime may have both in flight at once.
    """

    src: int
    dst: int
    reason: str
    key: Optional[str] = None


# when several unit edges lift onto one region edge, keep the most
# informative reason: true data dependencies beat ordering constraints
_REGION_REASON_RANK = {"raw": 0, "waw": 1, "war": 2,
                       "barrier": 3, "host-order": 4}


def _segment_to_region(regions: list[Region]) -> dict[int, int]:
    return {s: r.index for r in regions for s in r.segments}


def region_access(dag: ScheduleDag,
                  regions: list[Region]) -> dict[int, tuple]:
    """Per-region footprint: ``index -> (reads, writes, barrier)``.

    The union of the member units' footprints (the same sets
    :func:`build_dag` derived), plus whether any member is a barrier —
    a barrier region (``sync()``, opaque host callback) forces the async
    dispatcher to drain every in-flight callback before it runs."""
    seg2r = _segment_to_region(regions)
    acc: dict[int, list] = {r.index: [set(), set(), False] for r in regions}
    for u in dag.units:
        ri = seg2r.get(u.segment)
        if ri is None:
            continue
        acc[ri][0] |= u.reads
        acc[ri][1] |= u.writes
        acc[ri][2] = acc[ri][2] or u.barrier
    return {i: (frozenset(r), frozenset(w), b)
            for i, (r, w, b) in acc.items()}


def region_dag(dag: ScheduleDag,
               regions: list[Region]) -> list[RegionEdge]:
    """Lift the unit-level dependency edges to the region level.

    Every :class:`DagEdge` whose endpoints landed in different regions
    becomes (after dedup) one :class:`RegionEdge` — so the region DAG
    inherits exactly the RAW/WAW/WAR/barrier/host-order analysis that
    :func:`build_dag` already performed, rather than recomputing
    footprints.  Units are placed before this is called (via
    :func:`dag_segments` or :func:`place_units`); edges between units of
    one region vanish (they are honored inside the fused executable)."""
    seg2r = _segment_to_region(regions)
    best: dict[tuple[int, int], RegionEdge] = {}
    for e in dag.edges:
        rs = seg2r.get(dag.units[e.src].segment)
        rd = seg2r.get(dag.units[e.dst].segment)
        if rs is None or rd is None or rs == rd:
            continue
        if rs > rd:          # unit edges point forward; defensive only
            rs, rd = rd, rs
        cur = best.get((rs, rd))
        if cur is None or (_REGION_REASON_RANK[e.reason]
                           < _REGION_REASON_RANK[cur.reason]):
            best[(rs, rd)] = RegionEdge(rs, rd, e.reason, e.key)
    return [best[k] for k in sorted(best)]


def region_waves(regions: list[Region],
                 edges: list[RegionEdge]) -> list[list[int]]:
    """Kahn layering of the region DAG into ready waves.

    Wave ``k`` holds every region whose predecessors all sit in earlier
    waves — the ready-set order the async dispatcher walks, and the
    "ready waves of regions" view ``plan.describe()`` renders.  Two
    regions sharing a wave have no dependency path between them: the
    runtime may overlap them (e.g. a host callback runs on the pool
    while the next device region is already dispatched)."""
    preds: dict[int, set[int]] = {r.index: set() for r in regions}
    for e in edges:
        preds[e.dst].add(e.src)
    done: set[int] = set()
    pending = [r.index for r in regions]
    waves: list[list[int]] = []
    while pending:
        ready = [i for i in pending if preds[i] <= done]
        if not ready:        # unreachable (edges point forward); safety
            ready = [pending[0]]
        waves.append(ready)
        done.update(ready)
        pending = [i for i in pending if i not in done]
    return waves


def sequential_segments(graph: Graph) -> list[tuple]:
    """Legacy program-order segmentation (the ``schedule="sequential"``
    escape hatch): every builder level is a wave in program order,
    consecutive device levels fuse, host / sync / loop nodes break the
    chain wherever they appear."""
    segments: list[tuple] = []
    device_levels: list[list[Node]] = []

    def flush() -> None:
        nonlocal device_levels
        if device_levels:
            segments.append(("device", device_levels))
            device_levels = []

    def walk(g: Graph) -> None:
        nonlocal device_levels
        for level in g.levels:
            dev_nodes: list[Node] = []
            for node in level:
                if node.kind == "subgraph":
                    if dev_nodes:
                        device_levels.append(dev_nodes)
                        dev_nodes = []
                    walk(node.subgraph)
                elif node.kind == "loop":
                    if dev_nodes:
                        device_levels.append(dev_nodes)
                        dev_nodes = []
                    flush()
                    segments.append((
                        "loop" if node.subgraph.is_device_only()
                        else "host_loop", node.subgraph))
                elif (node.kind == "sync"
                        or node.exec_kind is ExecutionKind.Cpu):
                    if dev_nodes:
                        device_levels.append(dev_nodes)
                        dev_nodes = []
                    flush()
                    segments.append(("host", node))
                else:
                    dev_nodes.append(node)
            if dev_nodes:
                device_levels.append(dev_nodes)

    walk(graph)
    flush()
    return segments


def place_units(dag: ScheduleDag, segments: list[tuple]) -> None:
    """Record each unit's (segment, wave) placement for a segmentation
    produced outside :func:`dag_segments` (the sequential path), so
    :meth:`ScheduleDag.describe` renders either schedule.

    Placements are matched FIFO per object identity: the same subgraph
    object may legally appear several times in one graph, and both the
    unit list and the segment list are in program order."""
    pos: dict[int, list[tuple[int, int]]] = {}
    kinds: list[str] = []
    for si, (kind, payload) in enumerate(segments):
        kinds.append(kind)
        if kind == "device":
            for wi, wave in enumerate(payload):
                for n in wave:
                    pos.setdefault(id(n), []).append((si, wi))
        else:  # host: payload is the node; loop/host_loop: the subgraph
            pos.setdefault(id(payload), []).append((si, 0))
    for u in dag.units:
        key = id(u.node if u.node is not None else u.subgraph)
        slots = pos.get(key)
        u.segment, u.wave = slots.pop(0) if slots else (-1, -1)
    dag.segment_kinds = kinds
