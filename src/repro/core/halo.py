"""Halo (padding) exchange — paper §4.1/§5.4 adapted to shard_map/ppermute.

Ripple tensors carry ``padding`` cells filled either from neighbouring
partitions (inter-device copy) or from a boundary policy (constant /
first-order extrapolation).  Here a shard's halo arrives via
``lax.ppermute`` — XLA lowers this to ``collective-permute`` which the TPU
latency-hiding scheduler runs asynchronously, which is exactly the paper's
"padding ops can overlap the split kernel" (Fig. 7) in SPMD form.

Multi-axis halos are a *transfer schedule* over blocks keyed by which
sides of which axes they extend (paper §5.4's optimal scheduling across a
multi-dimensional space):

* phase 1 — every axis's edge strips leave at once (independent sends on
  the unextended shard);
* phase p — corner/vertex blocks: each phase-(p-1) block's edge along a
  later axis travels one more hop (the two-phase extended-edge exchange,
  so diagonal neighbours never talk directly);
* :func:`assemble_region` stitches any rectangular region of the extended
  array back together from the blocks — the full array for a synchronous
  exchange, or just a boundary strip's input for the overlapped lowering.

Because no block transfer depends on compute (phase p depends only on
phase p-1 receives), every send can be in flight while the interior
program runs.  All collective paths run *inside* shard_map (per-shard
view); axes with ``axis_name=None`` are filled locally from the boundary
policy, so fill-only schedules work anywhere.

Purity contract: every function in this module is a pure function of its
array arguments — no Python-side state, no eager dispatch decisions —
so the executor's *region compiler* can trace exchange and assembly
directly into a fused region executable (one jitted program per run of
segments) and replay it without retracing.  :func:`schedule_blocks` is
the static (shape-level) description of the same schedule, consumed by
the plan introspection for per-block byte accounting.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..runtime.faults import trip as _fault_trip

__all__ = [
    "Boundary",
    "HaloAxis",
    "exchange",
    "exchange_blocks",
    "exchange_multi",
    "assemble_region",
    "block_shape",
    "iter_block_keys",
    "schedule_blocks",
    "halo_blocks",
    "pad_boundary_only",
    "unpad",
    "interior",
]


class Boundary(enum.Enum):
    """Fill policy for halo cells at the global domain edge (paper's
    'methods for loading the padding for common cases')."""

    TRANSMISSIVE = "transmissive"  # constant (zero-gradient) extrapolation
    LINEAR = "linear"              # first-order extrapolation
    PERIODIC = "periodic"          # wrap around the global domain
    CONSTANT = "constant"          # fixed value


def _take(x: jax.Array, axis: int, start: int, size: int) -> jax.Array:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size) if start >= 0 else slice(start, None)
    return x[tuple(idx)]


def _edge_fill(
    x: jax.Array, axis: int, width: int, side: str, boundary: Boundary, constant
) -> jax.Array:
    """Halo block (width cells) synthesized from the shard's own edge."""
    if boundary is Boundary.CONSTANT:
        shape = list(x.shape)
        shape[axis] = width
        return jnp.full(shape, constant, dtype=x.dtype)
    if side == "left":
        edge = _take(x, axis, 0, 1)
        nxt = _take(x, axis, 1, 1) if x.shape[axis] > 1 else edge
        steps = jnp.arange(width, 0, -1)
    else:
        edge = _take(x, axis, x.shape[axis] - 1, 1)
        nxt = _take(x, axis, x.shape[axis] - 2, 1) if x.shape[axis] > 1 else edge
        steps = jnp.arange(1, width + 1)
    reps = [1] * x.ndim
    reps[axis] = width
    tiled = jnp.tile(edge, reps)
    if boundary is Boundary.TRANSMISSIVE:
        return tiled
    # LINEAR: edge + k * (edge - next_inner)
    shape = [1] * x.ndim
    shape[axis] = width
    k = steps.reshape(shape).astype(x.dtype)
    return tiled + k * (edge - nxt)


def halo_blocks(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    axis_name: str,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """The (left, right) halo blocks a shard receives, NOT yet concatenated.

    Exposing the blocks separately lets the executor overlap the ppermute
    with interior compute (paper Fig. 7: ``a_p`` parallel with ``a_s``).
    Must be called inside shard_map.
    """
    n = lax.psum(1, axis_name)  # number of shards (static at trace time)
    idx = lax.axis_index(axis_name)

    send_right = _take(x, axis, x.shape[axis] - width, width)  # -> right nbr
    send_left = _take(x, axis, 0, width)  # -> left nbr

    if boundary is Boundary.PERIODIC:
        left_halo = lax.ppermute(
            send_right, axis_name, [((i - 1) % n, i) for i in range(n)]
        )
        right_halo = lax.ppermute(
            send_left, axis_name, [((i + 1) % n, i) for i in range(n)]
        )
    else:
        # Non-cyclic: edge shards receive zeros, then overwrite from policy.
        left_halo = lax.ppermute(
            send_right, axis_name, [(i, i + 1) for i in range(n - 1)]
        )
        right_halo = lax.ppermute(
            send_left, axis_name, [(i, i - 1) for i in range(1, n)]
        )
        left_fill = _edge_fill(x, axis, width, "left", boundary, constant)
        right_fill = _edge_fill(x, axis, width, "right", boundary, constant)
        left_halo = jnp.where(idx == 0, left_fill, left_halo)
        right_halo = jnp.where(idx == n - 1, right_fill, right_halo)
    return left_halo, right_halo


def exchange(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    axis_name: str,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> jax.Array:
    """Per-shard halo exchange along storage ``axis`` over mesh ``axis_name``.

    Returns the shard extended by ``width`` cells on both sides of ``axis``:
    interior halos come from neighbours via ppermute, global-edge halos from
    the boundary policy.  Must be called inside shard_map.
    """
    if width == 0:
        return x
    left_halo, right_halo = halo_blocks(
        x,
        axis=axis,
        width=width,
        axis_name=axis_name,
        boundary=boundary,
        constant=constant,
    )
    return jnp.concatenate([left_halo, x, right_halo], axis=axis)


def pad_boundary_only(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> jax.Array:
    """Halo padding for an axis that is NOT partitioned (or a 1-shard mesh
    axis): both halos come from the boundary policy (PERIODIC wraps self)."""
    if width == 0:
        return x
    low, high = _block_pair(x, HaloAxis(axis, width, None), boundary, constant)
    return jnp.concatenate([low, x, high], axis=axis)


# -- multi-axis transfer schedule ---------------------------------------------

@dataclass(frozen=True)
class HaloAxis:
    """One haloed storage axis of a shard's block schedule.

    ``axis_name=None`` means the axis is not mesh-partitioned: its halo
    comes from the boundary policy (a local fill, no transfer)."""

    axis: int                       # storage axis
    width: int
    axis_name: Optional[str] = None  # mesh axis; None -> local fill


# A block key identifies which sides of which axes a block extends:
# a tuple of (axis_list_index, 'low'|'high') pairs with strictly ascending
# indices.  () is the shard itself; ((0,'low'),) its low edge strip along
# axes[0]; ((0,'low'),(1,'high')) the corner beyond both.
BlockKey = tuple


def iter_block_keys(axes: Sequence[HaloAxis]):
    """Yield ``(phase, key)`` for every block the schedule transfers.

    Phase 1 keys are the per-axis edge strips (sent from the unextended
    shard, all independent); phase p keys extend a phase-(p-1) block along
    a strictly later axis — the extended-edge exchange that routes corner
    data through face neighbours.  Zero-width axes contribute nothing.
    """
    frontier: list[BlockKey] = [()]
    phase = 0
    while frontier:
        phase += 1
        nxt: list[BlockKey] = []
        for key in frontier:
            start = key[-1][0] + 1 if key else 0
            for j in range(start, len(axes)):
                if axes[j].width == 0:
                    continue
                for side in ("low", "high"):
                    k = key + ((j, side),)
                    yield phase, k
                    nxt.append(k)
        frontier = nxt


def schedule_blocks(shape: Sequence[int], axes: Sequence[HaloAxis]):
    """Yield ``(phase, key, block_shape)`` for every transfer block of a
    shard of ``shape`` — the static, shape-level description of the
    schedule :func:`exchange_blocks` executes.  The executor's plan pass
    uses it to account per-block bytes (``HaloTransfer.nbytes``) without
    tracing anything."""
    for phase, key in iter_block_keys(axes):
        yield phase, key, block_shape(shape, axes, key)


def block_shape(
    shape: Sequence[int], axes: Sequence[HaloAxis], key: BlockKey
) -> tuple[int, ...]:
    """Shape of the halo block ``key`` for a shard of ``shape``.

    Along every axis the key extends, the block is ``width`` cells thick;
    along every other axis it spans the shard.  The executor uses this for
    per-block byte accounting (``HaloTransfer.nbytes``), which the DAG
    schedule surfaces as the traffic hoisted to each segment entry.
    """
    out = list(shape)
    for j, _side in key:
        out[axes[j].axis] = axes[j].width
    return tuple(out)


def _block_pair(
    x: jax.Array, a: HaloAxis, boundary: Boundary, constant
) -> tuple[jax.Array, jax.Array]:
    """(low, high) halo blocks of ``x`` along one axis: neighbour transfer
    for partitioned axes, boundary-policy fill otherwise."""
    if a.axis_name is None:
        if boundary is Boundary.PERIODIC:
            n = x.shape[a.axis]
            # modular gather supports width > n (wraps multiple times)
            low = jnp.take(x, (jnp.arange(-a.width, 0) % n), axis=a.axis)
            high = jnp.take(x, (jnp.arange(a.width) % n), axis=a.axis)
            return low, high
        return (_edge_fill(x, a.axis, a.width, "left", boundary, constant),
                _edge_fill(x, a.axis, a.width, "right", boundary, constant))
    return halo_blocks(x, axis=a.axis, width=a.width, axis_name=a.axis_name,
                       boundary=boundary, constant=constant)


def exchange_blocks(
    x: jax.Array,
    axes: Sequence[HaloAxis],
    *,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> dict[BlockKey, jax.Array]:
    """Run the transfer schedule: every block of :func:`iter_block_keys`,
    plus the shard itself under ``()``.

    All phase-1 sends are issued against ``x`` directly and phase p
    depends only on phase p-1 receives, so nothing here waits on compute —
    XLA's latency-hiding scheduler overlaps the collectives with whatever
    runs next.  Equivalent by value to the sequential per-axis
    exchange-then-concatenate chain (fills commute with earlier-axis
    extension because they act pointwise along the filled axis).
    Partitioned axes must be called inside shard_map.
    """
    blocks: dict[BlockKey, jax.Array] = {(): x}
    frontier: list[BlockKey] = [()]
    while frontier:
        nxt: list[BlockKey] = []
        for key in frontier:
            start = key[-1][0] + 1 if key else 0
            for j in range(start, len(axes)):
                a = axes[j]
                if a.width == 0:
                    continue
                # chaos injection point: one scheduled halo block.  This
                # runs at TRACE time (inside jit), so an injected
                # failure aborts the region build before any donation —
                # the caller's state is intact for a retry.
                _fault_trip("halo.block",
                            detail=f"axis{j}:{a.axis_name or 'fill'}")
                low, high = _block_pair(blocks[key], a, boundary, constant)
                blocks[key + ((j, "low"),)] = low
                blocks[key + ((j, "high"),)] = high
                nxt += [key + ((j, "low"),), key + ((j, "high"),)]
        frontier = nxt
    return blocks


def assemble_region(
    blocks: dict[BlockKey, jax.Array],
    axes: Sequence[HaloAxis],
    ranges: Sequence[tuple[int, int]],
) -> jax.Array:
    """Stitch one rectangular region of the extended array from ``blocks``.

    ``ranges[i]`` is the half-open extent along ``axes[i].axis`` in
    *extended* coordinates: ``[0, w)`` is the low halo zone, ``[w, w+m)``
    the shard, ``[w+m, w+2w+m)`` the high halo zone.  Full ranges
    reproduce the whole extended shard; sub-ranges cut exactly the input
    a boundary-strip program needs without touching unrelated blocks.
    """
    x = blocks[()]

    def rec(idx: int, key: BlockKey, slabs):
        if idx == len(axes):
            out = blocks[key]
            for ax, start, size in slabs:
                out = _take(out, ax, start, size)
            return out
        a = axes[idx]
        lo, hi = ranges[idx]
        m = x.shape[a.axis]
        parts = []
        if lo < a.width:  # low halo zone
            end = min(hi, a.width)
            sub = rec(idx + 1, key + ((idx, "low"),), slabs)
            if (lo, end) != (0, a.width):
                sub = _take(sub, a.axis, lo, end - lo)
            parts.append(sub)
        mid_lo, mid_hi = max(lo, a.width), min(hi, a.width + m)
        if mid_lo < mid_hi:  # shard zone
            slab = (a.axis, mid_lo - a.width, mid_hi - mid_lo)
            parts.append(rec(idx + 1, key,
                             slabs if slab[1:] == (0, m) else slabs + [slab]))
        base = a.width + m
        if hi > base:  # high halo zone
            start = max(lo, base)
            sub = rec(idx + 1, key + ((idx, "high"),), slabs)
            if (start, hi) != (base, base + a.width):
                sub = _take(sub, a.axis, start - base, hi - start)
            parts.append(sub)
        if not parts:
            raise ValueError(f"empty region range {ranges[idx]} on axis "
                             f"{a.axis}")
        return parts[0] if len(parts) == 1 else jnp.concatenate(
            parts, axis=a.axis)

    return rec(0, (), [])


def exchange_multi(
    x: jax.Array,
    axes: Sequence[HaloAxis],
    *,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> jax.Array:
    """Extend a shard along every haloed axis at once via the transfer
    schedule (corners included).  Value-equal to chaining
    :func:`exchange` / :func:`pad_boundary_only` per axis in list order,
    but every inter-device send is issued up front."""
    axes = [a for a in axes if a.width]
    if not axes:
        return x
    blocks = exchange_blocks(x, axes, boundary=boundary, constant=constant)
    ranges = [(0, x.shape[a.axis] + 2 * a.width) for a in axes]
    return assemble_region(blocks, axes, ranges)


def unpad(x: jax.Array, *, axis: int, width: int) -> jax.Array:
    """Strip ``width`` halo cells from both ends of ``axis``."""
    if width == 0:
        return x
    return _take(x, axis, width, x.shape[axis] - 2 * width)


def interior(x: jax.Array, *, axis: int, width: int) -> jax.Array:
    """The part of a shard whose stencil result needs no halo."""
    return unpad(x, axis=axis, width=width)
