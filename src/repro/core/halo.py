"""Halo (padding) exchange — paper §4.1/§5.4 adapted to shard_map/ppermute.

Ripple tensors carry ``padding`` cells filled either from neighbouring
partitions (inter-device copy) or from a boundary policy (constant /
first-order extrapolation).  Here a shard's halo arrives via
``lax.ppermute`` — XLA lowers this to ``collective-permute`` which the TPU
latency-hiding scheduler runs asynchronously, which is exactly the paper's
"padding ops can overlap the split kernel" (Fig. 7) in SPMD form.

All functions in this module run *inside* shard_map (per-shard view).
``pad_boundary_only`` provides the single-shard / unpartitioned-dim case.
"""

from __future__ import annotations

import enum
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "Boundary",
    "exchange",
    "halo_blocks",
    "pad_boundary_only",
    "unpad",
    "interior",
]


class Boundary(enum.Enum):
    """Fill policy for halo cells at the global domain edge (paper's
    'methods for loading the padding for common cases')."""

    TRANSMISSIVE = "transmissive"  # constant (zero-gradient) extrapolation
    LINEAR = "linear"              # first-order extrapolation
    PERIODIC = "periodic"          # wrap around the global domain
    CONSTANT = "constant"          # fixed value


def _take(x: jax.Array, axis: int, start: int, size: int) -> jax.Array:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size) if start >= 0 else slice(start, None)
    return x[tuple(idx)]


def _edge_fill(
    x: jax.Array, axis: int, width: int, side: str, boundary: Boundary, constant
) -> jax.Array:
    """Halo block (width cells) synthesized from the shard's own edge."""
    if boundary is Boundary.CONSTANT:
        shape = list(x.shape)
        shape[axis] = width
        return jnp.full(shape, constant, dtype=x.dtype)
    if side == "left":
        edge = _take(x, axis, 0, 1)
        nxt = _take(x, axis, 1, 1) if x.shape[axis] > 1 else edge
        steps = jnp.arange(width, 0, -1)
    else:
        edge = _take(x, axis, x.shape[axis] - 1, 1)
        nxt = _take(x, axis, x.shape[axis] - 2, 1) if x.shape[axis] > 1 else edge
        steps = jnp.arange(1, width + 1)
    reps = [1] * x.ndim
    reps[axis] = width
    tiled = jnp.tile(edge, reps)
    if boundary is Boundary.TRANSMISSIVE:
        return tiled
    # LINEAR: edge + k * (edge - next_inner)
    shape = [1] * x.ndim
    shape[axis] = width
    k = steps.reshape(shape).astype(x.dtype)
    return tiled + k * (edge - nxt)


def halo_blocks(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    axis_name: str,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """The (left, right) halo blocks a shard receives, NOT yet concatenated.

    Exposing the blocks separately lets the executor overlap the ppermute
    with interior compute (paper Fig. 7: ``a_p`` parallel with ``a_s``).
    Must be called inside shard_map.
    """
    n = lax.psum(1, axis_name)  # number of shards (static at trace time)
    idx = lax.axis_index(axis_name)

    send_right = _take(x, axis, x.shape[axis] - width, width)  # -> right nbr
    send_left = _take(x, axis, 0, width)  # -> left nbr

    if boundary is Boundary.PERIODIC:
        left_halo = lax.ppermute(
            send_right, axis_name, [((i - 1) % n, i) for i in range(n)]
        )
        right_halo = lax.ppermute(
            send_left, axis_name, [((i + 1) % n, i) for i in range(n)]
        )
    else:
        # Non-cyclic: edge shards receive zeros, then overwrite from policy.
        left_halo = lax.ppermute(
            send_right, axis_name, [(i, i + 1) for i in range(n - 1)]
        )
        right_halo = lax.ppermute(
            send_left, axis_name, [(i, i - 1) for i in range(1, n)]
        )
        left_fill = _edge_fill(x, axis, width, "left", boundary, constant)
        right_fill = _edge_fill(x, axis, width, "right", boundary, constant)
        left_halo = jnp.where(idx == 0, left_fill, left_halo)
        right_halo = jnp.where(idx == n - 1, right_fill, right_halo)
    return left_halo, right_halo


def exchange(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    axis_name: str,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> jax.Array:
    """Per-shard halo exchange along storage ``axis`` over mesh ``axis_name``.

    Returns the shard extended by ``width`` cells on both sides of ``axis``:
    interior halos come from neighbours via ppermute, global-edge halos from
    the boundary policy.  Must be called inside shard_map.
    """
    if width == 0:
        return x
    left_halo, right_halo = halo_blocks(
        x,
        axis=axis,
        width=width,
        axis_name=axis_name,
        boundary=boundary,
        constant=constant,
    )
    return jnp.concatenate([left_halo, x, right_halo], axis=axis)


def pad_boundary_only(
    x: jax.Array,
    *,
    axis: int,
    width: int,
    boundary: Boundary = Boundary.TRANSMISSIVE,
    constant: Any = 0.0,
) -> jax.Array:
    """Halo padding for an axis that is NOT partitioned (or a 1-shard mesh
    axis): both halos come from the boundary policy (PERIODIC wraps self)."""
    if width == 0:
        return x
    if boundary is Boundary.PERIODIC:
        n = x.shape[axis]
        # modular gather supports width > n (wraps multiple times)
        left = jnp.take(x, (jnp.arange(-width, 0) % n), axis=axis)
        right = jnp.take(x, (jnp.arange(width) % n), axis=axis)
    else:
        left = _edge_fill(x, axis, width, "left", boundary, constant)
        right = _edge_fill(x, axis, width, "right", boundary, constant)
    return jnp.concatenate([left, x, right], axis=axis)


def unpad(x: jax.Array, *, axis: int, width: int) -> jax.Array:
    """Strip ``width`` halo cells from both ends of ``axis``."""
    if width == 0:
        return x
    return _take(x, axis, width, x.shape[axis] - 2 * width)


def interior(x: jax.Array, *, axis: int, width: int) -> jax.Array:
    """The part of a shard whose stencil result needs no halo."""
    return unpad(x, axis=axis, width=width)
