"""Ripple core — polymorphic layout, distributed tensors, halo exchange,
graph DAG + executor (the paper's C1-C6, see DESIGN.md)."""

from .layout import (
    Field,
    Layout,
    RecordArray,
    RecordRef,
    RecordSpec,
    Vector,
    block_spec_for,
)
from .halo import Boundary, exchange, halo_blocks, interior, pad_boundary_only, unpad
from .tensor import DistTensor, ReductionResult, make_reduction_result
from .graph import (
    AccessMode,
    ExecutionKind,
    Graph,
    MaxReducer,
    MinReducer,
    Node,
    Reducer,
    SumReducer,
    TensorArg,
    concurrent_padded_access,
    concurrent_padded_access_in_shared,
    exclusive_padded_access,
    exclusive_padded_access_in_shared,
    in_shared,
)
from .executor import Executor, execute, make_mesh

__all__ = [
    "Field", "Layout", "RecordArray", "RecordRef", "RecordSpec", "Vector",
    "block_spec_for",
    "Boundary", "exchange", "halo_blocks", "interior", "pad_boundary_only",
    "unpad",
    "DistTensor", "ReductionResult", "make_reduction_result",
    "AccessMode", "ExecutionKind", "Graph", "MaxReducer", "MinReducer",
    "Node", "Reducer", "SumReducer", "TensorArg",
    "concurrent_padded_access", "concurrent_padded_access_in_shared",
    "exclusive_padded_access", "exclusive_padded_access_in_shared",
    "in_shared",
    "Executor", "execute", "make_mesh",
]
