"""Ripple core — polymorphic layout, distributed tensors, halo exchange,
graph DAG + executor (the paper's C1-C6, see DESIGN.md)."""

from .layout import (
    AOSOA_LANE,
    Field,
    Layout,
    RecordArray,
    RecordRef,
    RecordSpec,
    Vector,
    aosoa_tile,
    block_spec_for,
    dispatch_with_relayout,
    record_grid_1d,
    relayout,
)
from .halo import Boundary, exchange, halo_blocks, interior, pad_boundary_only, unpad
from .tensor import DistTensor, ReductionResult, make_reduction_result
from .graph import (
    AccessMode,
    ExecutionKind,
    Graph,
    MaxReducer,
    MinReducer,
    Node,
    Reducer,
    SumReducer,
    TensorArg,
    concurrent_padded_access,
    concurrent_padded_access_in_shared,
    exclusive_padded_access,
    exclusive_padded_access_in_shared,
    in_shared,
    preferred_layout,
)
from .executor import (
    Executor,
    LayoutPlan,
    RelayoutStep,
    execute,
    make_mesh,
    solve_layouts,
)

__all__ = [
    "AOSOA_LANE", "Field", "Layout", "RecordArray", "RecordRef", "RecordSpec",
    "Vector", "aosoa_tile", "block_spec_for", "dispatch_with_relayout",
    "record_grid_1d", "relayout",
    "Boundary", "exchange", "halo_blocks", "interior", "pad_boundary_only",
    "unpad",
    "DistTensor", "ReductionResult", "make_reduction_result",
    "AccessMode", "ExecutionKind", "Graph", "MaxReducer", "MinReducer",
    "Node", "Reducer", "SumReducer", "TensorArg",
    "concurrent_padded_access", "concurrent_padded_access_in_shared",
    "exclusive_padded_access", "exclusive_padded_access_in_shared",
    "in_shared", "preferred_layout",
    "Executor", "LayoutPlan", "RelayoutStep", "execute", "make_mesh",
    "solve_layouts",
]
