"""N-dimensional distributed tensor (paper §4.1) on a JAX mesh.

A :class:`DistTensor` is the *handle* describing a logical space: its
record spec + polymorphic layout (C1), per-dimension partitioning onto
mesh axes, per-dimension halo widths and boundary policies (C3).  The
storage itself is a jax.Array (or :class:`RecordArray`) living in the
executor's state dict, placed with the NamedSharding derived here.

Paper mapping:
  * ``Tensor<double, 2> t({2, 2}, size_x, size_y)``  ->
    ``DistTensor("t", space=(sx, sy), partition=("gx", "gy"))``
  * sub-partitions (same-device blocks)              ->  ``subblocks`` hint,
    consumed by Pallas kernels as their BlockSpec grid (DESIGN.md §2).
  * padding parameter                                ->  ``halo`` widths.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .halo import Boundary
from .layout import Layout, RecordArray, RecordSpec

__all__ = ["DistTensor", "ReductionResult", "make_reduction_result"]


@dataclass(frozen=True)
class DistTensor:
    """Handle for a partitioned, haloed, layout-polymorphic tensor.

    Describes a logical N-d space — record spec + storage layout,
    per-dim mesh partitioning, per-dim halo widths and the boundary
    policy — while the storage itself lives in the executor's state
    dict as a raw ``jax.Array``.

    Example::

        mesh = make_mesh((4,), ("d",))
        u = DistTensor("u", (1024, 1024), partition=("d",), halo=(1,),
                       boundary=Boundary.PERIODIC)
        # record cells with a pinned AoS layout:
        p = DistTensor("p", (65536,), spec=RecordSpec.create("x", "y"),
                       layout=Layout.AOS, pin_layout=True)
    """

    name: str
    space: tuple[int, ...]
    dtype: Any = jnp.float32
    spec: Optional[RecordSpec] = None          # None -> scalar cells
    layout: Layout = Layout.SOA
    pin_layout: bool = False                   # user pin: solver must honor
    partition: tuple[Optional[str], ...] = ()  # mesh axis per space dim
    halo: tuple[int, ...] = ()
    boundary: Boundary = Boundary.TRANSMISSIVE
    boundary_constant: float = 0.0
    subblocks: tuple[int, ...] = ()            # per-device sub-partition hint

    def __post_init__(self):
        nd = len(self.space)
        object.__setattr__(self, "space", tuple(self.space))
        part = tuple(self.partition) + (None,) * (nd - len(self.partition))
        object.__setattr__(self, "partition", part[:nd])
        h = tuple(self.halo) + (0,) * (nd - len(self.halo))
        object.__setattr__(self, "halo", h[:nd])

    # -- shape/layout ----------------------------------------------------
    @property
    def is_record(self) -> bool:
        """True when cells are records (``spec`` given) rather than
        scalars — only record tensors participate in layout solving."""
        return self.spec is not None

    @property
    def storage_shape(self) -> tuple[int, ...]:
        """Shape of the backing array under the declared layout (AoS
        appends the component axis, SoA prepends it, AoSoA tiles the
        last space dim)."""
        if not self.is_record:
            return self.space
        return RecordArray.storage_shape(self.spec, self.space, self.layout)

    def storage_axis(self, dim: int) -> int:
        """Storage axis for space dim (skips the SoA component axis)."""
        if not self.is_record:
            return dim
        if self.layout is Layout.AOS:
            return dim
        if self.layout is Layout.SOA:
            return dim + 1
        if dim == len(self.space) - 1:
            raise ValueError(
                f"{self.name}: AOSOA tiles the last space dim; halo/"
                f"per-axis ops are unsupported there")
        return dim

    # -- sharding ----------------------------------------------------------
    def pspec(self) -> P:
        """PartitionSpec over the *storage* shape (component axis unsharded)."""
        dims: list[Optional[str]] = list(self.partition)
        if self.is_record:
            if self.layout is Layout.AOS:
                dims = dims + [None]
            elif self.layout is Layout.SOA:
                dims = [None] + dims
            else:  # AOSOA: (*space[:-1], n_tiles, C, tile); the tiled dim
                # must stay unsharded (validate_mesh enforces it)
                dims = dims[:-1] + [None, None, None]
        return P(*dims)

    def sharding(self, mesh: Mesh) -> NamedSharding:
        """The NamedSharding placing this tensor's storage on ``mesh``."""
        return NamedSharding(mesh, self.pspec())

    def shards_along(self, mesh: Mesh, dim: int) -> int:
        """How many shards space dim ``dim`` splits into on ``mesh``."""
        ax = self.partition[dim]
        return 1 if ax is None else mesh.shape[ax]

    def shard_space(self, mesh: Mesh) -> tuple[int, ...]:
        """The per-shard space extents on ``mesh``."""
        return tuple(
            s // self.shards_along(mesh, d) for d, s in enumerate(self.space)
        )

    def validate_mesh(self, mesh: Mesh) -> None:
        """Raise ``ValueError`` when this handle cannot live on ``mesh``:
        unknown axis, non-divisible extent, shard smaller than its halo,
        or AoSoA carrying halo/partition on the tiled dim."""
        if self.is_record and self.layout is Layout.AOSOA:
            nd = len(self.space)
            if self.partition[nd - 1] is not None:
                raise ValueError(
                    f"{self.name}: AOSOA cannot be partitioned along the "
                    f"tiled (last) space dim")
            if self.halo[nd - 1]:
                raise ValueError(
                    f"{self.name}: AOSOA cannot carry a halo on the tiled "
                    f"(last) space dim")
        for d, ax in enumerate(self.partition):
            if ax is None:
                continue
            if ax not in mesh.shape:
                raise ValueError(f"{self.name}: mesh has no axis {ax!r}")
            n = mesh.shape[ax]
            if self.space[d] % n:
                raise ValueError(
                    f"{self.name}: space dim {d} ({self.space[d]}) not divisible "
                    f"by mesh axis {ax!r} ({n})"
                )
            if self.halo[d] and self.space[d] // n < self.halo[d]:
                raise ValueError(
                    f"{self.name}: shard extent {self.space[d] // n} smaller than "
                    f"halo {self.halo[d]} in dim {d}"
                )

    # -- materialization -----------------------------------------------------
    def init(
        self, mesh: Optional[Mesh] = None, fill: float = 0.0
    ) -> jax.Array | RecordArray:
        """Allocate storage (zeros/fill), sharded if a mesh is given."""
        if mesh is not None:
            self.validate_mesh(mesh)
        arr = jnp.full(self.storage_shape, fill, dtype=self.dtype)
        if mesh is not None:
            arr = jax.device_put(arr, self.sharding(mesh))
        if self.is_record:
            return RecordArray(arr, self.spec, self.layout)
        return arr

    def wrap(self, data: jax.Array) -> jax.Array | RecordArray:
        """View raw state storage through this handle (a
        :class:`RecordArray` for record tensors, pass-through
        otherwise) — e.g. ``ex.read(state, t)``."""
        if self.is_record:
            return RecordArray(data, self.spec, self.layout)
        return data

    def with_(self, **kw) -> "DistTensor":
        """A copy of this handle with fields replaced, e.g.
        ``t.with_(layout=Layout.SOA)`` (handles are frozen)."""
        return replace(self, **kw)

    def storage_key(self) -> tuple:
        """Identity of the *storage* this handle refers to.  Halo widths
        and boundary policies are access-level attributes (paper §5.4: the
        access modifier is per-node), so two handles of the same name may
        differ in them while sharing one buffer."""
        return (self.name, self.space, str(self.dtype), self.spec,
                self.layout, self.partition, self.subblocks)


@dataclass(frozen=True)
class ReductionResult:
    """Paper's ``ReductionResult<T>`` — a named replicated scalar slot in the
    executor state.  The 'complete' flag of the paper is subsumed by data
    flow: any node consuming the value depends on the psum that produced it,
    per-partition partial reductions still start as soon as their own
    dependencies are met (XLA reduce + all-reduce decomposition)."""

    name: str
    dtype: Any = jnp.float32
    init: float = 0.0

    def value(self, state: dict) -> jax.Array:
        return state[self.name]


def make_reduction_result(
    name: str, init: float = 0.0, dtype: Any = jnp.float32
) -> ReductionResult:
    """Declare a named reduction slot for ``Graph.reduce`` to fill.

    Example::

        total = make_reduction_result("total")
        g.then_reduce(t, total, SumReducer())
        state = execute(g)          # state["total"] holds the sum
    """
    return ReductionResult(name=name, dtype=dtype, init=init)
