"""Graph builder (paper §5.3): emplace / then / split / then_split /
reduce / then_reduce / conditional / sync / subgraphs + access modifiers.

A :class:`Graph` records *levels* of :class:`Node` s — the paper's DAG where
a level contains nodes that may execute in parallel and each level depends
on the previous one.  Per-partition node splitting (the paper's ``split``
creating one node per tensor partition) is realized by SPMD: the executor
lowers the level once and every shard runs it, so the paper's parallel
kernel submission is implicit (DESIGN.md §2).

Access modifiers communicate *how a kernel touches halo data*, which is
exactly the information the paper uses to minimize graph connectivity:

* plain tensor arg                      — no halo read (paper's default);
* ``concurrent_padded_access(t)``       — reads halo, writes a different
  buffer: halo exchange may overlap the kernel's interior compute
  (``overlap=True`` on split nodes enables it for any number of
  mesh-partitioned halo axes — 2-D/3-D decompositions included);
* ``exclusive_padded_access(t)``        — reads halo of a buffer the kernel
  itself updates: the pre-update halo must be captured first (ordering edge);
* ``*_in_shared(t)``                    — additionally stage blocks in VMEM
  (TPU's shared memory) via the Pallas path of the kernel.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field as dfield
from typing import Any, Callable, Optional, Sequence, Union

from .layout import Layout
from .tensor import DistTensor, ReductionResult

__all__ = [
    "ExecutionKind",
    "AccessMode",
    "TensorArg",
    "preferred_layout",
    "concurrent_padded_access",
    "exclusive_padded_access",
    "in_shared",
    "concurrent_padded_access_in_shared",
    "exclusive_padded_access_in_shared",
    "Reducer",
    "SumReducer",
    "MaxReducer",
    "MinReducer",
    "MulReducer",
    "AndReducer",
    "OrReducer",
    "XorReducer",
    "MinimumReducer",
    "MaximumReducer",
    "Node",
    "Graph",
]

_node_counter = itertools.count()


class ExecutionKind(enum.Enum):
    Cpu = "cpu"  # host-executed (outside jit) — heterogeneous nodes
    Gpu = "gpu"  # device-executed (jit/shard_map); TPU in production


class AccessMode(enum.Enum):
    DEFAULT = "default"
    CONCURRENT_PADDED = "concurrent_padded"
    EXCLUSIVE_PADDED = "exclusive_padded"
    SHARED = "shared"
    CONCURRENT_PADDED_SHARED = "concurrent_padded_shared"
    EXCLUSIVE_PADDED_SHARED = "exclusive_padded_shared"

    @property
    def padded(self) -> bool:
        return self in (
            AccessMode.CONCURRENT_PADDED,
            AccessMode.EXCLUSIVE_PADDED,
            AccessMode.CONCURRENT_PADDED_SHARED,
            AccessMode.EXCLUSIVE_PADDED_SHARED,
        )

    @property
    def exclusive(self) -> bool:
        return self in (
            AccessMode.EXCLUSIVE_PADDED,
            AccessMode.EXCLUSIVE_PADDED_SHARED,
        )

    @property
    def shared(self) -> bool:
        return self in (
            AccessMode.SHARED,
            AccessMode.CONCURRENT_PADDED_SHARED,
            AccessMode.EXCLUSIVE_PADDED_SHARED,
        )


@dataclass(frozen=True)
class TensorArg:
    tensor: DistTensor
    mode: AccessMode = AccessMode.DEFAULT
    layout: Optional[Layout] = None  # kernel's preferred layout (solver hint)


def preferred_layout(t: DistTensor | TensorArg,
                     layout: Layout) -> TensorArg:
    """Annotate an argument with the kernel's preferred layout.

    A *hint*, not a pin: the executor's layout solver honors it unless a
    stronger constraint (user ``pin_layout`` or a padded-access
    requirement) overrides it."""
    if isinstance(t, TensorArg):
        return TensorArg(t.tensor, t.mode, layout)
    return TensorArg(t, AccessMode.DEFAULT, layout)


def concurrent_padded_access(t: DistTensor) -> TensorArg:
    """Mark ``t`` as read *including its halo*, written elsewhere.

    The defining access mode of a double-buffered stencil: because the
    node writes a different buffer, the halo exchange may overlap the
    kernel's interior compute (``g.split(..., overlap=True)``).

    Example::

        g.split(laplace, concurrent_padded_access(src), dst, overlap=True)
    """
    return TensorArg(t, AccessMode.CONCURRENT_PADDED)


def exclusive_padded_access(t: DistTensor) -> TensorArg:
    """Mark ``t`` as read including its halo by a node that ALSO updates
    ``t`` in place (paper Fig. 9): the pre-update halo must be captured
    before the write, so the executor threads it as an extra data
    dependency instead of overlapping it.

    Example::

        g.split(fim_sweep, exclusive_padded_access(phi), mask, writes=(0,))
    """
    return TensorArg(t, AccessMode.EXCLUSIVE_PADDED)


def in_shared(t: DistTensor) -> TensorArg:
    """Mark ``t`` for staging through shared memory (VMEM on TPU): the
    kernel's Pallas path DMAs each block into the fast on-chip space
    before computing (paper's ``in_shared()``).  Example:
    ``g.split(kern, in_shared(u), out)``."""
    return TensorArg(t, AccessMode.SHARED)


def concurrent_padded_access_in_shared(t: DistTensor) -> TensorArg:
    """:func:`concurrent_padded_access` + :func:`in_shared`: halo read of
    a separately-written buffer, blocks staged in VMEM (the paper's
    combined modifier, e.g. the FORCE stencil's winning config)."""
    return TensorArg(t, AccessMode.CONCURRENT_PADDED_SHARED)


def exclusive_padded_access_in_shared(t: DistTensor) -> TensorArg:
    """:func:`exclusive_padded_access` + :func:`in_shared`: in-place halo
    read with VMEM staging (the eikonal FIM kernel's configuration)."""
    return TensorArg(t, AccessMode.EXCLUSIVE_PADDED_SHARED)


@dataclass(frozen=True)
class Reducer:
    """Local reduction + cross-shard combiner pair."""

    name: str
    local: Callable  # array -> scalar
    combine: str     # 'add'|'mul'|'max'|'min'|'and'|'or'|'xor'|
                     # 'minimum'|'maximum' (executor picks lax.p* or
                     # all_gather+fold)


def SumReducer() -> Reducer:  # noqa: N802 - mirrors paper naming
    """Sum reduction: ``jnp.sum`` per shard + ``lax.psum`` across shards.
    Example: ``g.then_reduce(t, total, SumReducer())``."""
    import jax.numpy as jnp

    return Reducer("sum", jnp.sum, "add")


def _nan_ignoring(reduce_all, reduce_nan):
    """Per the Ripple spec NaN table, ``min``/``max`` return the NUMBER
    when one operand is a quiet NaN — i.e. quiet NaNs are ignored (the
    all-NaN slice still reduces to qNaN)."""
    import jax.numpy as jnp

    def local(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return reduce_nan(x)
        return reduce_all(x)

    return local


def _nan_propagating(reduce_all):
    """``minimum``/``maximum`` semantics: any quiet NaN operand makes the
    whole reduction qNaN (spec: NUM vs qNaN -> qNaN)."""
    import jax.numpy as jnp

    def local(x):
        x = jnp.asarray(x)
        m = reduce_all(x)
        if jnp.issubdtype(x.dtype, jnp.floating):
            m = jnp.where(jnp.isnan(x).any(),
                          jnp.asarray(jnp.nan, m.dtype), m)
        return m

    return local


def MaxReducer() -> Reducer:  # noqa: N802
    """Max reduction: NaN-ignoring ``max`` per shard (spec: NUM vs qNaN ->
    NUM) + ``lax.pmax`` across shards (e.g. the Euler wavespeed CFL
    bound).  For the NaN-propagating variant use :func:`MaximumReducer`."""
    import jax.numpy as jnp

    return Reducer("max", _nan_ignoring(jnp.max, jnp.nanmax), "max")


def MinReducer() -> Reducer:  # noqa: N802
    """Min reduction: NaN-ignoring ``min`` per shard + ``lax.pmin`` across
    shards.  For the NaN-propagating variant use :func:`MinimumReducer`."""
    import jax.numpy as jnp

    return Reducer("min", _nan_ignoring(jnp.min, jnp.nanmin), "min")


def MulReducer() -> Reducer:  # noqa: N802
    """Product reduction: ``jnp.prod`` per shard; cross-shard combine is an
    all-gather of the per-shard scalars + local fold (no ``lax.pprod``
    exists, and the log-sum trick is wrong for zeros/negatives)."""
    import jax.numpy as jnp

    return Reducer("mul", jnp.prod, "mul")


def AndReducer() -> Reducer:  # noqa: N802
    """Bitwise/logical AND reduction over integer or boolean records
    (e.g. "did every cell converge" flags); all_gather+fold combine."""
    import jax.numpy as jnp

    def local(x):
        x = jnp.asarray(x)
        init = ~jnp.zeros((), x.dtype)  # all-ones identity (True for bool)
        from jax import lax as _lax
        return _lax.reduce(x, init, _lax.bitwise_and, tuple(range(x.ndim)))

    return Reducer("and", local, "and")


def OrReducer() -> Reducer:  # noqa: N802
    """Bitwise/logical OR reduction (e.g. "did any cell hit the boundary"
    flags); all_gather+fold combine."""
    import jax.numpy as jnp

    def local(x):
        x = jnp.asarray(x)
        from jax import lax as _lax
        return _lax.reduce(x, jnp.zeros((), x.dtype), _lax.bitwise_or,
                           tuple(range(x.ndim)))

    return Reducer("or", local, "or")


def XorReducer() -> Reducer:  # noqa: N802
    """Bitwise XOR reduction (parity / checksum-style reductions);
    all_gather+fold combine."""
    import jax.numpy as jnp

    def local(x):
        x = jnp.asarray(x)
        from jax import lax as _lax
        return _lax.reduce(x, jnp.zeros((), x.dtype), _lax.bitwise_xor,
                           tuple(range(x.ndim)))

    return Reducer("xor", local, "xor")


def MinimumReducer() -> Reducer:  # noqa: N802
    """NaN-PROPAGATING min (spec ``minimum``: NUM vs qNaN -> qNaN), the
    float-only companion of :func:`MinReducer`."""
    import jax.numpy as jnp

    return Reducer("minimum", _nan_propagating(jnp.min), "minimum")


def MaximumReducer() -> Reducer:  # noqa: N802
    """NaN-PROPAGATING max (spec ``maximum``: NUM vs qNaN -> qNaN), the
    float-only companion of :func:`MaxReducer`."""
    import jax.numpy as jnp

    return Reducer("maximum", _nan_propagating(jnp.max), "maximum")


NodeArg = Union[DistTensor, TensorArg, ReductionResult, Any]


@dataclass
class Node:
    kind: str                      # 'op' | 'split' | 'reduce' | 'sync' | 'loop'
    fn: Optional[Callable] = None
    args: tuple = ()
    writes: Optional[tuple[int, ...]] = None  # arg indices the fn returns
    exec_kind: ExecutionKind = ExecutionKind.Gpu
    reducer: Optional[Reducer] = None
    result: Optional[ReductionResult] = None
    overlap: bool = False          # interior/boundary comm-compute overlap
    subgraph: Optional["Graph"] = None
    name: str = dfield(default_factory=lambda: f"node{next(_node_counter)}")

    def tensor_args(self):
        for i, a in enumerate(self.args):
            if isinstance(a, TensorArg):
                yield i, a.tensor, a.mode
            elif isinstance(a, DistTensor):
                yield i, a, AccessMode.DEFAULT

    def default_writes(self) -> tuple[int, ...]:
        """Paper convention for split nodes: the last tensor argument is the
        output (saxpy: (a, x, y) writes y; double-buffered stencils:
        (in, out) writes out)."""
        if self.writes is not None:
            return self.writes
        tidx = [i for i, _, _ in self.tensor_args()]
        return (tidx[-1],) if tidx else ()


class Graph:
    """Builder for a level-structured DAG (paper Listings 5-12)."""

    def __init__(self, default_exec: ExecutionKind = ExecutionKind.Gpu,
                 name: str = "graph"):
        self.default_exec = default_exec
        self.name = name
        self.levels: list[list[Node]] = []
        self.condition: Optional[Callable] = None  # state -> bool array

    # -- internals ---------------------------------------------------------
    def _current_level(self) -> list[Node]:
        if not self.levels:
            self.levels.append([])
        return self.levels[-1]

    def _new_level(self) -> list[Node]:
        if not self.levels or self.levels[-1]:
            self.levels.append([])
        return self.levels[-1]

    def _exec(self, kind: Optional[ExecutionKind]) -> ExecutionKind:
        return kind if kind is not None else self.default_exec

    @staticmethod
    def _hint_args(args: tuple, layout: Optional[Layout]) -> tuple:
        """Apply a node-level ``layout=`` preference to record tensor args
        that don't already carry their own hint."""
        if layout is None:
            return args
        out = []
        for a in args:
            if isinstance(a, TensorArg) and a.layout is None \
                    and a.tensor.is_record:
                a = TensorArg(a.tensor, a.mode, layout)
            elif isinstance(a, DistTensor) and a.is_record:
                a = TensorArg(a, AccessMode.DEFAULT, layout)
            out.append(a)
        return tuple(out)

    def _add(self, level: list[Node], item, exec_kind, **kw) -> None:
        if isinstance(item, Graph):
            level.append(Node(kind="loop" if item.condition else "subgraph",
                              subgraph=item,
                              exec_kind=self._exec(exec_kind)))
        else:
            level.append(Node(fn=item, exec_kind=self._exec(exec_kind), **kw))

    # -- paper API -----------------------------------------------------------
    def emplace(self, *items, exec_kind: Optional[ExecutionKind] = None,
                layout: Optional[Layout] = None, **kw) -> "Graph":
        """Add node(s)/subgraph(s) to the *current* level (parallel).

        ``layout=`` marks every record tensor in ``args`` with the node's
        preferred layout (a solver hint, see ``core/executor.py``)."""
        if "args" in kw:
            kw["args"] = self._hint_args(tuple(kw["args"]), layout)
        level = self._current_level()
        for item in items:
            self._add(level, item, exec_kind, kind="op", **kw)
        return self

    def then(self, *items, exec_kind: Optional[ExecutionKind] = None,
             layout: Optional[Layout] = None, **kw) -> "Graph":
        """Add node(s)/subgraph(s) on a *new* level (sequential dep)."""
        if "args" in kw:
            kw["args"] = self._hint_args(tuple(kw["args"]), layout)
        level = self._new_level()
        for item in items:
            self._add(level, item, exec_kind, kind="op", **kw)
        return self

    def split(self, fn: Callable, *args: NodeArg,
              writes: Optional[Sequence[int]] = None,
              exec_kind: Optional[ExecutionKind] = None,
              overlap: bool = False,
              layout: Optional[Layout] = None) -> "Graph":
        """Tensor op on the current level; becomes one node per partition
        (paper §5.3.3) — here: SPMD over the tensor's mesh axes.

        ``overlap=True`` asks for the interior/boundary lowering: the
        padded args' halo transfers (all partitioned axes, corners
        included) fly while the interior program runs.  ``fn`` must then
        be a shape-polymorphic stencil (``m + 2w -> m`` cells along every
        haloed dim).  Declined requests are recorded in
        ``Executor.plan.overlap_fallbacks`` (and warn once when real
        transfers were degraded)."""
        self._current_level().append(
            Node(kind="split", fn=fn, args=self._hint_args(args, layout),
                 writes=None if writes is None else tuple(writes),
                 exec_kind=self._exec(exec_kind), overlap=overlap))
        return self

    def then_split(self, fn: Callable, *args: NodeArg,
                   writes: Optional[Sequence[int]] = None,
                   exec_kind: Optional[ExecutionKind] = None,
                   overlap: bool = False,
                   layout: Optional[Layout] = None) -> "Graph":
        """:meth:`split` on a *new* level (sequential dependency on the
        current one)."""
        self._new_level()
        return self.split(fn, *args, writes=writes, exec_kind=exec_kind,
                          overlap=overlap, layout=layout)

    def reduce(self, tensor: DistTensor, result: ReductionResult,
               reducer: Reducer, field: Optional[str] = None) -> "Graph":
        """Reduce ``tensor`` (or one record ``field`` of it) into the
        ``result`` slot on the current level (paper Listing 8):
        ``reducer.local`` per shard, ``lax.p*`` across the mesh.

        Example::

            total = make_reduction_result("total")
            g.then_reduce(t, total, SumReducer())   # state["total"]
        """
        self._current_level().append(
            Node(kind="reduce", args=(tensor, field), reducer=reducer,
                 result=result, exec_kind=ExecutionKind.Gpu))
        return self

    def then_reduce(self, tensor: DistTensor, result: ReductionResult,
                    reducer: Reducer, field: Optional[str] = None) -> "Graph":
        """:meth:`reduce` on a *new* level (sequential dependency)."""
        self._new_level()
        return self.reduce(tensor, result, reducer, field)

    def sync(self, fn: Optional[Callable] = None) -> "Graph":
        """Full barrier: all pending device work completes, then ``fn`` runs
        on the host (paper §5.3.4)."""
        self._new_level().append(Node(kind="sync", fn=fn,
                                      exec_kind=ExecutionKind.Cpu))
        self._new_level()
        return self

    def conditional(self, pred: Callable) -> "Graph":
        """Execute this graph while ``pred(state)`` is true (paper §5.3.6,
        cf. Listing 9's map-reduce loop).  Proper *while* semantics: the
        predicate gates the first iteration too, so a graph entered with a
        false condition runs zero times."""
        self.condition = pred
        return self

    # -- introspection ---------------------------------------------------------
    def nodes(self):
        """Every node in builder (program) order, levels flattened."""
        for level in self.levels:
            yield from level

    def all_tensors(self) -> dict[str, DistTensor]:
        """Every :class:`DistTensor` the graph touches, by name
        (subgraphs included).  Two accesses of one name must agree on
        storage (space/layout/partition); halo may differ per access."""
        out: dict[str, DistTensor] = {}
        for node in self.nodes():
            if node.subgraph is not None:
                out.update(node.subgraph.all_tensors())
                continue
            for _, t, _ in node.tensor_args():
                prev = out.get(t.name)
                if prev is not None and prev.storage_key() != t.storage_key():
                    raise ValueError(
                        f"tensor name {t.name!r} bound to two different "
                        f"storages (halo/boundary may differ per access; "
                        f"space/layout/partition may not)")
                out[t.name] = t
        return out

    def all_results(self) -> dict[str, ReductionResult]:
        """Every reduction-result slot the graph writes, by name."""
        out: dict[str, ReductionResult] = {}
        for node in self.nodes():
            if node.subgraph is not None:
                out.update(node.subgraph.all_results())
            if node.result is not None:
                out[node.result.name] = node.result
        return out

    def is_device_only(self) -> bool:
        """True when no node needs the host (no ``sync()``, no Cpu
        nodes) — the whole graph can trace into one jitted program."""
        for node in self.nodes():
            if node.kind == "sync":
                return False
            if node.subgraph is not None and not node.subgraph.is_device_only():
                return False
            if node.exec_kind is ExecutionKind.Cpu and node.kind != "subgraph":
                return False
        return True

    def summary(self) -> str:
        """One line per node: level, kind, and the tensors it touches."""
        lines = [f"Graph {self.name!r} ({len(self.levels)} levels)"]
        for i, level in enumerate(self.levels):
            for n in level:
                desc = n.kind
                if n.subgraph is not None:
                    desc += f"[{n.subgraph.name}]"
                ts = ",".join(t.name for _, t, _ in n.tensor_args())
                lines.append(f"  L{i}: {n.name} {desc} ({ts})")
        if self.condition is not None:
            lines.append("  while <condition>")
        return "\n".join(lines)
