"""Polymorphic data layout (paper §4.2) — JAX/TPU adaptation.

Ripple lets a user-defined struct be stored over an N-d space either
contiguously (AoS) or strided (SoA), selected by a single template
parameter, with accessors that make kernel code layout-independent.

Here a :class:`RecordSpec` plays the role of ``StorageDescriptor`` and a
:class:`RecordArray` is the materialized storage over a space:

* ``Layout.AOS``  -> one array of shape ``(*space, C)``   (components minor)
* ``Layout.SOA``  -> one array of shape ``(C, *space)``   (space minor)

TPU note (DESIGN.md §2): on GPU SoA wins via warp coalescing; on TPU it
wins because the minor-most dimension fills the 128-lane VREGs and gives
contiguous HBM->VMEM DMA, while a small minor component dim wastes lanes.
Same paper conclusion, different mechanism.

``RecordArray`` is a pytree, so it moves freely through jit / shard_map /
grad, and :class:`RecordRef` provides the same named accessors over Pallas
``Ref`` blocks so every kernel is written once for both layouts.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Layout",
    "Field",
    "Vector",
    "RecordSpec",
    "RecordArray",
    "RecordRef",
]


class Layout(enum.Enum):
    """Storage layout for record data (paper: contiguous vs strided)."""

    AOS = "aos"  # array-of-structs: components contiguous per cell
    SOA = "soa"  # struct-of-arrays: each component contiguous over space

    def __repr__(self) -> str:  # nicer in config dumps
        return f"Layout.{self.name}"


@dataclass(frozen=True)
class Field:
    """One named member of a record; ``size > 1`` is the paper's Vector<T, D>."""

    name: str
    size: int = 1

    def __post_init__(self) -> None:
        if self.size < 1:
            raise ValueError(f"field {self.name!r}: size must be >= 1")


def Vector(name: str, size: int) -> Field:  # noqa: N802 - mirrors paper API
    """Paper's ``Vector<T, Size>`` member declaration."""
    return Field(name, size)


@dataclass(frozen=True)
class RecordSpec:
    """The ``StorageDescriptor``: ordered named fields of a record."""

    fields: tuple[Field, ...]

    def __post_init__(self) -> None:
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in {names}")

    @classmethod
    def create(cls, *fields: Field | tuple[str, int] | str) -> "RecordSpec":
        norm = []
        for f in fields:
            if isinstance(f, Field):
                norm.append(f)
            elif isinstance(f, str):
                norm.append(Field(f))
            else:
                norm.append(Field(*f))
        return cls(tuple(norm))

    @property
    def num_components(self) -> int:
        return sum(f.size for f in self.fields)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    def offset(self, name: str) -> tuple[int, int]:
        """(start, size) of a field in the component axis (compile-time,
        like the paper's ``get<I>`` offset computation)."""
        start = 0
        for f in self.fields:
            if f.name == name:
                return start, f.size
            start += f.size
        raise KeyError(f"no field {name!r} in {self.names}")


def _component_axis(layout: Layout, ndim_space: int) -> int:
    return ndim_space if layout is Layout.AOS else 0


@jax.tree_util.register_pytree_node_class
class RecordArray:
    """A record-of-fields stored over an N-d space with polymorphic layout.

    The single backing array keeps the abstraction zero-copy for field
    *access* (slices) while making whole-record ops (halo exchange, DMA,
    checkpointing) single-buffer, matching Ripple's single-allocation
    storage.
    """

    __slots__ = ("data", "spec", "layout")

    def __init__(self, data: jax.Array, spec: RecordSpec, layout: Layout):
        self.data = data
        self.spec = spec
        self.layout = layout

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return (self.data,), (self.spec, self.layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        spec, layout = aux
        return cls(children[0], spec, layout)

    # -- construction ----------------------------------------------------
    @classmethod
    def create(
        cls,
        spec: RecordSpec,
        space: Sequence[int],
        layout: Layout = Layout.SOA,
        dtype: Any = jnp.float32,
        fill: float = 0.0,
    ) -> "RecordArray":
        space = tuple(space)
        shape = cls.storage_shape(spec, space, layout)
        return cls(jnp.full(shape, fill, dtype=dtype), spec, layout)

    @classmethod
    def from_fields(
        cls,
        spec: RecordSpec,
        fields: Mapping[str, jax.Array],
        layout: Layout = Layout.SOA,
    ) -> "RecordArray":
        """Build from per-field arrays of shape ``(*space[, size])``;
        size-1 fields may pass ``(*space)`` or ``(*space, 1)``."""
        # resolve the space from any multi-component field first (size-1
        # fields are ambiguous about a trailing 1)
        space = None
        for f in spec.fields:
            if f.size > 1:
                space = tuple(jnp.asarray(fields[f.name]).shape[:-1])
                break
        if space is None:  # all size-1: full shapes are the space
            space = tuple(jnp.asarray(fields[spec.fields[0].name]).shape)
        parts = []
        for f in spec.fields:
            v = jnp.asarray(fields[f.name])
            if f.size == 1 and v.shape == space:
                v = v[..., None]
            if v.shape != (*space, f.size):
                raise ValueError(
                    f"field {f.name!r}: expected {(*space, f.size)} or "
                    f"{space}, got {v.shape}"
                )
            parts.append(v)
        aos = jnp.concatenate(parts, axis=-1)
        out = cls(aos, spec, Layout.AOS)
        return out if layout is Layout.AOS else out.with_layout(layout)

    @staticmethod
    def storage_shape(
        spec: RecordSpec, space: Sequence[int], layout: Layout
    ) -> tuple[int, ...]:
        c = spec.num_components
        return (*space, c) if layout is Layout.AOS else (c, *space)

    # -- basic properties -------------------------------------------------
    @property
    def space(self) -> tuple[int, ...]:
        if self.layout is Layout.AOS:
            return self.data.shape[:-1]
        return self.data.shape[1:]

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def num_components(self) -> int:
        return self.spec.num_components

    def __repr__(self) -> str:
        return (
            f"RecordArray(space={self.space}, fields={self.spec.names}, "
            f"layout={self.layout.name}, dtype={self.dtype})"
        )

    # -- accessors (paper §4.3) -------------------------------------------
    def field(self, name: str) -> jax.Array:
        """Field view with shape ``(*space,)`` (size 1) or ``(*space, size)``."""
        start, size = self.spec.offset(name)
        if self.layout is Layout.AOS:
            v = self.data[..., start : start + size]
        else:
            v = jnp.moveaxis(self.data[start : start + size], 0, -1)
        return v[..., 0] if size == 1 else v

    f = field  # short alias used heavily in kernels/examples

    def set_field(self, name: str, value: jax.Array) -> "RecordArray":
        start, size = self.spec.offset(name)
        value = jnp.asarray(value, dtype=self.dtype)
        if size == 1 and value.ndim == len(self.space):
            value = value[..., None]
        if value.shape != (*self.space, size):
            raise ValueError(
                f"set_field({name!r}): expected {(*self.space, size)}, got {value.shape}"
            )
        if self.layout is Layout.AOS:
            data = self.data.at[..., start : start + size].set(value)
        else:
            data = self.data.at[start : start + size].set(
                jnp.moveaxis(value, -1, 0)
            )
        return RecordArray(data, self.spec, self.layout)

    def to_fields(self) -> dict[str, jax.Array]:
        return {f.name: self.field(f.name) for f in self.spec.fields}

    # -- layout interop (paper: "interoperability of the layouts") ---------
    def with_layout(self, layout: Layout) -> "RecordArray":
        if layout is self.layout:
            return self
        nd = len(self.space)
        if layout is Layout.SOA:  # (*space, C) -> (C, *space)
            data = jnp.moveaxis(self.data, nd, 0)
        else:  # (C, *space) -> (*space, C)
            data = jnp.moveaxis(self.data, 0, nd)
        # materialize the transpose so downstream DMA sees the new layout
        return RecordArray(data.copy(), self.spec, layout)

    # -- whole-record ops used by tensor/halo machinery ---------------------
    def map_data(self, fn) -> "RecordArray":
        """Apply ``fn`` to the raw storage (shape-preserving)."""
        return RecordArray(fn(self.data), self.spec, self.layout)

    def space_axis(self, dim: int) -> int:
        """Storage axis corresponding to space dimension ``dim``."""
        nd = len(self.space)
        if not 0 <= dim < nd:
            raise ValueError(f"dim {dim} out of range for space {self.space}")
        return dim if self.layout is Layout.AOS else dim + 1


class RecordRef:
    """Layout-generic accessor over a Pallas ``Ref`` block (kernel-side).

    A Pallas kernel receives the raw block of the backing array; wrapping it
    in ``RecordRef(ref, spec, layout)`` gives the same ``.get/.set`` component
    API in both layouts, so kernels are written once (paper's core claim).

    Components are returned as plain ``(*block_space)`` arrays — the layout
    only changes *where* they live in the block.
    """

    __slots__ = ("ref", "spec", "layout")

    def __init__(self, ref, spec: RecordSpec, layout: Layout):
        self.ref = ref
        self.spec = spec
        self.layout = layout

    def get(self, name: str, comp: int = 0):
        start, size = self.spec.offset(name)
        if comp >= size:
            raise IndexError(f"{name}[{comp}] out of range (size {size})")
        idx = start + comp
        if self.layout is Layout.AOS:
            return self.ref[..., idx]
        return self.ref[idx]

    def set(self, name: str, value, comp: int = 0) -> None:
        start, size = self.spec.offset(name)
        if comp >= size:
            raise IndexError(f"{name}[{comp}] out of range (size {size})")
        idx = start + comp
        if self.layout is Layout.AOS:
            self.ref[..., idx] = value
        else:
            self.ref[idx] = value

    def get_vector(self, name: str):
        """All components of a vector field, stacked on a NEW leading axis."""
        start, size = self.spec.offset(name)
        return jnp.stack([self.get(name, i) for i in range(size)], axis=0)


def block_spec_for(
    spec: RecordSpec,
    layout: Layout,
    space_block: tuple[int, ...],
    space_index_map,
):
    """Build a Pallas BlockSpec for a RecordArray storage given a *space*
    block shape and index map; the component axis always rides along whole.

    ``space_index_map(*grid_ids) -> space block indices`` — layout handling
    (where the component axis sits) is done here so kernels never branch.
    """
    from jax.experimental import pallas as pl  # local: keep core import-light

    c = spec.num_components
    if layout is Layout.AOS:
        block = (*space_block, c)

        def index_map(*ids):
            return (*space_index_map(*ids), 0)

    else:
        block = (c, *space_block)

        def index_map(*ids):
            return (0, *space_index_map(*ids))

    return pl.BlockSpec(block, index_map)
