"""Polymorphic data layout (paper §4.2) — JAX/TPU adaptation.

Ripple lets a user-defined struct be stored over an N-d space either
contiguously (AoS) or strided (SoA), selected by a single template
parameter, with accessors that make kernel code layout-independent.

Here a :class:`RecordSpec` plays the role of ``StorageDescriptor`` and a
:class:`RecordArray` is the materialized storage over a space:

* ``Layout.AOS``   -> one array of shape ``(*space, C)``   (components minor)
* ``Layout.SOA``   -> one array of shape ``(C, *space)``   (space minor)
* ``Layout.AOSOA`` -> one array of shape ``(*space[:-1], n_tiles, C, tile)``
  — the tiled hybrid: the last space dimension is blocked into
  lane-width-aligned tiles and the component axis sits *between* tiles,
  so each record tile is contiguous (AoS-ish locality) while every
  component within a tile fills whole VREG lanes (SoA-ish vectorization).
  ``tile = gcd(n, 128)``: lane-aligned whenever the extent allows, and
  always an exact tiling (no padding), so conversions are value-exact.

TPU note (DESIGN.md §2): on GPU SoA wins via warp coalescing; on TPU it
wins because the minor-most dimension fills the 128-lane VREGs and gives
contiguous HBM->VMEM DMA, while a small minor component dim wastes lanes.
Same paper conclusion, different mechanism.  AoSoA keeps the lane-filling
minor dim *and* record locality — the paper's "blocked" layout family.

Conversions between any two layouts go through :func:`relayout` (or
``RecordArray.with_layout``), a transpose+reshape that the executor's
layout solver inserts at jit-segment boundaries when the producing and
consuming segments disagree (see ``core/executor.py``).  AoSoA storage
does not support halo or partitioning along the tiled (last) space
dimension — the solver never selects it for such tensors, and a user pin
that forces it raises at validation time.

``RecordArray`` is a pytree, so it moves freely through jit / shard_map /
grad, and :class:`RecordRef` provides the same named accessors over Pallas
``Ref`` blocks so every kernel is written once for both layouts.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Layout",
    "Field",
    "Vector",
    "RecordSpec",
    "RecordArray",
    "RecordRef",
    "relayout",
    "relayout_data",
    "dispatch_with_relayout",
    "storage_candidates",
    "aosoa_tile",
    "AOSOA_LANE",
    "record_grid_1d",
]


class Layout(enum.Enum):
    """Storage layout for record data (paper: contiguous vs strided)."""

    AOS = "aos"      # array-of-structs: components contiguous per cell
    SOA = "soa"      # struct-of-arrays: each component contiguous over space
    AOSOA = "aosoa"  # tiled hybrid: lane-aligned component blocks

    def __repr__(self) -> str:  # nicer in config dumps
        return f"Layout.{self.name}"


AOSOA_LANE = 128  # TPU VREG lane width: preferred AoSoA tile extent


def aosoa_tile(n: int) -> int:
    """Tile extent for an AoSoA last-space-dim of ``n`` cells.

    ``gcd(n, 128)`` — full lane width whenever ``n`` allows, otherwise the
    largest lane-divisor that tiles ``n`` exactly, so no shape ever needs
    padding and every relayout is a pure permutation of values."""
    if n < 1:
        raise ValueError(f"space extent must be >= 1, got {n}")
    return math.gcd(n, AOSOA_LANE)


@dataclass(frozen=True)
class Field:
    """One named member of a record; ``size > 1`` is the paper's Vector<T, D>."""

    name: str
    size: int = 1

    def __post_init__(self) -> None:
        if self.size < 1:
            raise ValueError(f"field {self.name!r}: size must be >= 1")


def Vector(name: str, size: int) -> Field:  # noqa: N802 - mirrors paper API
    """Paper's ``Vector<T, Size>`` member declaration."""
    return Field(name, size)


@dataclass(frozen=True)
class RecordSpec:
    """The ``StorageDescriptor``: ordered named fields of a record."""

    fields: tuple[Field, ...]

    def __post_init__(self) -> None:
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in {names}")

    @classmethod
    def create(cls, *fields: Field | tuple[str, int] | str) -> "RecordSpec":
        norm = []
        for f in fields:
            if isinstance(f, Field):
                norm.append(f)
            elif isinstance(f, str):
                norm.append(Field(f))
            else:
                norm.append(Field(*f))
        return cls(tuple(norm))

    @property
    def num_components(self) -> int:
        """Total scalar components per record (vector fields flattened)."""
        return sum(f.size for f in self.fields)

    @property
    def names(self) -> tuple[str, ...]:
        """Field names in declaration order."""
        return tuple(f.name for f in self.fields)

    def offset(self, name: str) -> tuple[int, int]:
        """(start, size) of a field in the component axis (compile-time,
        like the paper's ``get<I>`` offset computation)."""
        start = 0
        for f in self.fields:
            if f.name == name:
                return start, f.size
            start += f.size
        raise KeyError(f"no field {name!r} in {self.names}")


@jax.tree_util.register_pytree_node_class
class RecordArray:
    """A record-of-fields stored over an N-d space with polymorphic layout.

    The single backing array keeps the abstraction zero-copy for field
    *access* (slices) while making whole-record ops (halo exchange, DMA,
    checkpointing) single-buffer, matching Ripple's single-allocation
    storage.
    """

    __slots__ = ("data", "spec", "layout")

    def __init__(self, data: jax.Array, spec: RecordSpec, layout: Layout):
        self.data = data
        self.spec = spec
        self.layout = layout

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        """Pytree protocol: the backing array is the single leaf, spec +
        layout ride as static aux data (so RecordArrays flow through
        jit / shard_map / grad)."""
        return (self.data,), (self.spec, self.layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Pytree protocol: rebuild from (spec, layout) aux + data leaf."""
        spec, layout = aux
        return cls(children[0], spec, layout)

    # -- construction ----------------------------------------------------
    @classmethod
    def create(
        cls,
        spec: RecordSpec,
        space: Sequence[int],
        layout: Layout = Layout.SOA,
        dtype: Any = jnp.float32,
        fill: float = 0.0,
    ) -> "RecordArray":
        space = tuple(space)
        shape = cls.storage_shape(spec, space, layout)
        return cls(jnp.full(shape, fill, dtype=dtype), spec, layout)

    @classmethod
    def from_fields(
        cls,
        spec: RecordSpec,
        fields: Mapping[str, jax.Array],
        layout: Layout = Layout.SOA,
    ) -> "RecordArray":
        """Build from per-field arrays of shape ``(*space[, size])``;
        size-1 fields may pass ``(*space)`` or ``(*space, 1)``."""
        # resolve the space from any multi-component field first (size-1
        # fields are ambiguous about a trailing 1)
        space = None
        for f in spec.fields:
            if f.size > 1:
                space = tuple(jnp.asarray(fields[f.name]).shape[:-1])
                break
        if space is None:  # all size-1: full shapes are the space
            space = tuple(jnp.asarray(fields[spec.fields[0].name]).shape)
        parts = []
        for f in spec.fields:
            v = jnp.asarray(fields[f.name])
            if f.size == 1 and v.shape == space:
                v = v[..., None]
            if v.shape != (*space, f.size):
                raise ValueError(
                    f"field {f.name!r}: expected {(*space, f.size)} or "
                    f"{space}, got {v.shape}"
                )
            parts.append(v)
        aos = jnp.concatenate(parts, axis=-1)
        out = cls(aos, spec, Layout.AOS)
        return out if layout is Layout.AOS else out.with_layout(layout)

    @staticmethod
    def storage_shape(
        spec: RecordSpec, space: Sequence[int], layout: Layout
    ) -> tuple[int, ...]:
        c = spec.num_components
        space = tuple(space)
        if layout is Layout.AOS:
            return (*space, c)
        if layout is Layout.SOA:
            return (c, *space)
        tile = aosoa_tile(space[-1])
        return (*space[:-1], space[-1] // tile, c, tile)

    # -- basic properties -------------------------------------------------
    @property
    def space(self) -> tuple[int, ...]:
        """The logical N-d space extents (layout-independent)."""
        if self.layout is Layout.AOS:
            return self.data.shape[:-1]
        if self.layout is Layout.SOA:
            return self.data.shape[1:]
        s = self.data.shape
        return (*s[:-3], s[-3] * s[-1])

    @property
    def dtype(self):
        """Element dtype of the backing storage."""
        return self.data.dtype

    @property
    def num_components(self) -> int:
        """Total scalar components per record (see RecordSpec)."""
        return self.spec.num_components

    def __repr__(self) -> str:
        return (
            f"RecordArray(space={self.space}, fields={self.spec.names}, "
            f"layout={self.layout.name}, dtype={self.dtype})"
        )

    # -- accessors (paper §4.3) -------------------------------------------
    def field(self, name: str) -> jax.Array:
        """Field view with shape ``(*space,)`` (size 1) or ``(*space, size)``."""
        start, size = self.spec.offset(name)
        if self.layout is Layout.AOS:
            v = self.data[..., start : start + size]
        elif self.layout is Layout.SOA:
            v = jnp.moveaxis(self.data[start : start + size], 0, -1)
        else:  # AOSOA (*sp', nt, C, tile) -> (*sp', nt, tile, size) -> merge
            v = jnp.moveaxis(self.data[..., start : start + size, :], -2, -1)
            v = v.reshape(*self.space, size)
        return v[..., 0] if size == 1 else v

    f = field  # short alias used heavily in kernels/examples

    def set_field(self, name: str, value: jax.Array) -> "RecordArray":
        """A new RecordArray with field ``name`` replaced by ``value``
        (shape ``(*space,)`` or ``(*space, size)``) — the functional
        counterpart of :meth:`field`, layout handled internally."""
        start, size = self.spec.offset(name)
        value = jnp.asarray(value, dtype=self.dtype)
        if size == 1 and value.ndim == len(self.space):
            value = value[..., None]
        if value.shape != (*self.space, size):
            raise ValueError(
                f"set_field({name!r}): expected {(*self.space, size)}, got {value.shape}"
            )
        if self.layout is Layout.AOS:
            data = self.data.at[..., start : start + size].set(value)
        elif self.layout is Layout.SOA:
            data = self.data.at[start : start + size].set(
                jnp.moveaxis(value, -1, 0)
            )
        else:  # AOSOA: (*space, size) -> (*sp', nt, tile, size) -> swap
            nt, tile = self.data.shape[-3], self.data.shape[-1]
            v = value.reshape(*self.space[:-1], nt, tile, size)
            data = self.data.at[..., start : start + size, :].set(
                jnp.moveaxis(v, -1, -2)
            )
        return RecordArray(data, self.spec, self.layout)

    def to_fields(self) -> dict[str, jax.Array]:
        """All fields as a name -> array dict (layout-independent
        values; the inverse of :meth:`from_fields`)."""
        return {f.name: self.field(f.name) for f in self.spec.fields}

    # -- layout interop (paper: "interoperability of the layouts") ---------
    def _to_aos_data(self) -> jax.Array:
        """Canonical AoS view ``(*space, C)`` of the storage."""
        nd = len(self.space)
        if self.layout is Layout.AOS:
            return self.data
        if self.layout is Layout.SOA:
            return jnp.moveaxis(self.data, 0, nd)
        # AOSOA (*sp', nt, C, tile) -> (*sp', nt, tile, C) -> (*space, C)
        v = jnp.moveaxis(self.data, -2, -1)
        return v.reshape(*self.space, self.num_components)

    def with_layout(self, layout: Layout) -> "RecordArray":
        """Convert to ``layout`` (value-exact; all pairs go via AoS).

        The transpose is materialized (``.copy()``) so downstream DMA /
        kernels see the new physical order — this is the relayout cost the
        executor's solver weighs against kernel layout preferences."""
        if layout is self.layout:
            return self
        aos = self._to_aos_data()
        space = self.space
        if layout is Layout.AOS:
            data = aos
        elif layout is Layout.SOA:
            data = jnp.moveaxis(aos, len(space), 0)
        else:  # AOS -> AOSOA
            tile = aosoa_tile(space[-1])
            c = self.num_components
            v = aos.reshape(*space[:-1], space[-1] // tile, tile, c)
            data = jnp.moveaxis(v, -1, -2)
        # materialize the transpose so downstream DMA sees the new layout
        return RecordArray(data.copy(), self.spec, layout)

    # -- whole-record ops used by tensor/halo machinery ---------------------
    def map_data(self, fn) -> "RecordArray":
        """Apply ``fn`` to the raw storage (shape-preserving)."""
        return RecordArray(fn(self.data), self.spec, self.layout)

    def space_axis(self, dim: int) -> int:
        """Storage axis corresponding to space dimension ``dim``."""
        nd = len(self.space)
        if not 0 <= dim < nd:
            raise ValueError(f"dim {dim} out of range for space {self.space}")
        if self.layout is Layout.AOS:
            return dim
        if self.layout is Layout.SOA:
            return dim + 1
        if dim == nd - 1:
            raise ValueError(
                "AOSOA tiles the last space dim across two storage axes; "
                "per-axis ops (halo, partition) are unsupported there")
        return dim


def relayout(arr: RecordArray, target: Layout) -> RecordArray:
    """Convert ``arr`` to ``target`` layout (no-op when already there).

    The paper's layout interoperability as a first-class graph operation:
    the executor's layout solver emits exactly this at segment boundaries
    when a producer and consumer disagree on a tensor's layout."""
    return arr.with_layout(target)


def relayout_data(data, spec: RecordSpec, src: Layout, dst: Layout):
    """Pure, trace-safe relayout on *raw* record storage.

    This is the form the executor's region compiler emits *inside* a
    fused region program: the boundary conversion between two jit
    segments is a plain transpose+reshape of the backing array, so it
    can be traced into the region executable instead of dispatched
    eagerly from Python between segment calls.  Value-identical to
    ``relayout(RecordArray(data, spec, src), dst).data``."""
    if src is dst:
        return data
    return RecordArray(data, spec, src).with_layout(dst).data


def storage_candidates(space: Sequence[int], halo: Sequence[int] = (),
                       partition: Sequence = ()) -> tuple[Layout, ...]:
    """The layouts a record over ``space`` can physically be stored in.

    AoS and SoA are always feasible.  AoSoA tiles the LAST space dim
    across two storage axes, so it is excluded whenever that dim carries
    a halo or a mesh partition (per-axis ops — halo exchange, sharding —
    cannot address it); this is the same rule the executor's layout
    solver clamps with, and the candidate set the measured autotuner
    (``repro.tuning``) searches over.

    Example::

        >>> storage_candidates((4, 256))
        (Layout.AOS, Layout.SOA, Layout.AOSOA)
        >>> storage_candidates((4, 256), halo=(0, 1))
        (Layout.AOS, Layout.SOA)
    """
    space = tuple(space)
    nd = len(space)
    halo = tuple(halo) or (0,) * nd
    partition = tuple(partition) or (None,) * nd
    if halo[nd - 1] or partition[nd - 1] is not None:
        return (Layout.AOS, Layout.SOA)
    return (Layout.AOS, Layout.SOA, Layout.AOSOA)


def dispatch_with_relayout(kernel_fn, rec: RecordArray, *args,
                           supported: Sequence[Layout],
                           preferred: Layout, **kw):
    """Run ``kernel_fn(rec, *args, **kw)``, staging ``rec`` through
    ``preferred`` when its layout is not in ``supported`` and converting
    the result back — the single implementation of the relayout-fallback
    contract every kernel ops wrapper shares."""
    if rec.layout in supported:
        return kernel_fn(rec, *args, **kw)
    out = kernel_fn(relayout(rec, preferred), *args, **kw)
    return relayout(out, rec.layout)


class RecordRef:
    """Layout-generic accessor over a Pallas ``Ref`` block (kernel-side).

    A Pallas kernel receives the raw block of the backing array; wrapping it
    in ``RecordRef(ref, spec, layout)`` gives the same ``.get/.set`` component
    API in both layouts, so kernels are written once (paper's core claim).

    Components are returned as plain ``(*block_space)`` arrays for AoS/SoA —
    the layout only changes *where* they live in the block.  For AoSoA the
    component keeps its tiled block shape ``(*lead, n_tiles, tile)``: get
    and set are symmetric, so elementwise kernel bodies (the common case)
    are still layout-oblivious.
    """

    __slots__ = ("ref", "spec", "layout")

    def __init__(self, ref, spec: RecordSpec, layout: Layout):
        self.ref = ref
        self.spec = spec
        self.layout = layout

    def get(self, name: str, comp: int = 0):
        start, size = self.spec.offset(name)
        if comp >= size:
            raise IndexError(f"{name}[{comp}] out of range (size {size})")
        idx = start + comp
        if self.layout is Layout.AOS:
            return self.ref[..., idx]
        if self.layout is Layout.SOA:
            return self.ref[idx]
        return self.ref[..., idx, :]

    def set(self, name: str, value, comp: int = 0) -> None:
        start, size = self.spec.offset(name)
        if comp >= size:
            raise IndexError(f"{name}[{comp}] out of range (size {size})")
        idx = start + comp
        if self.layout is Layout.AOS:
            self.ref[..., idx] = value
        elif self.layout is Layout.SOA:
            self.ref[idx] = value
        else:
            self.ref[..., idx, :] = value

    def get_vector(self, name: str):
        """All components of a vector field, stacked on a NEW leading axis."""
        start, size = self.spec.offset(name)
        return jnp.stack([self.get(name, i) for i in range(size)], axis=0)


def record_grid_1d(spec: RecordSpec, layout: Layout, n: int, block: int):
    """Grid + BlockSpec for a 1-d record kernel processing ``block`` cells
    per program, in any layout (the single place the AoSoA tiling math
    lives — kernels over 1-d record spaces should not re-derive it).

    AoS/SoA: ``block`` must divide ``n``.  AoSoA: each program receives
    whole ``(bt, C, tile)`` record tiles, ``bt`` the largest tile count
    <= block/tile that divides the total tile count.
    """
    from jax.experimental import pallas as pl  # local: keep core import-light

    c = spec.num_components
    if layout is Layout.AOS:
        return (n // block,), pl.BlockSpec((block, c), lambda i: (i, 0))
    if layout is Layout.SOA:
        return (n // block,), pl.BlockSpec((c, block), lambda i: (0, i))
    tile = aosoa_tile(n)
    bt = max(block // tile, 1)
    nt = n // tile
    while nt % bt:
        bt -= 1
    return (nt // bt,), pl.BlockSpec((bt, c, tile), lambda i: (i, 0, 0))


def block_spec_for(
    spec: RecordSpec,
    layout: Layout,
    space_block: tuple[int, ...],
    space_index_map,
):
    """Build a Pallas BlockSpec for a RecordArray storage given a *space*
    block shape and index map; the component axis always rides along whole.

    ``space_index_map(*grid_ids) -> space block indices`` — layout handling
    (where the component axis sits) is done here so kernels never branch.

    For ``Layout.AOSOA`` the last entry of ``space_block`` must equal the
    storage tile extent (``aosoa_tile`` of the space extent) and the index
    map's last output addresses tile-count units: each program gets one
    whole ``(…, 1, C, tile)`` record tile.
    """
    from jax.experimental import pallas as pl  # local: keep core import-light

    c = spec.num_components
    if layout is Layout.AOS:
        block = (*space_block, c)

        def index_map(*ids):
            return (*space_index_map(*ids), 0)

    elif layout is Layout.SOA:
        block = (c, *space_block)

        def index_map(*ids):
            return (0, *space_index_map(*ids))

    else:  # AOSOA: the last space-block extent must be whole tiles; the
        # grid index along that dim addresses tile-count units.
        tile = space_block[-1]
        block = (*space_block[:-1], 1, c, tile)

        def index_map(*ids):
            return (*space_index_map(*ids), 0, 0)

    return pl.BlockSpec(block, index_map)
