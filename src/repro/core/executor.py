"""Graph executor (paper §6) — compiles a Ripple Graph to jitted SPMD code.

The paper schedules graph nodes dynamically with a heterogeneous
work-stealing pool.  Under SPMD/XLA that role collapses into *lowering
decisions* (DESIGN.md §2/§4), which this executor makes explicitly:

* consecutive device levels are fused into one jit *segment* so XLA's
  latency-hiding scheduler can overlap collectives with compute across the
  paper's level boundaries (the paper's "compact GPU pipelines");
* a segment with partitioned tensors is lowered through one ``shard_map``
  — the paper's one-node-per-partition becomes one program per shard;
* ``concurrent_padded_access`` + ``overlap=True`` splits the stencil into
  interior/boundary programs so the halo ppermute flies during interior
  compute (paper Fig. 7);
* ``exclusive_padded_access`` captures the pre-update halo first and
  threads it as a data dependency (paper Fig. 9's extra edges);
* host (Cpu) nodes and ``sync()`` break segments — the host work runs
  between jit calls (heterogeneous execution);
* a graph with ``conditional`` becomes a ``lax.while_loop`` (device) or a
  host do/while (if it contains host nodes);
* state buffers are donated to each segment (the paper's allocator-reuse,
  C6): steps update state in place;
* a **layout solver** (paper §4.2's polymorphic layout made a compiler
  decision) assigns each record tensor a storage layout *per jit segment*:
  a user pin (``DistTensor.pin_layout``) is always honored, a node-level
  preference (``preferred_layout`` / ``layout=`` on graph methods) is
  honored next, padded (halo) access clamps AoSoA back to a per-axis
  layout, and otherwise the declared layout stands.  Where the producing
  and consuming segments disagree, the executor inserts an explicit
  relayout step at the segment boundary (``LayoutPlan.relayouts`` lists
  them for introspection).  Outside a call, every state dict is kept in
  the plan's *initial* layouts (the trailing conversions are undone on
  exit), so state dicts are interchangeable between calls and re-inits.
  Device-only graphs always collapse into a single jit segment, so the
  layout choice is naturally uniform there — layout changes never happen
  inside a jitted loop body.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field as dfield
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map_compat
from . import halo as halo_lib
from .graph import AccessMode, ExecutionKind, Graph, Node, TensorArg
from .layout import Layout, RecordArray, relayout
from .tensor import DistTensor, ReductionResult

__all__ = ["Executor", "execute", "make_mesh", "LayoutPlan", "RelayoutStep",
           "solve_layouts"]

# version-guarded shard_map accepting the modern kwarg set — bound here so
# the executor does not depend on repro/__init__'s global jax monkeypatch
shard_map = shard_map_compat()


def make_mesh(shape, axis_names) -> Mesh:
    """make_mesh with Auto axis types, version-guarded: older JAX installs
    have neither ``jax.sharding.AxisType`` nor the ``axis_types`` kwarg
    (the single guard implementation lives in ``repro.compat``)."""
    from ..compat import make_mesh_auto

    return make_mesh_auto(shape, axis_names)


@dataclass
class _HaloEntry:
    dim: int
    storage_axis: int
    width: int
    mesh_axis: Optional[str]  # None -> boundary-pad only


def _halo_plan(t: DistTensor, mesh: Optional[Mesh]) -> list[_HaloEntry]:
    plan = []
    for d, w in enumerate(t.halo):
        if w == 0:
            continue
        ax = t.partition[d]
        if mesh is None or ax is None or mesh.shape[ax] == 1:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, None))
        else:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, ax))
    return plan


def _apply_halo(data: jax.Array, t: DistTensor, mesh: Optional[Mesh]) -> jax.Array:
    for e in _halo_plan(t, mesh):
        if e.mesh_axis is None:
            data = halo_lib.pad_boundary_only(
                data, axis=e.storage_axis, width=e.width,
                boundary=t.boundary, constant=t.boundary_constant)
        else:
            data = halo_lib.exchange(
                data, axis=e.storage_axis, width=e.width, axis_name=e.mesh_axis,
                boundary=t.boundary, constant=t.boundary_constant)
    return data


def _slice(x, axis, start, size):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size)
    return x[tuple(idx)]


# -- layout solver (paper §4.2 as a per-segment compiler pass) -----------------

@dataclass(frozen=True)
class RelayoutStep:
    """An explicit layout conversion the executor inserts at a segment
    boundary: ``tensor`` is converted ``src -> dst`` before ``segment``."""

    segment: int
    tensor: str
    src: Layout
    dst: Layout


@dataclass
class LayoutPlan:
    """Solver output: one layout choice per record tensor per segment.

    ``initial`` is what :meth:`Executor.init_state` materializes (the first
    consuming segment's choice, so the common case needs zero relayouts);
    ``relayouts`` are the boundary conversions of one sequential pass."""

    per_segment: list[dict[str, Layout]] = dfield(default_factory=list)
    initial: dict[str, Layout] = dfield(default_factory=dict)
    relayouts: list[RelayoutStep] = dfield(default_factory=list)


def _segment_nodes(kind: str, payload):
    """All nodes a segment executes (loop bodies recursively)."""
    if kind == "device":
        for level in payload:
            yield from level
    elif kind in ("loop", "host_loop"):
        yield from _graph_nodes(payload)
    elif kind == "host":
        yield payload


def _graph_nodes(g: Graph):
    for node in g.nodes():
        if node.subgraph is not None:
            yield from _graph_nodes(node.subgraph)
        else:
            yield node


def _clamp_layout(t: DistTensor, lay: Layout) -> Layout:
    """AoSoA cannot carry halo/partition on the tiled (last) dim; fall back
    to SoA (the per-axis layout the halo machinery favors) when it would."""
    if lay is not Layout.AOSOA or not t.is_record:
        return lay
    nd = len(t.space)
    if t.halo[nd - 1] or t.partition[nd - 1] is not None:
        return Layout.SOA
    return lay


def solve_layouts(
    segments,
    tensors: dict[str, DistTensor],
    overrides: Optional[dict[str, Layout]] = None,
) -> LayoutPlan:
    """Choose a storage layout per record tensor per segment.

    Decision order per tensor (first match wins):

    1. ``overrides`` — a parent executor's already-made choice (loop
       sub-executors must agree with the enclosing plan);
    2. ``DistTensor.pin_layout`` — the user's pin;
    3. the first node-level preference (``TensorArg.layout``) in node
       order, clamped by halo/partition feasibility;
    4. the tensor's declared layout (clamped the same way).

    Segments are the executor's host-boundary segmentation, so a
    device-only graph is one segment and gets one uniform decision.
    """
    overrides = overrides or {}

    def choose(nodes) -> dict[str, Layout]:
        hints: dict[str, Layout] = {}
        seen: set[str] = set()
        no_aosoa: set[str] = set()
        for node in nodes:
            for a in node.args:
                if isinstance(a, TensorArg):
                    t, hint = a.tensor, a.layout
                elif isinstance(a, DistTensor):
                    t, hint = a, None
                else:
                    continue
                if not t.is_record:
                    continue
                seen.add(t.name)
                # feasibility is per ACCESS handle: halo widths are
                # access-level (storage_key excludes them), so any haloed
                # access vetoes AoSoA for the shared storage
                if _clamp_layout(t, Layout.AOSOA) is not Layout.AOSOA:
                    no_aosoa.add(t.name)
                if hint is not None and t.name not in hints:
                    hints[t.name] = hint
        out: dict[str, Layout] = {}
        for name in seen:
            t = tensors[name]
            if name in overrides:
                out[name] = overrides[name]
            elif t.pin_layout:
                # an infeasible pin is a user error, surfaced at
                # construction (mesh or not), never worked around
                if t.layout is Layout.AOSOA and (
                        name in no_aosoa
                        or _clamp_layout(t, Layout.AOSOA)
                        is not Layout.AOSOA):
                    raise ValueError(
                        f"{name}: pinned AOSOA layout is infeasible — the "
                        f"tensor carries a halo or partition on the tiled "
                        f"(last) space dim")
                out[name] = t.layout
            else:
                lay = _clamp_layout(t, hints.get(name, t.layout))
                if lay is Layout.AOSOA and name in no_aosoa:
                    lay = Layout.SOA
                out[name] = lay
        return out

    per_segment = [choose(list(_segment_nodes(k, p))) for k, p in segments]

    plan = LayoutPlan(per_segment=per_segment)
    current: dict[str, Layout] = {}
    for i, seg in enumerate(per_segment):
        for name, lay in seg.items():
            cur = current.get(name)
            if cur is None:
                plan.initial[name] = lay
            elif cur is not lay:
                plan.relayouts.append(RelayoutStep(i, name, cur, lay))
            current[name] = lay
    for name, t in tensors.items():
        if t.is_record and name not in plan.initial:
            plan.initial[name] = t.layout
    return plan


class Executor:
    """Compile + run a Graph against an optional mesh."""

    def __init__(self, graph: Graph, mesh: Optional[Mesh] = None,
                 donate: bool = True,
                 layout_overrides: Optional[dict[str, Layout]] = None):
        self.graph = graph
        self.mesh = mesh
        self.donate = donate
        self.tensors = graph.all_tensors()
        self.results = graph.all_results()
        self._segments = self._build_segments(graph)
        self.plan = solve_layouts(self._segments, self.tensors,
                                  overrides=layout_overrides)
        # physical layout of each record tensor's state entry right now
        self._state_layouts: dict[str, Layout] = dict(self.plan.initial)
        if mesh is not None:
            for name, t in self.tensors.items():
                lays = {self.plan.initial.get(name, t.layout)}
                lays.update(seg[name] for seg in self.plan.per_segment
                            if name in seg)
                for lay in lays:
                    (t.with_(layout=lay) if t.is_record
                     else t).validate_mesh(mesh)
        self._jitted: dict[int, Callable] = {}

    # -- layout plumbing ---------------------------------------------------
    def _eff(self, t: DistTensor) -> DistTensor:
        """The tensor handle in its *current physical* layout."""
        if not t.is_record:
            return t
        lay = self._state_layouts.get(t.name, t.layout)
        return t if lay is t.layout else t.with_(layout=lay)

    def _apply_segment_layouts(self, state: dict, seg: int) -> dict:
        """Insert the solver's relayout steps before segment ``seg``:
        convert every tensor whose physical layout disagrees with the
        segment's chosen layout (paper: explicit layout-interop nodes)."""
        return self._convert_layouts(state, self.plan.per_segment[seg])

    def _restore_initial_layouts(self, state: dict) -> dict:
        """Undo trailing conversions so that outside a call every state
        dict is in the plan's initial layouts — state dicts stay
        interchangeable between calls, re-inits, and ``read``."""
        return self._convert_layouts(state, self.plan.initial)

    def _convert_layouts(self, state: dict,
                         targets: dict[str, Layout]) -> dict:
        for name, lay in targets.items():
            t = self.tensors[name]
            cur = self._state_layouts.get(name, t.layout)
            if cur is lay:
                continue
            arr = relayout(RecordArray(state[name], t.spec, cur), lay)
            data = arr.data
            self._state_layouts[name] = lay
            if self.mesh is not None:
                data = jax.device_put(data,
                                      self._eff(t).sharding(self.mesh))
            state[name] = data
        return state

    # -- state management ------------------------------------------------
    def init_state(self, **overrides) -> dict[str, Any]:
        """Allocate all tensors/results (zeros unless overridden).

        Record tensors are materialized directly in the layout the solver
        chose for their first consuming segment; a RecordArray override in
        another layout is relayouted on the way in."""
        self._state_layouts = dict(self.plan.initial)
        state: dict[str, Any] = {}
        for name, t in self.tensors.items():
            eff = self._eff(t)
            if name in overrides:
                v = overrides[name]
                if isinstance(v, RecordArray):
                    data = relayout(v, eff.layout).data
                elif t.is_record:
                    v = jnp.asarray(v)
                    src = self._infer_override_layout(t, v.shape)
                    data = relayout(RecordArray(v, t.spec, src),
                                    eff.layout).data
                else:
                    data = jnp.asarray(v)
                if self.mesh is not None:
                    data = jax.device_put(data, eff.sharding(self.mesh))
                state[name] = data
            else:
                v = eff.init(self.mesh)
                state[name] = v.data if isinstance(v, RecordArray) else v
        for name, r in self.results.items():
            state[name] = jnp.asarray(r.init, dtype=r.dtype)
        return state

    def _infer_override_layout(self, t: DistTensor, shape) -> Layout:
        """Which layout a raw (non-RecordArray) record override is stored
        in, by matching the storage shape against each layout's.  The two
        plausible sources are the solver's initial layout (an executor-
        produced state entry outside a call is always in it) and the
        declared layout (hand-built arrays).  When those differ and the
        shape matches both, guessing could silently scramble the data, so
        we refuse and ask for a RecordArray; otherwise the unique
        matching candidate wins."""
        def fits(lay):
            return tuple(shape) == RecordArray.storage_shape(
                t.spec, t.space, lay)

        preferred = list(dict.fromkeys(
            [self.plan.initial.get(t.name, t.layout), t.layout]))
        matches = [lay for lay in preferred if fits(lay)]
        if len(matches) == 1:
            return matches[0]
        if len(matches) > 1:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in matches]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        others = [lay for lay in Layout
                  if lay not in preferred and fits(lay)]
        if len(others) == 1:
            return others[0]
        if others:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in others]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        raise ValueError(
            f"{t.name}: override shape {tuple(shape)} matches no layout's "
            f"storage shape for space {t.space} "
            f"(pass a RecordArray to make the layout explicit)")

    def state_shardings(self, state: dict) -> dict:
        if self.mesh is None:
            return {k: None for k in state}
        out = {}
        for k in state:
            t = self.tensors.get(k)
            spec = self._eff(t).pspec() if t is not None else P()
            out[k] = NamedSharding(self.mesh, spec)
        return out

    def read(self, state: dict, t: DistTensor):
        """Wrap a state entry back into its RecordArray view (in the
        tensor's current physical layout; accessors hide the difference)."""
        return self._eff(t).wrap(state[t.name])

    # -- segmentation ------------------------------------------------------
    def _build_segments(self, graph: Graph):
        """Split levels into host/device segments.

        Returns a list of ('device', [levels...]) / ('host', node) /
        ('loop', subgraph) entries.  Subgraphs without conditions are
        inlined into the level stream.
        """
        segments: list[tuple[str, Any]] = []
        device_levels: list[list[Node]] = []

        def flush():
            nonlocal device_levels
            if device_levels:
                segments.append(("device", device_levels))
                device_levels = []

        def walk(g: Graph):
            nonlocal device_levels
            for level in g.levels:
                dev_nodes: list[Node] = []
                for node in level:
                    if node.kind == "subgraph":
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        walk(node.subgraph)
                    elif node.kind == "loop":
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        if node.subgraph.is_device_only():
                            flush()
                            segments.append(("loop", node.subgraph))
                        else:
                            flush()
                            segments.append(("host_loop", node.subgraph))
                    elif node.kind == "sync" or node.exec_kind is ExecutionKind.Cpu:
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        flush()
                        segments.append(("host", node))
                    else:
                        dev_nodes.append(node)
                if dev_nodes:
                    device_levels.append(dev_nodes)
            return

        walk(graph)
        flush()
        return segments

    # -- node lowering (called inside shard_map / plain trace) ----------------
    def _resolve_args(self, node: Node, state: dict, sharded: bool):
        """Build the python args passed to a node fn; haloed where needed."""
        mesh = self.mesh if sharded else None
        vals = []
        for i, a in enumerate(node.args):
            if isinstance(a, ReductionResult):
                vals.append(state[a.name])
                continue
            t = None
            mode = AccessMode.DEFAULT
            from .graph import TensorArg
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t = a
            if t is None:
                vals.append(a)
                continue
            t = self._eff(t)
            data = state[t.name]
            if mode.padded:
                data = _apply_halo(data, t, mesh)
            vals.append(t.wrap(data) if t.is_record else data)
        return vals

    def _lower_split(self, node: Node, state: dict, sharded: bool) -> None:
        writes = node.default_writes()
        write_tensors = []
        for i in writes:
            a = node.args[i]
            from .graph import TensorArg
            write_tensors.append(a.tensor if isinstance(a, TensorArg) else a)

        if node.overlap and sharded and self._overlap_entry(node) is not None:
            self._lower_split_overlapped(node, state, write_tensors)
            return

        vals = self._resolve_args(node, state, sharded)
        out = node.fn(*vals)
        self._store_writes(node, state, write_tensors, out)

    def _store_writes(self, node, state, write_tensors, out) -> None:
        if not write_tensors:
            return
        if len(write_tensors) == 1:
            out = (out,)
        if len(out) != len(write_tensors):
            raise ValueError(
                f"{node.name}: fn returned {len(out)} values for "
                f"{len(write_tensors)} writes")
        for t, v in zip(write_tensors, out):
            data = v.data if isinstance(v, RecordArray) else jnp.asarray(v)
            state[t.name] = data

    def _overlap_entry(self, node: Node) -> Optional[tuple[DistTensor, _HaloEntry]]:
        """Overlap lowering applies when exactly one padded-access arg has
        exactly one mesh-partitioned halo dim."""
        cands = []
        for i, t, mode in node.tensor_args():
            if not mode.padded:
                continue
            t = self._eff(t)
            entries = [e for e in _halo_plan(t, self.mesh) if e.mesh_axis]
            if len(entries) == 1:
                cands.append((t, entries[0]))
            elif entries:
                return None
        return cands[0] if len(cands) == 1 else None

    def _lower_split_overlapped(self, node: Node, state: dict,
                                write_tensors) -> None:
        """Interior/boundary split: ppermute of halos overlaps the interior
        stencil program (paper Fig. 7).  fn must be a stencil mapping
        (m + 2w) -> m cells along the partitioned dim."""
        t, entry = self._overlap_entry(node)
        ax, w = entry.storage_axis, entry.width
        from .graph import TensorArg

        def arg_variant(variant: str):
            """Resolve args with the padded arg replaced per variant."""
            vals = []
            for i, a in enumerate(node.args):
                if isinstance(a, ReductionResult):
                    vals.append(state[a.name])
                    continue
                at, mode = (a.tensor, a.mode) if isinstance(a, TensorArg) else (
                    (a, AccessMode.DEFAULT) if isinstance(a, DistTensor) else (None, None))
                if at is None:
                    vals.append(a)
                    continue
                at = self._eff(at)
                data = state[at.name]
                if at.name == t.name and mode.padded:
                    # boundary-pad the non-partitioned haloed dims first
                    for e in _halo_plan(at, self.mesh):
                        if e.mesh_axis is None:
                            data = halo_lib.pad_boundary_only(
                                data, axis=e.storage_axis, width=e.width,
                                boundary=at.boundary,
                                constant=at.boundary_constant)
                    left, right = halo_lib.halo_blocks(
                        data, axis=ax, width=w, axis_name=entry.mesh_axis,
                        boundary=at.boundary, constant=at.boundary_constant)
                    n = data.shape[ax]
                    if variant == "interior":
                        data = data  # (n,) -> fn -> n - 2w interior cells
                    elif variant == "left":
                        data = jnp.concatenate(
                            [left, _slice(data, ax, 0, 2 * w)], axis=ax)
                    else:
                        data = jnp.concatenate(
                            [_slice(data, ax, n - 2 * w, 2 * w), right], axis=ax)
                elif mode.padded:
                    data = _apply_halo(data, at, self.mesh)
                else:
                    # non-padded args must be sliced to match output extent
                    if at.name != t.name and variant != "interior":
                        n_out = state[t.name].shape[ax]
                        s_ax = ax
                        if variant == "left":
                            data = _slice(data, s_ax, 0, w)
                        else:
                            data = _slice(data, s_ax, n_out - w, w)
                    elif variant == "interior" and at.name != t.name:
                        n_out = state[t.name].shape[ax]
                        data = _slice(data, ax, w, n_out - 2 * w)
                vals.append(at.wrap(data) if at.is_record else data)
            return vals

        def run(variant: str):
            out = node.fn(*arg_variant(variant))
            if len(write_tensors) == 1:
                out = (out,)
            return [v.data if isinstance(v, RecordArray) else jnp.asarray(v)
                    for v in out]

        interior = run("interior")
        left = run("left")
        right = run("right")
        for wt, li, ii, ri in zip(write_tensors, left, interior, right):
            state[wt.name] = jnp.concatenate(
                [li, ii, ri], axis=self._eff(wt).storage_axis(entry.dim))

    def _lower_reduce(self, node: Node, state: dict, sharded: bool) -> None:
        t, field = node.args
        data = state[t.name]
        if t.is_record and field is not None:
            data = self._eff(t).wrap(data).field(field)
        local = node.reducer.local(data)
        if sharded:
            axes = tuple({ax for ax in t.partition if ax is not None
                          and self.mesh.shape[ax] > 1})
            if axes:
                op = {"add": lax.psum, "max": lax.pmax, "min": lax.pmin}[
                    node.reducer.combine]
                local = op(local, axes)
        state[node.result.name] = jnp.asarray(local, dtype=node.result.dtype)

    def _lower_levels(self, levels, state: dict, sharded: bool) -> dict:
        state = dict(state)
        for level in levels:
            # paper: nodes on a level are independent -> lower all against the
            # same input snapshot, then merge (XLA runs them in parallel).
            snapshot = dict(state)
            for node in level:
                if node.kind == "split":
                    tmp = dict(snapshot)
                    self._lower_split(node, tmp, sharded)
                    for k, v in tmp.items():
                        if k not in snapshot or v is not snapshot[k]:
                            state[k] = v
                elif node.kind == "reduce":
                    tmp = dict(snapshot)
                    self._lower_reduce(node, tmp, sharded)
                    state[node.result.name] = tmp[node.result.name]
                elif node.kind == "op":
                    tmp = dict(snapshot)
                    vals = self._resolve_args(node, tmp, sharded)
                    writes = node.default_writes()
                    wt = []
                    from .graph import TensorArg
                    for i in writes:
                        a = node.args[i]
                        wt.append(a.tensor if isinstance(a, TensorArg) else a)
                    out = node.fn(*vals) if node.fn is not None else None
                    if wt:
                        self._store_writes(node, tmp, wt, out)
                        for t in wt:
                            state[t.name] = tmp[t.name]
                else:
                    raise ValueError(f"unexpected node kind {node.kind}")
        return state

    # -- segment compilation -----------------------------------------------
    def _device_fn(self, levels) -> Callable:
        sharded = self.mesh is not None and any(
            ax is not None for t in self.tensors.values() for ax in t.partition)

        def body(state):
            return self._lower_levels(levels, state, sharded)

        if not sharded:
            return jax.jit(body, donate_argnums=0 if self.donate else ())

        in_specs = {}
        # specs must cover exactly the state dict; build lazily per call
        def call(state):
            specs = {k: (self._eff(self.tensors[k]).pspec()
                         if k in self.tensors else P())
                     for k in state}
            fn = shard_map(body, mesh=self.mesh, in_specs=(specs,),
                               out_specs=specs, check_vma=False)
            return fn(state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    def _loop_fn(self, sub: Graph, seg: int) -> Callable:
        # the sub-executor must agree with the enclosing plan: layouts are
        # loop-invariant inside one compiled while body
        sub_exec = Executor(sub, self.mesh, donate=False,
                            layout_overrides=self.plan.per_segment[seg])
        sharded = self.mesh is not None and any(
            ax is not None for t in sub_exec.tensors.values()
            for ax in t.partition)

        def body_fn(state):
            s = state
            for kind, payload in sub_exec._segments:
                if kind != "device":
                    raise ValueError("device loop with host segment")
                s = sub_exec._lower_levels(payload, s, sharded)
            return s

        def call(state):
            if sharded:
                specs = {k: (sub_exec._eff(sub_exec.tensors[k]).pspec()
                             if k in sub_exec.tensors else P())
                         for k in state}

                def shard_body(s):
                    return lax.while_loop(sub.condition, body_fn, body_fn(s))

                fn = shard_map(shard_body, mesh=self.mesh,
                                   in_specs=(specs,), out_specs=specs,
                                   check_vma=False)
                return fn(state)
            return lax.while_loop(sub.condition, body_fn, body_fn(state))

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    # -- public execution -----------------------------------------------------
    @contextmanager
    def _layout_epoch(self):
        """Invariant bracket: incoming states are in the plan's initial
        layouts, and whatever happens inside (including an exception),
        the bookkeeping ends at initial again — any state the caller
        still holds outside a call is in the initial layouts."""
        self._state_layouts = dict(self.plan.initial)
        try:
            yield
        finally:
            self._state_layouts = dict(self.plan.initial)

    def __call__(self, state: dict) -> dict:
        with self._layout_epoch():
            state = self._call_segments(dict(state))
            return self._restore_initial_layouts(dict(state))

    def _call_segments(self, state: dict) -> dict:
        """One pass over all segments; relayouts are runtime-driven from
        the current physical layouts, so repeated passes (``run``'s
        fallback loop) only convert where consecutive iterations actually
        disagree instead of restoring after every pass."""
        for i, (kind, payload) in enumerate(self._segments):
            state = self._apply_segment_layouts(state, i)
            if kind == "device":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._device_fn(payload)
                state = fn(state)
            elif kind == "loop":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._loop_fn(payload, i)
                state = fn(state)
            elif kind == "host_loop":
                sub_exec = Executor(
                    payload, self.mesh, donate=False,
                    layout_overrides=self.plan.per_segment[i])
                state = sub_exec(state)
                while bool(jax.device_get(payload.condition(state))):
                    state = sub_exec(state)
            elif kind == "host":
                node: Node = payload
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                if node.fn is not None:
                    vals = self._resolve_args(node, state, sharded=False) \
                        if node.args else []
                    node.fn(*vals)
        return state

    def run(self, state: dict, steps: int) -> dict:
        """Execute the whole graph ``steps`` times (graphs are built once,
        executed many — paper §5.3).  Device-only graphs without a condition
        are compiled as one fori_loop."""
        if steps <= 0:
            return state
        if (self.graph.is_device_only() and self.graph.condition is None
                and all(k == "device" for k, _ in self._segments)):
            return self._run_fused(state, steps)
        with self._layout_epoch():
            for _ in range(steps):
                state = self._call_segments(dict(state))
            return self._restore_initial_layouts(dict(state))

    def _run_fused(self, state: dict, steps: int) -> dict:
        """Device-only fast path: all steps in one jitted fori_loop."""
        with self._layout_epoch():
            for i in range(len(self._segments)):
                state = self._apply_segment_layouts(dict(state), i)
            levels = [lv for _, seg in self._segments for lv in seg]
            sharded = self.mesh is not None and any(
                ax is not None for t in self.tensors.values()
                for ax in t.partition)

            def body(_, s):
                return self._lower_levels(levels, s, sharded)

            def call(s):
                if sharded:
                    specs = {k: (self._eff(self.tensors[k]).pspec()
                                 if k in self.tensors else P())
                             for k in s}
                    fn = shard_map(
                        lambda st: lax.fori_loop(0, steps, body, st),
                        mesh=self.mesh, in_specs=(specs,), out_specs=specs,
                        check_vma=False)
                    return fn(s)
                return lax.fori_loop(0, steps, body, s)

            out = jax.jit(call,
                          donate_argnums=0 if self.donate else ())(state)
            return self._restore_initial_layouts(dict(out))


def execute(graph: Graph, mesh: Optional[Mesh] = None, steps: int = 1,
            **state_overrides) -> dict:
    """One-shot convenience: init state, run, return final state."""
    ex = Executor(graph, mesh)
    state = ex.init_state(**state_overrides)
    return ex.run(state, steps) if steps != 1 else ex(state)
