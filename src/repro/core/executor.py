"""Graph executor (paper §6) — compiles a Ripple Graph to jitted SPMD code.

The paper schedules graph nodes dynamically with a heterogeneous
work-stealing pool.  Under SPMD/XLA that role collapses into *lowering
decisions* (DESIGN.md §2/§4), which this executor makes explicitly:

* graph nodes are scheduled from their real data dependencies
  (``core/schedule.py``): the dependency DAG's antichains of independent
  device nodes fuse into shared waves and consecutive waves into one jit
  *segment*, so XLA's latency-hiding scheduler can overlap independent
  nodes, their collectives, and compute across the paper's level
  boundaries (the paper's "compact GPU pipelines");
  ``Executor(schedule="sequential")`` is the legacy program-order
  lowering, and ``Executor.plan.describe_dag()`` renders the DAG, its
  segment/wave placement, and the transfers hoisted to segment entries;
* a segment with partitioned tensors is lowered through one ``shard_map``
  — the paper's one-node-per-partition becomes one program per shard;
* ``concurrent_padded_access`` + ``overlap=True`` splits the stencil into
  interior/boundary programs so the halo ppermutes fly during interior
  compute (paper Fig. 7) — for any number of mesh-partitioned halo axes
  and padded args: all edge strips are sent up front, corner blocks ride
  the two-phase extended-edge exchange, and one boundary program per
  (axis, side) consumes them (``core/halo.py``'s transfer schedule);
  ``Executor.plan.halo_transfers`` lists the scheduled blocks per segment
  and ``plan.overlap_fallbacks`` every declined overlap request (the
  genuinely-degraded ones also warn once);
* ``exclusive_padded_access`` captures the pre-update halo first and
  threads it as a data dependency (paper Fig. 9's extra edges);
* host (Cpu) nodes and ``sync()`` break segments — the host work runs
  between jit calls (heterogeneous execution);
* a graph with ``conditional`` becomes a ``lax.while_loop`` (device) or a
  host do/while (if it contains host nodes);
* state buffers are donated to each segment (the paper's allocator-reuse,
  C6): steps update state in place;
* a **layout solver** (paper §4.2's polymorphic layout made a compiler
  decision) assigns each record tensor a storage layout *per jit segment*:
  a user pin (``DistTensor.pin_layout``) is always honored, a node-level
  preference (``preferred_layout`` / ``layout=`` on graph methods) is
  honored next, padded (halo) access clamps AoSoA back to a per-axis
  layout, and otherwise the declared layout stands.  Where the producing
  and consuming segments disagree, the executor inserts an explicit
  relayout step at the segment boundary (``LayoutPlan.relayouts`` lists
  them for introspection).  Outside a call, every state dict is kept in
  the plan's *initial* layouts (the trailing conversions are undone on
  exit), so state dicts are interchangeable between calls and re-inits.
  Device-only graphs always collapse into a single jit segment, so the
  layout choice is naturally uniform there — layout changes never happen
  inside a jitted loop body.
"""

from __future__ import annotations

import math
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field as dfield
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map_compat
from . import halo as halo_lib
from . import schedule as schedule_lib
from .graph import AccessMode, Graph, Node, TensorArg
from .layout import Layout, RecordArray, relayout
from .schedule import ScheduleDag
from .tensor import DistTensor, ReductionResult

__all__ = ["Executor", "execute", "make_mesh", "LayoutPlan", "RelayoutStep",
           "HaloTransfer", "OverlapFallback", "solve_layouts"]

# version-guarded shard_map accepting the modern kwarg set — bound here so
# the executor does not depend on repro/__init__'s global jax monkeypatch
shard_map = shard_map_compat()


def make_mesh(shape, axis_names) -> Mesh:
    """make_mesh with Auto axis types, version-guarded: older JAX installs
    have neither ``jax.sharding.AxisType`` nor the ``axis_types`` kwarg
    (the single guard implementation lives in ``repro.compat``)."""
    from ..compat import make_mesh_auto

    return make_mesh_auto(shape, axis_names)


@dataclass
class _HaloEntry:
    dim: int
    storage_axis: int
    width: int
    mesh_axis: Optional[str]  # None -> boundary-pad only


def _halo_plan(t: DistTensor, mesh: Optional[Mesh]) -> list[_HaloEntry]:
    plan = []
    for d, w in enumerate(t.halo):
        if w == 0:
            continue
        ax = t.partition[d]
        if mesh is None or ax is None or mesh.shape[ax] == 1:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, None))
        else:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, ax))
    return plan


def _halo_axes(entries: list[_HaloEntry]) -> list[halo_lib.HaloAxis]:
    return [halo_lib.HaloAxis(e.storage_axis, e.width, e.mesh_axis)
            for e in entries]


def _apply_halo(data: jax.Array, t: DistTensor, mesh: Optional[Mesh]) -> jax.Array:
    """Extend a shard by all its halos through the transfer schedule: all
    axes' edge strips are sent up front, corner blocks ride the two-phase
    extended-edge exchange (value-equal to the old sequential per-axis
    exchange->concatenate chain, but nothing serializes on compute)."""
    entries = _halo_plan(t, mesh)
    if not entries:
        return data
    return halo_lib.exchange_multi(
        data, _halo_axes(entries),
        boundary=t.boundary, constant=t.boundary_constant)


def _slice(x, axis, start, size):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size)
    return x[tuple(idx)]


def _shard_storage_shape(t: DistTensor,
                         mesh: Optional[Mesh]) -> tuple[int, ...]:
    """Per-shard storage shape of ``t``'s state entry (for transfer-block
    byte accounting)."""
    space = t.space if mesh is None else t.shard_space(mesh)
    if not t.is_record:
        return space
    return RecordArray.storage_shape(t.spec, space, t.layout)


# -- layout solver (paper §4.2 as a per-segment compiler pass) -----------------

@dataclass(frozen=True)
class RelayoutStep:
    """An explicit layout conversion the executor inserts at a segment
    boundary: ``tensor`` is converted ``src -> dst`` before ``segment``."""

    segment: int
    tensor: str
    src: Layout
    dst: Layout


@dataclass(frozen=True)
class HaloTransfer:
    """One scheduled halo block of a segment's exchange (plan introspection).

    ``block`` names which sides of which space dims the block extends —
    ``((1, 'low'),)`` is an edge strip, ``((0, 'low'), (1, 'high'))`` a
    corner.  ``mesh_axis`` is the axis the block's final hop permutes over
    (``None`` — a local boundary fill, no transfer); ``phase`` is when the
    send is issued (1 = up-front edge strips, 2+ = extended-edge corner
    hops); ``overlapped`` marks blocks whose flight is hidden behind the
    node's interior program."""

    segment: int
    node: str
    tensor: str
    phase: int
    block: tuple[tuple[int, str], ...]   # ((space_dim, 'low'|'high'), ...)
    mesh_axis: Optional[str]
    width: int
    overlapped: bool
    nbytes: int = 0                      # per-shard block payload size

    def describe(self) -> str:
        where = "+".join(f"{'-' if s == 'low' else '+'}d{d}"
                         for d, s in self.block)
        via = f"ppermute[{self.mesh_axis}]" if self.mesh_axis else "fill"
        mode = "overlapped" if self.overlapped else "sync"
        return (f"seg{self.segment} {self.node}: {self.tensor} {where} "
                f"w={self.width} via {via} phase{self.phase} ({mode})")


@dataclass(frozen=True)
class OverlapFallback:
    """A node that asked for ``overlap=True`` but was lowered through the
    synchronous halo path, and why (no more silent drops)."""

    segment: int
    node: str
    reason: str


@dataclass
class LayoutPlan:
    """Solver output plus the executor's halo-transfer schedule.

    ``initial`` is what :meth:`Executor.init_state` materializes (the first
    consuming segment's choice, so the common case needs zero relayouts);
    ``relayouts`` are the boundary conversions of one sequential pass.
    ``halo_transfers`` lists every scheduled halo block per segment
    (:meth:`transfers_for_segment`), ``overlap_fallbacks`` every declined
    overlap request with its reason — both filled in by the Executor.
    ``dag`` is the graph's dependency DAG with its segment placement
    (``core/schedule.py``); :meth:`describe_dag` renders it together with
    the relayout steps and halo blocks hoisted to each segment entry."""

    per_segment: list[dict[str, Layout]] = dfield(default_factory=list)
    initial: dict[str, Layout] = dfield(default_factory=dict)
    relayouts: list[RelayoutStep] = dfield(default_factory=list)
    halo_transfers: list[HaloTransfer] = dfield(default_factory=list)
    overlap_fallbacks: list[OverlapFallback] = dfield(default_factory=list)
    dag: Optional[ScheduleDag] = None

    def transfers_for_segment(self, segment: int) -> list[HaloTransfer]:
        return [h for h in self.halo_transfers if h.segment == segment]

    def describe_dag(self) -> str:
        if self.dag is None:
            return "(no dependency DAG recorded)"
        return self.dag.describe(plan=self)

    def describe_transfers(self) -> str:
        if not self.halo_transfers:
            return "(no scheduled halo transfers)"
        lines = [h.describe() for h in self.halo_transfers]
        lines += [f"seg{f.segment} {f.node}: overlap fallback — {f.reason}"
                  for f in self.overlap_fallbacks]
        return "\n".join(lines)


def _segment_nodes(kind: str, payload):
    """All nodes a segment executes (loop bodies recursively)."""
    if kind == "device":
        for level in payload:
            yield from level
    elif kind in ("loop", "host_loop"):
        yield from _graph_nodes(payload)
    elif kind == "host":
        yield payload


def _graph_nodes(g: Graph):
    for node in g.nodes():
        if node.subgraph is not None:
            yield from _graph_nodes(node.subgraph)
        else:
            yield node


def _clamp_layout(t: DistTensor, lay: Layout) -> Layout:
    """AoSoA cannot carry halo/partition on the tiled (last) dim; fall back
    to SoA (the per-axis layout the halo machinery favors) when it would."""
    if lay is not Layout.AOSOA or not t.is_record:
        return lay
    nd = len(t.space)
    if t.halo[nd - 1] or t.partition[nd - 1] is not None:
        return Layout.SOA
    return lay


def solve_layouts(
    segments,
    tensors: dict[str, DistTensor],
    overrides: Optional[dict[str, Layout]] = None,
) -> LayoutPlan:
    """Choose a storage layout per record tensor per segment.

    Decision order per tensor (first match wins):

    1. ``overrides`` — a parent executor's already-made choice (loop
       sub-executors must agree with the enclosing plan);
    2. ``DistTensor.pin_layout`` — the user's pin;
    3. the first node-level preference (``TensorArg.layout``) in node
       order, clamped by halo/partition feasibility;
    4. the tensor's declared layout (clamped the same way).

    Segments are the executor's host-boundary segmentation, so a
    device-only graph is one segment and gets one uniform decision.
    """
    overrides = overrides or {}

    def choose(nodes) -> dict[str, Layout]:
        hints: dict[str, Layout] = {}
        seen: set[str] = set()
        no_aosoa: set[str] = set()
        for node in nodes:
            for a in node.args:
                if isinstance(a, TensorArg):
                    t, hint = a.tensor, a.layout
                elif isinstance(a, DistTensor):
                    t, hint = a, None
                else:
                    continue
                if not t.is_record:
                    continue
                seen.add(t.name)
                # feasibility is per ACCESS handle: halo widths are
                # access-level (storage_key excludes them), so any haloed
                # access vetoes AoSoA for the shared storage
                if _clamp_layout(t, Layout.AOSOA) is not Layout.AOSOA:
                    no_aosoa.add(t.name)
                if hint is not None and t.name not in hints:
                    hints[t.name] = hint
        out: dict[str, Layout] = {}
        for name in seen:
            t = tensors[name]
            if name in overrides:
                out[name] = overrides[name]
            elif t.pin_layout:
                # an infeasible pin is a user error, surfaced at
                # construction (mesh or not), never worked around
                if t.layout is Layout.AOSOA and (
                        name in no_aosoa
                        or _clamp_layout(t, Layout.AOSOA)
                        is not Layout.AOSOA):
                    raise ValueError(
                        f"{name}: pinned AOSOA layout is infeasible — the "
                        f"tensor carries a halo or partition on the tiled "
                        f"(last) space dim")
                out[name] = t.layout
            else:
                lay = _clamp_layout(t, hints.get(name, t.layout))
                if lay is Layout.AOSOA and name in no_aosoa:
                    lay = Layout.SOA
                out[name] = lay
        return out

    per_segment = [choose(list(_segment_nodes(k, p))) for k, p in segments]

    plan = LayoutPlan(per_segment=per_segment)
    current: dict[str, Layout] = {}
    for i, seg in enumerate(per_segment):
        for name, lay in seg.items():
            cur = current.get(name)
            if cur is None:
                plan.initial[name] = lay
            elif cur is not lay:
                plan.relayouts.append(RelayoutStep(i, name, cur, lay))
            current[name] = lay
    for name, t in tensors.items():
        if t.is_record and name not in plan.initial:
            plan.initial[name] = t.layout
    return plan


# -- overlap decision (paper Fig. 7 generalized) -------------------------------

# (node name, reason) pairs already warned about — "warn once" holds across
# the sub-executors a loop segment re-creates for the same node
_warned_overlap: set[tuple[str, str]] = set()


@dataclass(frozen=True)
class _OverlapDecision:
    """Whether an ``overlap=True`` split node gets the interior/boundary
    lowering: ``strips`` = ((space_dim, max halo width), ...) ascending,
    or None with a ``reason`` (``warn`` when real transfers get degraded
    to the synchronous path rather than there being nothing to hide)."""

    strips: Optional[tuple[tuple[int, int], ...]]
    reason: Optional[str] = None
    warn: bool = False


def _decide_overlap(node: Node, mesh: Optional[Mesh], eff) -> _OverlapDecision:
    if mesh is None:
        return _OverlapDecision(
            None, "graph has no mesh — nothing to overlap", False)
    padded = [eff(t) for _, t, mode in node.tensor_args() if mode.padded]
    if not padded:
        return _OverlapDecision(
            None, "no padded-access tensor arg to overlap", True)
    strips: dict[int, int] = {}
    for t in padded:
        for e in _halo_plan(t, mesh):
            if e.mesh_axis is not None:
                strips[e.dim] = max(strips.get(e.dim, 0), e.width)
    if not strips:
        return _OverlapDecision(
            None, "no mesh-partitioned halo axis (single shard along every "
            "haloed dim)", False)
    ref = padded[0]
    tensors = [eff(t) for _, t, _ in node.tensor_args()]
    for d in sorted(strips):
        w = strips[d]
        ax_name = ref.partition[d]
        for t in tensors:
            if len(t.space) <= d or t.space[d] != ref.space[d] \
                    or t.partition[d] != ax_name:
                return _OverlapDecision(
                    None, f"arg {t.name!r} does not align with "
                    f"partitioned halo dim {d} of {ref.name!r}", True)
            try:
                t.storage_axis(d)
            except ValueError as exc:
                return _OverlapDecision(None, str(exc), True)
        m = ref.space[d] // mesh.shape[ax_name]
        if m <= 2 * w:
            return _OverlapDecision(
                None, f"shard extent {m} along dim {d} leaves no interior "
                f"behind boundary strips of width {w}", True)
    return _OverlapDecision(tuple(sorted(strips.items())))


class Executor:
    """Compile + run a Graph against an optional mesh.

    ``schedule`` selects how graph nodes become jit segments:

    * ``"dag"`` (default) — dependency-DAG scheduling
      (``core/schedule.py``): antichains of independent device nodes fuse
      into shared waves/segments, and host / loop nodes break the chain
      only where a dependency path forces it;
    * ``"sequential"`` — the legacy program-order lowering (every level a
      barrier, every host node a break) — the escape hatch and the
      reference semantics the property tests compare against.

    Both schedules produce bitwise-identical state for any valid graph;
    the DAG schedule just gives XLA more to overlap per dispatch.
    """

    def __init__(self, graph: Graph, mesh: Optional[Mesh] = None,
                 donate: bool = True,
                 layout_overrides: Optional[dict[str, Layout]] = None,
                 schedule: str = "dag"):
        if schedule not in ("dag", "sequential"):
            raise ValueError(
                f"schedule must be 'dag' or 'sequential', got {schedule!r}")
        self.graph = graph
        self.mesh = mesh
        self.donate = donate
        self.schedule = schedule
        self.tensors = graph.all_tensors()
        self.results = graph.all_results()
        self.dag = schedule_lib.build_dag(graph)
        if schedule == "dag":
            self._segments = schedule_lib.dag_segments(self.dag)
        else:
            self._segments = schedule_lib.sequential_segments(graph)
            schedule_lib.place_units(self.dag, self._segments)
        self.plan = solve_layouts(self._segments, self.tensors,
                                  overrides=layout_overrides)
        self.plan.dag = self.dag
        # physical layout of each record tensor's state entry right now
        self._state_layouts: dict[str, Layout] = dict(self.plan.initial)
        if mesh is not None:
            for name, t in self.tensors.items():
                lays = {self.plan.initial.get(name, t.layout)}
                lays.update(seg[name] for seg in self.plan.per_segment
                            if name in seg)
                for lay in lays:
                    (t.with_(layout=lay) if t.is_record
                     else t).validate_mesh(mesh)
        self._overlap_decisions: dict[str, _OverlapDecision] = {}
        self._collect_halo_schedule()
        self._jitted: dict[int, Callable] = {}

    def _collect_halo_schedule(self) -> None:
        """Static pass: record every scheduled halo transfer per segment in
        ``plan.halo_transfers``, decide overlap per node, and surface every
        declined ``overlap=True`` in ``plan.overlap_fallbacks`` (warning
        once when the fallback actually degrades scheduling)."""
        mesh = self.mesh
        for si, (kind, payload) in enumerate(self._segments):
            seg_layouts = self.plan.per_segment[si]

            def eff(t, _lays=seg_layouts):
                if t.is_record:
                    lay = _lays.get(t.name, t.layout)
                    if lay is not t.layout:
                        return t.with_(layout=lay)
                return t

            for node in _segment_nodes(kind, payload):
                if node.kind not in ("split", "op"):
                    continue
                dec = None
                if node.kind == "split" and node.overlap:
                    dec = _decide_overlap(node, mesh, eff)
                    self._overlap_decisions[node.name] = dec
                    if dec.strips is None:
                        self.plan.overlap_fallbacks.append(
                            OverlapFallback(si, node.name, dec.reason))
                        key = (node.name, dec.reason)
                        if dec.warn and key not in _warned_overlap:
                            _warned_overlap.add(key)
                            warnings.warn(
                                f"node {node.name!r}: overlap=True falls "
                                f"back to synchronous halo exchange — "
                                f"{dec.reason}", RuntimeWarning,
                                stacklevel=3)
                overlapped = dec is not None and dec.strips is not None
                for _, t, mode in node.tensor_args():
                    if not mode.padded:
                        continue
                    eff_t = eff(t)
                    entries = _halo_plan(eff_t, mesh)
                    if not entries:
                        continue
                    axes = _halo_axes(entries)
                    shard = _shard_storage_shape(eff_t, mesh)
                    itemsize = np.dtype(eff_t.dtype).itemsize
                    for phase, bkey in halo_lib.iter_block_keys(axes):
                        last, _side = bkey[-1]
                        shape = halo_lib.block_shape(shard, axes, bkey)
                        self.plan.halo_transfers.append(HaloTransfer(
                            si, node.name, t.name, phase,
                            tuple((entries[j].dim, s) for j, s in bkey),
                            entries[last].mesh_axis, entries[last].width,
                            overlapped,
                            nbytes=math.prod(shape) * itemsize))

    # -- layout plumbing ---------------------------------------------------
    def _eff(self, t: DistTensor) -> DistTensor:
        """The tensor handle in its *current physical* layout."""
        if not t.is_record:
            return t
        lay = self._state_layouts.get(t.name, t.layout)
        return t if lay is t.layout else t.with_(layout=lay)

    def _apply_segment_layouts(self, state: dict, seg: int) -> dict:
        """Insert the solver's relayout steps before segment ``seg``:
        convert every tensor whose physical layout disagrees with the
        segment's chosen layout (paper: explicit layout-interop nodes)."""
        return self._convert_layouts(state, self.plan.per_segment[seg])

    def _restore_initial_layouts(self, state: dict) -> dict:
        """Undo trailing conversions so that outside a call every state
        dict is in the plan's initial layouts — state dicts stay
        interchangeable between calls, re-inits, and ``read``."""
        return self._convert_layouts(state, self.plan.initial)

    def _convert_layouts(self, state: dict,
                         targets: dict[str, Layout]) -> dict:
        for name, lay in targets.items():
            t = self.tensors[name]
            cur = self._state_layouts.get(name, t.layout)
            if cur is lay:
                continue
            arr = relayout(RecordArray(state[name], t.spec, cur), lay)
            data = arr.data
            self._state_layouts[name] = lay
            if self.mesh is not None:
                data = jax.device_put(data,
                                      self._eff(t).sharding(self.mesh))
            state[name] = data
        return state

    # -- state management ------------------------------------------------
    def init_state(self, **overrides) -> dict[str, Any]:
        """Allocate all tensors/results (zeros unless overridden).

        Record tensors are materialized directly in the layout the solver
        chose for their first consuming segment; a RecordArray override in
        another layout is relayouted on the way in."""
        self._state_layouts = dict(self.plan.initial)
        state: dict[str, Any] = {}
        for name, t in self.tensors.items():
            eff = self._eff(t)
            if name in overrides:
                v = overrides[name]
                if isinstance(v, RecordArray):
                    data = relayout(v, eff.layout).data
                elif t.is_record:
                    v = jnp.asarray(v)
                    src = self._infer_override_layout(t, v.shape)
                    data = relayout(RecordArray(v, t.spec, src),
                                    eff.layout).data
                else:
                    data = jnp.asarray(v)
                if self.mesh is not None:
                    data = jax.device_put(data, eff.sharding(self.mesh))
                state[name] = data
            else:
                v = eff.init(self.mesh)
                state[name] = v.data if isinstance(v, RecordArray) else v
        for name, r in self.results.items():
            state[name] = jnp.asarray(r.init, dtype=r.dtype)
        return state

    def _infer_override_layout(self, t: DistTensor, shape) -> Layout:
        """Which layout a raw (non-RecordArray) record override is stored
        in, by matching the storage shape against each layout's.  The two
        plausible sources are the solver's initial layout (an executor-
        produced state entry outside a call is always in it) and the
        declared layout (hand-built arrays).  When those differ and the
        shape matches both, guessing could silently scramble the data, so
        we refuse and ask for a RecordArray; otherwise the unique
        matching candidate wins."""
        def fits(lay):
            return tuple(shape) == RecordArray.storage_shape(
                t.spec, t.space, lay)

        preferred = list(dict.fromkeys(
            [self.plan.initial.get(t.name, t.layout), t.layout]))
        matches = [lay for lay in preferred if fits(lay)]
        if len(matches) == 1:
            return matches[0]
        if len(matches) > 1:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in matches]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        others = [lay for lay in Layout
                  if lay not in preferred and fits(lay)]
        if len(others) == 1:
            return others[0]
        if others:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in others]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        raise ValueError(
            f"{t.name}: override shape {tuple(shape)} matches no layout's "
            f"storage shape for space {t.space} "
            f"(pass a RecordArray to make the layout explicit)")

    def state_shardings(self, state: dict) -> dict:
        if self.mesh is None:
            return {k: None for k in state}
        out = {}
        for k in state:
            t = self.tensors.get(k)
            spec = self._eff(t).pspec() if t is not None else P()
            out[k] = NamedSharding(self.mesh, spec)
        return out

    def read(self, state: dict, t: DistTensor):
        """Wrap a state entry back into its RecordArray view (in the
        tensor's current physical layout; accessors hide the difference)."""
        return self._eff(t).wrap(state[t.name])

    # -- schedule introspection -------------------------------------------
    def describe_dag(self) -> str:
        """Render the dependency DAG, its segment/wave placement under the
        active schedule, and the relayouts / halo blocks hoisted to each
        segment entry (see ``core/schedule.py``)."""
        return self.plan.describe_dag()

    # -- node lowering (called inside shard_map / plain trace) ----------------
    def _resolve_args(self, node: Node, state: dict, sharded: bool):
        """Build the python args passed to a node fn; haloed where needed."""
        mesh = self.mesh if sharded else None
        vals = []
        for i, a in enumerate(node.args):
            if isinstance(a, ReductionResult):
                vals.append(state[a.name])
                continue
            t = None
            mode = AccessMode.DEFAULT
            from .graph import TensorArg
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t = a
            if t is None:
                vals.append(a)
                continue
            t = self._eff(t)
            data = state[t.name]
            if mode.padded:
                data = _apply_halo(data, t, mesh)
            vals.append(t.wrap(data) if t.is_record else data)
        return vals

    def _lower_split(self, node: Node, state: dict, sharded: bool) -> None:
        writes = node.default_writes()
        write_tensors = []
        for i in writes:
            a = node.args[i]
            from .graph import TensorArg
            write_tensors.append(a.tensor if isinstance(a, TensorArg) else a)

        dec = self._overlap_decisions.get(node.name)
        if node.overlap and sharded and dec is not None \
                and dec.strips is not None:
            self._lower_split_overlapped(node, state, write_tensors,
                                         dec.strips)
            return

        vals = self._resolve_args(node, state, sharded)
        out = node.fn(*vals)
        self._store_writes(node, state, write_tensors, out)

    def _store_writes(self, node, state, write_tensors, out) -> None:
        if not write_tensors:
            return
        if len(write_tensors) == 1:
            out = (out,)
        if len(out) != len(write_tensors):
            raise ValueError(
                f"{node.name}: fn returned {len(out)} values for "
                f"{len(write_tensors)} writes")
        for t, v in zip(write_tensors, out):
            data = v.data if isinstance(v, RecordArray) else jnp.asarray(v)
            state[t.name] = data

    def _lower_split_overlapped(self, node: Node, state: dict,
                                write_tensors,
                                strips: tuple[tuple[int, int], ...]) -> None:
        """Interior/boundary split over N partitioned halo axes: every
        halo block's ppermute is issued up front (phase 1 edge strips,
        phase 2+ corner hops), the interior program runs on the unextended
        shard while they fly, then one boundary-strip program per
        (axis, side) consumes the received blocks and the results are
        stitched (paper Fig. 7 generalized to the multi-dimensional
        transfer space of §5.4).

        ``strips`` is ((space_dim, W), ...) ascending; ``fn`` must be a
        shape-polymorphic stencil mapping (m + 2w) -> m cells along every
        haloed dim.  fn sees, per variant, exactly the sub-region of the
        extended array that its output cells read, so overlap output ==
        synchronous output value-for-value."""
        mesh = self.mesh
        strip_dims = [d for d, _ in strips]
        w_strip = dict(strips)

        # Resolve every arg once: all transfer-schedule sends are issued
        # here, before any variant program is traced.
        preps: list[tuple[str, Any]] = []
        for a in node.args:
            if isinstance(a, ReductionResult):
                preps.append(("raw", state[a.name]))
                continue
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t, mode = a, AccessMode.DEFAULT
            else:
                preps.append(("raw", a))
                continue
            t = self._eff(t)
            data = state[t.name]
            entries = ({e.dim: e for e in _halo_plan(t, mesh)}
                       if mode.padded else {})
            dims = sorted(set(entries) | set(strip_dims))
            axes = [halo_lib.HaloAxis(
                t.storage_axis(d),
                entries[d].width if d in entries else 0,
                entries[d].mesh_axis if d in entries else None)
                for d in dims]
            blocks = (halo_lib.exchange_blocks(
                data, axes, boundary=t.boundary,
                constant=t.boundary_constant)
                if any(ax.width for ax in axes) else {(): data})
            preps.append(("tensor", (t, dims, axes, blocks)))

        def ranges_for(variant, dims, axes, blocks):
            """Per-axis extended-coordinate input range for one variant.

            A variant's output domain is: the full boundary slab along its
            own dim, the interior along every earlier strip dim (those
            slabs were peeled off by earlier variants), the full extent
            elsewhere; the input range widens it by this arg's own halo."""
            vd = None if variant == "interior" else variant[0]
            out = []
            for d, ax in zip(dims, axes):
                m = blocks[()].shape[ax.axis]
                w, big_w = ax.width, w_strip.get(d, 0)
                if d == vd:
                    out.append((0, big_w + 2 * w) if variant[1] == "low"
                               else (m - big_w, m + 2 * w))
                elif big_w and (vd is None or d < vd):
                    out.append((big_w, m - big_w + 2 * w))
                else:
                    out.append((0, m + 2 * w))
            return out

        def run(variant):
            vals = []
            for kind, payload in preps:
                if kind == "raw":
                    vals.append(payload)
                    continue
                t, dims, axes, blocks = payload
                data = halo_lib.assemble_region(
                    blocks, axes, ranges_for(variant, dims, axes, blocks))
                vals.append(t.wrap(data) if t.is_record else data)
            out = node.fn(*vals)
            if len(write_tensors) == 1:
                out = (out,)
            if len(out) != len(write_tensors):
                raise ValueError(
                    f"{node.name}: fn returned {len(out)} values for "
                    f"{len(write_tensors)} writes")
            return [v.data if isinstance(v, RecordArray) else jnp.asarray(v)
                    for v in out]

        interior = run("interior")
        strip_outs = {
            (k, side): run((d, side))
            for k, (d, _) in enumerate(strips) for side in ("low", "high")}

        for wi, wt in enumerate(write_tensors):
            wt_eff = self._eff(wt)

            def stitch(k: int):
                if k == len(strips):
                    return interior[wi]
                d = strips[k][0]
                return jnp.concatenate(
                    [strip_outs[(k, "low")][wi], stitch(k + 1),
                     strip_outs[(k, "high")][wi]],
                    axis=wt_eff.storage_axis(d))

            state[wt.name] = stitch(0)

    def _lower_reduce(self, node: Node, state: dict, sharded: bool) -> None:
        t, field = node.args
        data = state[t.name]
        if t.is_record and field is not None:
            data = self._eff(t).wrap(data).field(field)
        local = node.reducer.local(data)
        if sharded:
            axes = tuple({ax for ax in t.partition if ax is not None
                          and self.mesh.shape[ax] > 1})
            if axes:
                op = {"add": lax.psum, "max": lax.pmax, "min": lax.pmin}[
                    node.reducer.combine]
                local = op(local, axes)
        state[node.result.name] = jnp.asarray(local, dtype=node.result.dtype)

    def _lower_levels(self, levels, state: dict, sharded: bool) -> dict:
        state = dict(state)
        for level in levels:
            # paper: nodes on a level are independent -> lower all against the
            # same input snapshot, then merge (XLA runs them in parallel).
            snapshot = dict(state)
            for node in level:
                if node.kind == "split":
                    tmp = dict(snapshot)
                    self._lower_split(node, tmp, sharded)
                    for k, v in tmp.items():
                        if k not in snapshot or v is not snapshot[k]:
                            state[k] = v
                elif node.kind == "reduce":
                    tmp = dict(snapshot)
                    self._lower_reduce(node, tmp, sharded)
                    state[node.result.name] = tmp[node.result.name]
                elif node.kind == "op":
                    tmp = dict(snapshot)
                    vals = self._resolve_args(node, tmp, sharded)
                    writes = node.default_writes()
                    wt = []
                    from .graph import TensorArg
                    for i in writes:
                        a = node.args[i]
                        wt.append(a.tensor if isinstance(a, TensorArg) else a)
                    out = node.fn(*vals) if node.fn is not None else None
                    if wt:
                        self._store_writes(node, tmp, wt, out)
                        for t in wt:
                            state[t.name] = tmp[t.name]
                else:
                    raise ValueError(f"unexpected node kind {node.kind}")
        return state

    # -- segment compilation -----------------------------------------------
    def _device_fn(self, levels) -> Callable:
        sharded = self.mesh is not None and any(
            ax is not None for t in self.tensors.values() for ax in t.partition)

        def body(state):
            return self._lower_levels(levels, state, sharded)

        if not sharded:
            return jax.jit(body, donate_argnums=0 if self.donate else ())

        in_specs = {}
        # specs must cover exactly the state dict; build lazily per call
        def call(state):
            specs = {k: (self._eff(self.tensors[k]).pspec()
                         if k in self.tensors else P())
                     for k in state}
            fn = shard_map(body, mesh=self.mesh, in_specs=(specs,),
                               out_specs=specs, check_vma=False)
            return fn(state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    def _loop_fn(self, sub: Graph, seg: int) -> Callable:
        # the sub-executor must agree with the enclosing plan: layouts are
        # loop-invariant inside one compiled while body
        sub_exec = Executor(sub, self.mesh, donate=False,
                            layout_overrides=self.plan.per_segment[seg],
                            schedule=self.schedule)
        sharded = self.mesh is not None and any(
            ax is not None for t in sub_exec.tensors.values()
            for ax in t.partition)

        def body_fn(state):
            s = state
            for kind, payload in sub_exec._segments:
                if kind != "device":
                    raise ValueError("device loop with host segment")
                s = sub_exec._lower_levels(payload, s, sharded)
            return s

        def call(state):
            if sharded:
                specs = {k: (sub_exec._eff(sub_exec.tensors[k]).pspec()
                             if k in sub_exec.tensors else P())
                         for k in state}

                def shard_body(s):
                    # while semantics: predicate gates the FIRST iteration
                    # too (an initially-false condition runs nothing)
                    return lax.while_loop(sub.condition, body_fn, s)

                fn = shard_map(shard_body, mesh=self.mesh,
                                   in_specs=(specs,), out_specs=specs,
                                   check_vma=False)
                return fn(state)
            return lax.while_loop(sub.condition, body_fn, state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    # -- public execution -----------------------------------------------------
    @contextmanager
    def _layout_epoch(self):
        """Invariant bracket: incoming states are in the plan's initial
        layouts, and whatever happens inside (including an exception),
        the bookkeeping ends at initial again — any state the caller
        still holds outside a call is in the initial layouts."""
        self._state_layouts = dict(self.plan.initial)
        try:
            yield
        finally:
            self._state_layouts = dict(self.plan.initial)

    def __call__(self, state: dict) -> dict:
        with self._layout_epoch():
            state = self._call_segments(dict(state))
            return self._restore_initial_layouts(dict(state))

    def _call_segments(self, state: dict) -> dict:
        """One pass over all segments; relayouts are runtime-driven from
        the current physical layouts, so repeated passes (``run``'s
        fallback loop) only convert where consecutive iterations actually
        disagree instead of restoring after every pass."""
        for i, (kind, payload) in enumerate(self._segments):
            state = self._apply_segment_layouts(state, i)
            if kind == "device":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._device_fn(payload)
                state = fn(state)
            elif kind == "loop":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._loop_fn(payload, i)
                state = fn(state)
            elif kind == "host_loop":
                sub_exec = Executor(
                    payload, self.mesh, donate=False,
                    layout_overrides=self.plan.per_segment[i],
                    schedule=self.schedule)
                # while semantics: check before the first iteration too
                while bool(jax.device_get(payload.condition(state))):
                    state = sub_exec(state)
            elif kind == "host":
                node: Node = payload
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                if node.fn is not None:
                    vals = self._resolve_args(node, state, sharded=False) \
                        if node.args else []
                    node.fn(*vals)
        return state

    def run(self, state: dict, steps: int) -> dict:
        """Execute the whole graph ``steps`` times (graphs are built once,
        executed many — paper §5.3).  Device-only graphs without a condition
        are compiled as one fori_loop."""
        if steps <= 0:
            return state
        # the scheduler owns the fusability decision: only a DAG with no
        # host / sync / loop vertex lowers every segment to device code,
        # whatever the schedule mode (a host node anywhere must run
        # between jit calls every step, so it breaks the fori fusion)
        if self.graph.condition is None and self.dag.device_only:
            return self._run_fused(state, steps)
        with self._layout_epoch():
            for _ in range(steps):
                state = self._call_segments(dict(state))
            return self._restore_initial_layouts(dict(state))

    def _run_fused(self, state: dict, steps: int) -> dict:
        """Device-only fast path: all steps in one jitted fori_loop."""
        with self._layout_epoch():
            for i in range(len(self._segments)):
                state = self._apply_segment_layouts(dict(state), i)
            levels = [lv for _, seg in self._segments for lv in seg]
            sharded = self.mesh is not None and any(
                ax is not None for t in self.tensors.values()
                for ax in t.partition)

            def body(_, s):
                return self._lower_levels(levels, s, sharded)

            def call(s):
                if sharded:
                    specs = {k: (self._eff(self.tensors[k]).pspec()
                                 if k in self.tensors else P())
                             for k in s}
                    fn = shard_map(
                        lambda st: lax.fori_loop(0, steps, body, st),
                        mesh=self.mesh, in_specs=(specs,), out_specs=specs,
                        check_vma=False)
                    return fn(s)
                return lax.fori_loop(0, steps, body, s)

            out = jax.jit(call,
                          donate_argnums=0 if self.donate else ())(state)
            return self._restore_initial_layouts(dict(out))


def execute(graph: Graph, mesh: Optional[Mesh] = None, steps: int = 1,
            **state_overrides) -> dict:
    """One-shot convenience: init state, run, return final state."""
    ex = Executor(graph, mesh)
    state = ex.init_state(**state_overrides)
    return ex.run(state, steps) if steps != 1 else ex(state)
