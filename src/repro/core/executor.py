"""Graph executor (paper §6) — compiles a Ripple Graph to jitted SPMD code.

The paper schedules graph nodes dynamically with a heterogeneous
work-stealing pool.  Under SPMD/XLA that role collapses into *lowering
decisions* (DESIGN.md §2/§4), which this executor makes explicitly:

* graph nodes are scheduled from their real data dependencies
  (``core/schedule.py``): the dependency DAG's antichains of independent
  device nodes fuse into shared waves and consecutive waves into one jit
  *segment*, so XLA's latency-hiding scheduler can overlap independent
  nodes, their collectives, and compute across the paper's level
  boundaries (the paper's "compact GPU pipelines");
  ``Executor(schedule="sequential")`` is the legacy program-order
  lowering, and ``Executor.plan.describe_dag()`` renders the DAG, its
  segment/wave placement, and the transfers hoisted to segment entries;
* a **region compiler** sits on top of the segment schedule (paper §5.3:
  graphs are built once, executed many): maximal runs of consecutive
  device / device-loop segments are grouped into *regions*
  (``core/schedule.py``'s ``group_regions``), each region lowers to ONE
  jitted program — the boundary relayout steps and halo assembly are
  traced inside it as pure functions (``core/layout.py``'s
  ``relayout_data``, ``core/halo.py``'s exchange/assembly) instead of
  being dispatched eagerly from Python between segment calls — and
  compiled regions live in a process-wide executable cache keyed by a
  structural *plan signature* (graph structure × shapes/dtypes × layouts
  × mesh × schedule mode × donation), so a re-instantiated ``Executor``
  over an identical graph (the serving pattern) reuses the compiled
  executables with zero new traces.  ``run(steps)`` is retrace-free: the
  fused fori fast path takes ``steps`` as a dynamic argument (distinct
  step counts share one trace) and the non-fused path loops over cached
  region executables with no eager relayout dispatch while consecutive
  iterations agree on layout.  ``Executor(regions=False)`` is the
  per-segment-dispatch escape hatch (and the baseline
  ``benchmarks/dispatch_overhead.py`` measures against);
* a segment with partitioned tensors is lowered through one ``shard_map``
  — the paper's one-node-per-partition becomes one program per shard;
* ``concurrent_padded_access`` + ``overlap=True`` splits the stencil into
  interior/boundary programs so the halo ppermutes fly during interior
  compute (paper Fig. 7) — for any number of mesh-partitioned halo axes
  and padded args: all edge strips are sent up front, corner blocks ride
  the two-phase extended-edge exchange, and one boundary program per
  (axis, side) consumes them (``core/halo.py``'s transfer schedule);
  ``Executor.plan.halo_transfers`` lists the scheduled blocks per segment
  and ``plan.overlap_fallbacks`` every declined overlap request (the
  genuinely-degraded ones also warn once);
* ``exclusive_padded_access`` captures the pre-update halo first and
  threads it as a data dependency (paper Fig. 9's extra edges);
* host (Cpu) nodes and ``sync()`` break segments — the host work runs
  between jit calls (heterogeneous execution).  By default the region
  loop is **event-driven** (``async_regions=True``): device regions are
  dispatched without blocking (JAX dispatch is already asynchronous),
  host callbacks run on a shared ``ThreadPoolExecutor`` as futures so
  only true data dependents wait on them, and when donation is on each
  callback reads a device-side snapshot of its arguments (double
  buffering: step N+1's relayouts/halo sends may overwrite the donated
  buffers while step N's callback still reads).  Barrier regions
  (``sync()``, opaque callbacks) and ``host_loop`` regions drain the
  in-flight callbacks first; ``run()``/``__call__`` drain before
  returning, re-raising the FIRST callback exception in program order
  and cancelling its successors.  ``Executor(async_regions=False)`` is
  the synchronous escape hatch (bitwise-identical results);
  ``core/schedule.py``'s ``region_dag``/``region_waves`` give regions —
  not just nodes — explicit dependencies, rendered by
  ``plan.describe()`` as ready waves;
* a graph with ``conditional`` becomes a ``lax.while_loop`` (device) or a
  host do/while (if it contains host nodes); device loops trace straight
  into their enclosing region, host loops run a cached sub-``Executor``;
* state buffers are donated to each region call (the paper's
  allocator-reuse, C6): steps update state in place — only buffers whose
  layout (hence shape) is stable across the region are donated, so XLA
  can actually alias them;
* a **layout solver** (paper §4.2's polymorphic layout made a compiler
  decision) assigns each record tensor a storage layout *per jit segment*:
  a user pin (``DistTensor.pin_layout``) is always honored, a node-level
  preference (``preferred_layout`` / ``layout=`` on graph methods) is
  honored next, padded (halo) access clamps AoSoA back to a per-axis
  layout, and otherwise the declared layout stands.  Where the producing
  and consuming segments disagree, the executor inserts an explicit
  relayout step at the segment boundary (``LayoutPlan.relayouts`` lists
  them for introspection).  Outside a call, every state dict is kept in
  the plan's *initial* layouts (the trailing conversions are undone on
  exit), so state dicts are interchangeable between calls and re-inits.
  Device-only graphs always collapse into a single jit segment, so the
  layout choice is naturally uniform there — layout changes never happen
  inside a jitted loop body.
"""

from __future__ import annotations

import enum as enum_lib
import functools
import hashlib
import math
import sys
import threading
import types
import warnings
from concurrent.futures import ThreadPoolExecutor, \
    TimeoutError as FuturesTimeout
from contextlib import contextmanager
from dataclasses import dataclass, field as dfield
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map_compat
from ..runtime.faults import (HostTimeoutError, TransientError,
                              trip as _fault_trip)
from ..tuning.tiles import tile_scope
from . import halo as halo_lib
from . import schedule as schedule_lib
from .graph import AccessMode, Graph, Node, TensorArg
from .layout import (Layout, RecordArray, relayout, relayout_data,
                     storage_candidates)
from .schedule import Region, ScheduleDag
from .tensor import DistTensor, ReductionResult

__all__ = ["Executor", "execute", "make_mesh", "LayoutPlan", "RelayoutStep",
           "HaloTransfer", "OverlapFallback", "DegradationEvent",
           "HostTimeoutError", "solve_layouts",
           "layout_candidates", "plan_signature", "ExecutableCacheEntry",
           "clear_executable_cache", "executable_cache_stats"]

# version-guarded shard_map accepting the modern kwarg set — bound here so
# the executor does not depend on repro/__init__'s global jax monkeypatch
shard_map = shard_map_compat()


def make_mesh(shape, axis_names) -> Mesh:
    """make_mesh with Auto axis types, version-guarded: older JAX installs
    have neither ``jax.sharding.AxisType`` nor the ``axis_types`` kwarg
    (the single guard implementation lives in ``repro.compat``)."""
    from ..compat import make_mesh_auto

    return make_mesh_auto(shape, axis_names)


@dataclass
class _HaloEntry:
    dim: int
    storage_axis: int
    width: int
    mesh_axis: Optional[str]  # None -> boundary-pad only


def _halo_plan(t: DistTensor, mesh: Optional[Mesh]) -> list[_HaloEntry]:
    plan = []
    for d, w in enumerate(t.halo):
        if w == 0:
            continue
        ax = t.partition[d]
        if mesh is None or ax is None or mesh.shape[ax] == 1:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, None))
        else:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, ax))
    return plan


def _halo_axes(entries: list[_HaloEntry]) -> list[halo_lib.HaloAxis]:
    return [halo_lib.HaloAxis(e.storage_axis, e.width, e.mesh_axis)
            for e in entries]


def _apply_halo(data: jax.Array, t: DistTensor, mesh: Optional[Mesh]) -> jax.Array:
    """Extend a shard by all its halos through the transfer schedule: all
    axes' edge strips are sent up front, corner blocks ride the two-phase
    extended-edge exchange (value-equal to the old sequential per-axis
    exchange->concatenate chain, but nothing serializes on compute)."""
    entries = _halo_plan(t, mesh)
    if not entries:
        return data
    return halo_lib.exchange_multi(
        data, _halo_axes(entries),
        boundary=t.boundary, constant=t.boundary_constant)


def _slice(x, axis, start, size):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size)
    return x[tuple(idx)]


def _shard_storage_shape(t: DistTensor,
                         mesh: Optional[Mesh]) -> tuple[int, ...]:
    """Per-shard storage shape of ``t``'s state entry (for transfer-block
    byte accounting)."""
    space = t.space if mesh is None else t.shard_space(mesh)
    if not t.is_record:
        return space
    return RecordArray.storage_shape(t.spec, space, t.layout)


# -- event-driven async region runtime ----------------------------------------

class _HostTaskCancelled(Exception):
    """Raised inside a pooled host task whose predecessor failed: the
    task's callback never runs (cancellation cascades down the
    host-order chain) and the drain skips it instead of reporting it."""


_HOST_POOL: Optional[ThreadPoolExecutor] = None
_HOST_POOL_LOCK = threading.Lock()


def _host_pool() -> ThreadPoolExecutor:
    """Process-wide pool for host-node callbacks (lazy singleton — one
    pool for every Executor, so constructing many executors never leaks
    threads).  Deadlock-free by construction: chained tasks only ever
    wait on earlier-submitted tasks, and the pool consumes its queue
    FIFO, so the earliest unfinished task always holds a worker."""
    global _HOST_POOL
    with _HOST_POOL_LOCK:
        if _HOST_POOL is None:
            _HOST_POOL = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="ripple-host")
        return _HOST_POOL


def _snapshot_for_host(v):
    """Device-side copy of one resolved host argument — the double
    buffer under donation: the callback reads the snapshot while the
    next region call donates (and XLA overwrites) the original buffer.
    The copy itself is async-dispatched, so it rides the device stream
    *before* the overwrite without blocking the dispatcher."""
    if isinstance(v, RecordArray):
        return RecordArray(jnp.copy(v.data), v.spec, v.layout)
    if isinstance(v, jax.Array):
        return jnp.copy(v)
    return v


def _host_arg_leaves(vals) -> list:
    """The device arrays among resolved host args (what the pooled task
    blocks on before invoking the callback)."""
    leaves = []
    for v in vals:
        if isinstance(v, RecordArray):
            leaves.append(v.data)
        elif isinstance(v, jax.Array):
            leaves.append(v)
    return leaves


class _AsyncRun:
    """The in-flight host-callback futures of ONE ``run()``/``__call__``
    epoch (the event-driven dispatcher's mutable state).

    Each non-barrier host region is submitted to the shared pool instead
    of blocking the dispatcher; the task first waits on the previous
    host task (program order for side effects — the host-order edges of
    the region DAG), then blocks on its own argument arrays (its only
    true data dependency), then runs the callback.  ``donate=True``
    snapshots the arguments at submit time so later donating region
    calls cannot delete the buffers out from under a still-running
    callback.  ``max_inflight`` bounds the pipeline depth.

    ``host_timeout`` (seconds, None = no watchdog) arms the hung-
    callback watchdog: any wait on an in-flight future — the inflight
    cap, a barrier/epoch drain, or a successor's host-order wait —
    gives up after that long, raises :class:`HostTimeoutError`
    (transient), sets the cancel event so every not-yet-started task
    exits immediately as cancelled, and leaves this context drained and
    reusable.  Python threads cannot be killed, so a truly hung
    callback keeps occupying one pool slot until it returns — but the
    dispatcher, the epoch, and the executor all stay live."""

    max_inflight = 32

    def __init__(self, donate: bool, host_timeout: Optional[float] = None):
        self.donate = donate
        self.host_timeout = host_timeout
        self.tasks: list = []    # (region_index, Future), dispatch order
        self._prev = None        # tail of the host-order chain
        self._cancelled = threading.Event()

    def submit(self, region_index: int, fn, vals) -> None:
        self.check()
        _fault_trip("executor.dispatch", detail=f"region{region_index}")
        if len(self.tasks) >= self.max_inflight:
            self._wait_oldest()
        if self.donate:
            vals = [_snapshot_for_host(v) for v in vals]
        leaves = _host_arg_leaves(vals)
        prev = self._prev
        timeout = self.host_timeout
        cancelled = self._cancelled

        def task():
            if cancelled.is_set():
                raise _HostTaskCancelled()
            # Future.exception() blocks until prev completes — this IS
            # the host-order chain; a failed predecessor cancels us.
            # Under the watchdog the wait is bounded: a predecessor
            # still running after host_timeout counts as failed.
            if prev is not None:
                try:
                    if prev.exception(timeout=timeout) is not None:
                        raise _HostTaskCancelled()
                except FuturesTimeout:
                    raise _HostTaskCancelled() from None
            if cancelled.is_set():
                raise _HostTaskCancelled()
            jax.block_until_ready(leaves)
            _fault_trip("executor.host", detail=f"region{region_index}")
            if fn is not None:
                fn(*vals)

        fut = _host_pool().submit(task)
        self._prev = fut
        self.tasks.append((region_index, fut))

    def _timed_result(self, region_index: int, fut):
        """``fut.result`` under the watchdog; a timeout cancels every
        not-yet-started task and raises :class:`HostTimeoutError`."""
        try:
            return fut.result(timeout=self.host_timeout)
        except FuturesTimeout:
            self._cancelled.set()
            err = HostTimeoutError(
                f"host callback of region {region_index} still running "
                f"after {self.host_timeout}s — cancelling successors")
            err.site = "executor.host"
            raise err from None

    def _wait_oldest(self) -> None:
        region_index, fut = self.tasks[0]
        try:
            self._timed_result(region_index, fut)
        except _HostTaskCancelled:
            pass
        self.tasks.pop(0)

    def check(self) -> None:
        """Surface an already-failed callback without waiting on the
        rest — the dispatcher calls this before issuing each region so a
        failure stops new work promptly."""
        for _, fut in self.tasks:
            if fut.done():
                exc = fut.exception()
                if exc is not None and \
                        not isinstance(exc, _HostTaskCancelled):
                    raise exc

    def drain(self) -> None:
        """Wait for every in-flight callback; re-raise the FIRST failure
        in dispatch order (cancelled successors are skipped) — the
        exception a synchronous run would have raised.  Under the
        watchdog each wait is bounded: the first timeout cancels all
        not-yet-started tasks (which then finish promptly as cancelled)
        and the drain reports :class:`HostTimeoutError`."""
        first = None
        for region_index, fut in self.tasks:
            try:
                self._timed_result(region_index, fut)
            except _HostTaskCancelled:
                pass
            except BaseException as exc:
                if first is None:
                    first = exc
        self.tasks.clear()
        self._prev = None
        if first is not None:
            raise first

    def abort(self) -> None:
        """Exception-path cleanup: wait out every in-flight callback
        swallowing their errors (another exception is already flying) —
        no orphaned tasks, no deadlock.  Bounded waits under the
        watchdog: a still-hung callback is abandoned to the pool (its
        successors are cancelled) rather than deadlocking the abort."""
        self._cancelled.set()
        for _, fut in self.tasks:
            try:
                fut.result(timeout=self.host_timeout)
            except BaseException:
                pass
        self.tasks.clear()
        self._prev = None


# -- layout solver (paper §4.2 as a per-segment compiler pass) -----------------

@dataclass(frozen=True)
class RelayoutStep:
    """An explicit layout conversion the executor inserts at a segment
    boundary: ``tensor`` is converted ``src -> dst`` before ``segment``."""

    segment: int
    tensor: str
    src: Layout
    dst: Layout


@dataclass(frozen=True)
class HaloTransfer:
    """One scheduled halo block of a segment's exchange (plan introspection).

    ``block`` names which sides of which space dims the block extends —
    ``((1, 'low'),)`` is an edge strip, ``((0, 'low'), (1, 'high'))`` a
    corner.  ``mesh_axis`` is the axis the block's final hop permutes over
    (``None`` — a local boundary fill, no transfer); ``phase`` is when the
    send is issued (1 = up-front edge strips, 2+ = extended-edge corner
    hops); ``overlapped`` marks blocks whose flight is hidden behind the
    node's interior program."""

    segment: int
    node: str
    tensor: str
    phase: int
    block: tuple[tuple[int, str], ...]   # ((space_dim, 'low'|'high'), ...)
    mesh_axis: Optional[str]
    width: int
    overlapped: bool
    nbytes: int = 0                      # per-shard block payload size

    def describe(self) -> str:
        where = "+".join(f"{'-' if s == 'low' else '+'}d{d}"
                         for d, s in self.block)
        via = f"ppermute[{self.mesh_axis}]" if self.mesh_axis else "fill"
        mode = "overlapped" if self.overlapped else "sync"
        return (f"seg{self.segment} {self.node}: {self.tensor} {where} "
                f"w={self.width} via {via} phase{self.phase} ({mode})")


@dataclass(frozen=True)
class OverlapFallback:
    """A node that asked for ``overlap=True`` but was lowered through the
    synchronous halo path, and why (no more silent drops)."""

    segment: int
    node: str
    reason: str


@dataclass(frozen=True)
class DegradationEvent:
    """One recorded transition of the Executor's graceful-degradation
    ladder (never silent — rendered by ``plan.describe()`` exactly like
    :class:`OverlapFallback`).  ``action`` is ``"demote"`` or
    ``"promote"``; ``frm``/``to`` are ladder level names
    (:data:`Executor.LADDER`); ``site`` names the failing injection/
    failure site that drove a demotion (``""`` for promotions);
    ``passes`` is the executor's lifetime pass counter at the
    transition."""

    passes: int
    action: str
    frm: str
    to: str
    site: str
    reason: str

    def describe(self) -> str:
        """One line: what moved, which way, and why."""
        return (f"pass {self.passes}: {self.action} {self.frm} -> "
                f"{self.to} — {self.reason}")


@dataclass
class LayoutPlan:
    """Solver output plus the executor's halo-transfer schedule.

    ``initial`` is what :meth:`Executor.init_state` materializes (the first
    consuming segment's choice, so the common case needs zero relayouts);
    ``relayouts`` are the boundary conversions of one sequential pass.
    ``halo_transfers`` lists every scheduled halo block per segment
    (:meth:`transfers_for_segment`), ``overlap_fallbacks`` every declined
    overlap request with its reason — both filled in by the Executor.
    ``dag`` is the graph's dependency DAG with its segment placement
    (``core/schedule.py``); :meth:`describe_dag` renders it together with
    the relayout steps and halo blocks hoisted to each segment entry.
    ``regions`` is the region compiler's grouping of segments into fused
    executables, ``region_edges`` the region-level dependency DAG the
    event-driven dispatcher honors (``core/schedule.py``'s
    :func:`~repro.core.schedule.region_dag`; :meth:`region_waves`
    layers it into ready waves), ``signature`` the plan-signature
    digest keying the process-wide executable cache, and ``cache`` the
    live cache entry (builds / reuse hits / trace events) — all
    rendered by :meth:`describe_dag`.  ``tuning`` is the measured autotuner's
    :class:`~repro.tuning.search.TuningDecision` when the Executor was
    constructed with ``tune="load"``/``"auto"`` (None when tuning is
    off); :meth:`describe_tuning` renders what was measured, what was
    chosen, and why, and :meth:`describe` renders the whole plan."""

    per_segment: list[dict[str, Layout]] = dfield(default_factory=list)
    initial: dict[str, Layout] = dfield(default_factory=dict)
    relayouts: list[RelayoutStep] = dfield(default_factory=list)
    halo_transfers: list[HaloTransfer] = dfield(default_factory=list)
    overlap_fallbacks: list[OverlapFallback] = dfield(default_factory=list)
    dag: Optional[ScheduleDag] = None
    regions: list[Region] = dfield(default_factory=list)
    region_edges: list["schedule_lib.RegionEdge"] = dfield(
        default_factory=list)
    signature: str = ""
    cache: Optional["ExecutableCacheEntry"] = None
    tuning: Optional[Any] = None
    degradations: list[DegradationEvent] = dfield(default_factory=list)

    def transfers_for_segment(self, segment: int) -> list[HaloTransfer]:
        """The scheduled halo blocks entering one segment (see
        :class:`HaloTransfer`)."""
        return [h for h in self.halo_transfers if h.segment == segment]

    def region_waves(self) -> list[list[int]]:
        """Ready waves of region indices under the region-level DAG —
        regions sharing a wave have no dependency path between them, so
        the event-driven runtime may overlap them (also rendered by
        :meth:`describe_dag` as the "region ready waves" block)."""
        return schedule_lib.region_waves(self.regions, self.region_edges)

    def describe_dag(self) -> str:
        """Render the dependency DAG with its segment/wave placement,
        relayout steps, hoisted halo blocks, region grouping, and
        executable-cache state (see ``core/schedule.py``)."""
        if self.dag is None:
            return "(no dependency DAG recorded)"
        return self.dag.describe(plan=self)

    def describe_transfers(self) -> str:
        """One line per scheduled halo block plus every declined overlap
        request with its reason."""
        if not self.halo_transfers:
            return "(no scheduled halo transfers)"
        lines = [h.describe() for h in self.halo_transfers]
        lines += [f"seg{f.segment} {f.node}: overlap fallback — {f.reason}"
                  for f in self.overlap_fallbacks]
        return "\n".join(lines)

    def describe_tuning(self) -> str:
        """Render the measured autotuner's decision for this plan: the
        baseline-vs-tuned steady-state times, every candidate measured
        (layout per state key, tile per kernel) and which won.  With
        tuning off, says so and how to turn it on."""
        if self.tuning is None:
            return ("(no measured tuning: heuristic layout solver and "
                    "default kernel tiles — construct the Executor with "
                    "tune=\"auto\" to measure)")
        return self.tuning.describe()

    def describe_degradations(self) -> str:
        """One line per recorded ladder transition (demotions with the
        failing site and reason, promotions after clean passes); says so
        when the run never degraded."""
        if not self.degradations:
            return "(no degradation-ladder transitions)"
        return "\n".join("ladder " + d.describe() for d in self.degradations)

    def describe(self) -> str:
        """The full plan, human-readable: schedule + transfers + regions
        + cache state (:meth:`describe_dag`), the degradation-ladder
        transitions (:meth:`describe_degradations`), then the tuning
        report (:meth:`describe_tuning`)."""
        return (f"{self.describe_dag()}\n{self.describe_degradations()}\n"
                f"{self.describe_tuning()}")


_NATIVE_COMBINE = {"add": lax.psum, "max": lax.pmax, "min": lax.pmin}

_FOLD_COMBINE = {
    "mul": jnp.multiply,
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
    "minimum": jnp.minimum,   # NaN-propagating elementwise per IEEE/jnp
    "maximum": jnp.maximum,
}


def _combine_over_axes(local, axes, combine: str):
    """Cross-shard combine for a reduction result.

    ``add``/``max``/``min`` ride the native psum/pmax/pmin collectives.
    The remaining Ripple combiners (mul, bitwise and/or/xor, NaN-propagating
    minimum/maximum) have no lax primitive, so the per-shard scalars are
    all-gathered (tiny: one scalar per mesh shard) and folded locally —
    every shard computes the identical fold, keeping the result replicated
    exactly like the psum path."""
    if combine in _NATIVE_COMBINE:
        return _NATIVE_COMBINE[combine](local, axes)
    op = _FOLD_COMBINE[combine]
    gathered = lax.all_gather(local, axes)  # (n_shards, *local.shape)
    return functools.reduce(op, [gathered[i]
                                 for i in range(gathered.shape[0])])


def _segment_nodes(kind: str, payload):
    """All nodes a segment executes (loop bodies recursively)."""
    if kind == "device":
        for level in payload:
            yield from level
    elif kind in ("loop", "host_loop"):
        yield from _graph_nodes(payload)
    elif kind == "host":
        yield payload


def _graph_nodes(g: Graph):
    for node in g.nodes():
        if node.subgraph is not None:
            yield from _graph_nodes(node.subgraph)
        else:
            yield node


def _clamp_layout(t: DistTensor, lay: Layout) -> Layout:
    """AoSoA cannot carry halo/partition on the tiled (last) dim; fall back
    to SoA (the per-axis layout the halo machinery favors) when it would
    (feasibility rule: ``core/layout.py``'s :func:`storage_candidates`)."""
    if lay is not Layout.AOSOA or not t.is_record:
        return lay
    if lay not in storage_candidates(t.space, t.halo, t.partition):
        return Layout.SOA
    return lay


def solve_layouts(
    segments,
    tensors: dict[str, DistTensor],
    overrides: Optional[dict[str, Layout]] = None,
    segment_overrides: Optional[dict[int, dict[str, Layout]]] = None,
) -> LayoutPlan:
    """Choose a storage layout per record tensor per segment.

    Decision order per tensor (first match wins):

    1. ``segment_overrides`` — the joint autotuner's PER-SEGMENT choice
       (segment index -> key -> layout): mixed-segment assignments are
       value-exact because ``_build_region_fn`` traces the boundary
       relayouts this plan records;
    2. ``overrides`` — a plan-uniform forced choice (a parent executor's
       decision for loop sub-executors, or the tuner's uniform axis);
    3. ``DistTensor.pin_layout`` — the user's pin;
    4. the first node-level preference (``TensorArg.layout``) in node
       order, clamped by halo/partition feasibility;
    5. the tensor's declared layout (clamped the same way).

    Segments are the executor's host-boundary segmentation, so a
    device-only graph is one segment and gets one uniform decision.
    """
    overrides = overrides or {}
    segment_overrides = segment_overrides or {}

    def choose(seg_idx, nodes) -> dict[str, Layout]:
        seg_over = segment_overrides.get(seg_idx, {})
        hints: dict[str, Layout] = {}
        seen: set[str] = set()
        no_aosoa: set[str] = set()
        for node in nodes:
            for a in node.args:
                if isinstance(a, TensorArg):
                    t, hint = a.tensor, a.layout
                elif isinstance(a, DistTensor):
                    t, hint = a, None
                else:
                    continue
                if not t.is_record:
                    continue
                seen.add(t.name)
                # feasibility is per ACCESS handle: halo widths are
                # access-level (storage_key excludes them), so any haloed
                # access vetoes AoSoA for the shared storage
                if _clamp_layout(t, Layout.AOSOA) is not Layout.AOSOA:
                    no_aosoa.add(t.name)
                if hint is not None and t.name not in hints:
                    hints[t.name] = hint
        out: dict[str, Layout] = {}
        for name in seen:
            t = tensors[name]
            if name in seg_over:
                out[name] = seg_over[name]
            elif name in overrides:
                out[name] = overrides[name]
            elif t.pin_layout:
                # an infeasible pin is a user error, surfaced at
                # construction (mesh or not), never worked around
                if t.layout is Layout.AOSOA and (
                        name in no_aosoa
                        or _clamp_layout(t, Layout.AOSOA)
                        is not Layout.AOSOA):
                    raise ValueError(
                        f"{name}: pinned AOSOA layout is infeasible — the "
                        f"tensor carries a halo or partition on the tiled "
                        f"(last) space dim")
                out[name] = t.layout
            else:
                lay = _clamp_layout(t, hints.get(name, t.layout))
                if lay is Layout.AOSOA and name in no_aosoa:
                    lay = Layout.SOA
                out[name] = lay
        return out

    per_segment = [choose(i, list(_segment_nodes(k, p)))
                   for i, (k, p) in enumerate(segments)]

    plan = LayoutPlan(per_segment=per_segment)
    current: dict[str, Layout] = {}
    for i, seg in enumerate(per_segment):
        for name, lay in seg.items():
            cur = current.get(name)
            if cur is None:
                plan.initial[name] = lay
            elif cur is not lay:
                plan.relayouts.append(RelayoutStep(i, name, cur, lay))
            current[name] = lay
    for name, t in tensors.items():
        if t.is_record and name not in plan.initial:
            plan.initial[name] = t.layout
    return plan


# -- plan signature (structural identity of a compiled plan) -------------------
#
# The process-wide executable cache must never alias two plans that could
# compute different values, and should alias plans from *re-instantiated*
# executors over an identical graph (the serving pattern: build the graph,
# build an Executor, serve; rebuild on the next request).  Node names are
# excluded (they come from a global counter and differ per build); node
# *functions* are keyed by module/qualname + code object + closure/default
# values, so a rebuilt graph using the same function definitions matches.
# Anything the signature cannot prove equal falls back to ``id(...)``:
# a conservative cache miss, never a wrong hit.

_SIG_DEPTH = 6


def _module_singleton(fn) -> bool:
    """True if ``fn`` IS the attribute its module/qualname names — a
    stable process-wide singleton (e.g. ``jnp.sum``)."""
    mod = sys.modules.get(getattr(fn, "__module__", None) or "")
    if mod is None:
        return False
    obj = mod
    try:
        for part in fn.__qualname__.split("."):
            obj = getattr(obj, part)
    except AttributeError:
        return False
    return obj is fn


def _code_sig(code: types.CodeType):
    consts = tuple(_code_sig(c) if isinstance(c, types.CodeType) else repr(c)
                   for c in code.co_consts)
    return (code.co_name, code.co_argcount, code.co_code, consts,
            code.co_names)


def _all_code_names(code: types.CodeType) -> set:
    """Every global name referenced by ``code`` or its nested code
    objects (inner lambdas/defs share the enclosing fn's globals)."""
    names = set(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names |= _all_code_names(c)
    return names


def _globals_sig(fn, code: types.CodeType, depth: int):
    """Key the VALUES of the module globals a function reads — a node fn
    like ``def f(x): return x * SCALE`` must miss the cache when SCALE
    changed between Executor builds (co_names alone keys the name, not
    the value).  Module-valued names are keyed by module name (cheap)."""
    g = getattr(fn, "__globals__", None)
    if g is None:
        return ()
    out = []
    for name in sorted(_all_code_names(code)):
        if name in g:
            v = g[name]
            if isinstance(v, types.ModuleType):
                out.append((name, ("module", v.__name__)))
            else:
                out.append((name, _sig_value(v, depth)))
    return tuple(out)


def _fn_sig(fn, depth: int = 0):
    if depth > _SIG_DEPTH:
        return ("deep-fn", id(fn))
    if isinstance(fn, functools.partial):
        return ("partial", _fn_sig(fn.func, depth + 1),
                _sig_value(fn.args, depth + 1),
                _sig_value(fn.keywords, depth + 1))
    # a bound method proxies __code__/__closure__ from the underlying
    # function — the receiver carries state, so it must be keyed too
    self_obj = getattr(fn, "__self__", None)
    if self_obj is not None:
        func = getattr(fn, "__func__", None)
        return ("bound", _sig_value(self_obj, depth + 1),
                _fn_sig(func, depth + 1) if func is not None else None)
    code = getattr(fn, "__code__", None)
    if code is None:
        mod = getattr(fn, "__module__", None)
        qn = getattr(fn, "__qualname__", None) or getattr(fn, "__name__", None)
        if qn is not None and _module_singleton(fn):
            return ("singleton", mod, qn)
        return ("callable", mod, qn, id(fn))
    cells = []
    for c in (fn.__closure__ or ()):
        try:
            cells.append(_sig_value(c.cell_contents, depth + 1))
        except ValueError:          # empty cell
            cells.append(("empty-cell",))
    # globals are keyed by VALUE one level deep (the node fn itself and
    # its closure-level callees); deeper library internals would explode
    # the walk and are keyed by code identity alone
    globs = _globals_sig(fn, code, depth + 1) if depth < 2 else ()
    return ("fn", fn.__module__, fn.__qualname__, _code_sig(code),
            tuple(cells), _sig_value(fn.__defaults__ or (), depth + 1),
            _sig_value(fn.__kwdefaults__ or {}, depth + 1), globs)


def _tensor_sig(t: DistTensor):
    spec = (None if t.spec is None
            else tuple((f.name, f.size) for f in t.spec.fields))
    return ("dt", t.name, t.space, str(jnp.dtype(t.dtype)), spec,
            t.layout.name, t.pin_layout, t.partition, t.halo,
            t.boundary.name, t.boundary_constant, t.subblocks)


def _sig_value(v, depth: int = 0):
    if depth > _SIG_DEPTH:
        return ("deep", id(v))
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return v
    if isinstance(v, enum_lib.Enum):
        return ("enum", type(v).__name__, v.name)
    if isinstance(v, (tuple, list)):
        return ("seq", tuple(_sig_value(x, depth + 1) for x in v))
    if isinstance(v, dict):
        return ("map", tuple(sorted(
            (str(k), _sig_value(x, depth + 1)) for k, x in v.items())))
    if isinstance(v, DistTensor):
        return _tensor_sig(v)
    if isinstance(v, ReductionResult):
        return ("res", v.name, str(jnp.dtype(v.dtype)), v.init)
    if isinstance(v, (np.ndarray, jax.Array)):
        # size/shape/dtype are metadata (no device transfer); only small
        # arrays are materialized for value-keying
        if v.size > 1024:
            return ("bigarr", tuple(v.shape), str(v.dtype), id(v))
        a = np.asarray(v)
        return ("arr", a.shape, str(a.dtype), a.tobytes())
    if callable(v):
        return _fn_sig(v, depth + 1)
    return ("obj", type(v).__module__, type(v).__qualname__, id(v))


def _node_sig(node: Node):
    args = []
    for a in node.args:
        if isinstance(a, TensorArg):
            args.append(("targ", _tensor_sig(a.tensor), a.mode.name,
                         None if a.layout is None else a.layout.name))
        elif isinstance(a, DistTensor):
            args.append(("t", _tensor_sig(a)))
        elif isinstance(a, ReductionResult):
            args.append(("r", a.name, str(jnp.dtype(a.dtype)), a.init))
        else:
            args.append(("v", _sig_value(a)))
    red = (None if node.reducer is None else
           (node.reducer.name, node.reducer.combine,
            _fn_sig(node.reducer.local)))
    res = (None if node.result is None else
           (node.result.name, str(jnp.dtype(node.result.dtype)),
            node.result.init))
    sub = None if node.subgraph is None else _graph_sig(node.subgraph)
    return (node.kind, node.exec_kind.name, node.overlap, node.writes,
            tuple(args), None if node.fn is None else _fn_sig(node.fn),
            red, res, sub)


def _graph_sig(g: Graph):
    levels = tuple(tuple(_node_sig(n) for n in level) for level in g.levels)
    cond = None if g.condition is None else _fn_sig(g.condition)
    return ("graph", levels, cond)


def _segments_sig(segments):
    out = []
    for kind, payload in segments:
        if kind == "device":
            out.append(("device", tuple(
                tuple(_node_sig(n) for n in wave) for wave in payload)))
        elif kind == "host":
            out.append(("host", _node_sig(payload)))
        else:  # loop / host_loop: payload is the subgraph
            out.append((kind, _graph_sig(payload)))
    return tuple(out)


def _mesh_sig(mesh: Optional[Mesh]):
    if mesh is None:
        return None
    devices = [d for d in mesh.devices.flat]
    return (tuple(mesh.shape.items()),
            tuple(int(d.id) for d in devices),
            devices[0].platform if devices else None)


def plan_signature(executor: "Executor") -> tuple:
    """Structural identity of a compiled plan: graph structure (node
    kinds, args, function code + closures — NOT auto-generated node
    names), tensor shapes/dtypes/layouts, mesh, schedule mode, per-
    segment layout decisions, kernel tile overrides, and donation.  Two
    executors with equal signatures compute identical values for
    identical inputs, so their compiled region executables are
    interchangeable.  Tile overrides are part of the key because they
    change the Pallas programs traced into a region executable (the
    autotuner relies on this: candidate configurations never alias).
    v3 additionally keys the joint autotuner's per-segment layout
    overrides explicitly — a per-segment tuned assignment and a
    plan-uniform one that happen to agree still key identically through
    the per-segment decision tuples, but a FORCED per-segment override
    never aliases an unforced plan."""
    plan = executor.plan
    return ("ripple-plan-v3", executor.schedule, executor.donate,
            _mesh_sig(executor.mesh), _segments_sig(executor._segments),
            tuple(tuple(sorted((n, l.name) for n, l in seg.items()))
                  for seg in plan.per_segment),
            tuple(sorted((n, l.name) for n, l in plan.initial.items())),
            tuple(sorted(
                (si, n, l.name)
                for si, d in executor._segment_overrides.items()
                for n, l in d.items())),
            tuple(sorted((str(k), _sig_value(v))
                         for k, v in executor._tile_config.items())))


def layout_candidates(executor: "Executor") -> dict[str, tuple[Layout, ...]]:
    """The measured autotuner's layout search space (``repro.tuning``).

    For every record state key that is neither user-pinned nor already
    forced by a layout override: the halo-feasible storage layouts
    (``core/layout.py``'s :func:`storage_candidates`, additionally
    clamped by every *access* of the key — any haloed access vetoes
    AoSoA for the shared storage, exactly the PR-1 solver's rule — and
    validated against the mesh).  Keys with a single feasible layout
    are omitted: there is nothing to search."""
    no_aosoa: set[str] = set()
    seen: set[str] = set()
    for kind, payload in executor._segments:
        for node in _segment_nodes(kind, payload):
            for a in node.args:
                t = a.tensor if isinstance(a, TensorArg) else a
                if not isinstance(t, DistTensor) or not t.is_record:
                    continue
                seen.add(t.name)
                if _clamp_layout(t, Layout.AOSOA) is not Layout.AOSOA:
                    no_aosoa.add(t.name)
    out: dict[str, tuple[Layout, ...]] = {}
    for name in sorted(seen):
        t = executor.tensors[name]
        if t.pin_layout or name in executor._layout_overrides:
            continue
        cands = []
        for lay in storage_candidates(t.space, t.halo, t.partition):
            if lay is Layout.AOSOA and name in no_aosoa:
                continue
            if executor.mesh is not None:
                try:
                    t.with_(layout=lay).validate_mesh(executor.mesh)
                except ValueError:
                    continue
            cands.append(lay)
        if len(cands) > 1:
            out[name] = tuple(cands)
    return out


# -- process-wide executable cache ---------------------------------------------

@dataclass
class ExecutableCacheEntry:
    """All compiled executables of one plan signature.

    ``executables`` maps ``('region', index, entry-layout-sig)`` /
    ``('fused', entry-layout-sig)`` keys to jitted callables.  ``builds``
    counts executables constructed, ``hits`` counts fetches that found an
    executable some *other* fetch already built (the re-instantiated-
    executor reuse path), and ``trace_events`` counts actual jit traces
    (the callables bump it from inside their Python bodies, which only
    run while tracing) — steady-state ``run()`` must not move it."""

    executables: dict[Any, Callable] = dfield(default_factory=dict)
    builds: int = 0
    hits: int = 0
    trace_events: int = 0


# Entries pin their builder Executor (the jitted callables close over it)
# for process lifetime — that retention IS the serving-pattern feature
# (compiled programs survive Executor re-instantiation), but a process
# cycling through many distinct plans should call clear_executable_cache()
# when a plan generation is retired.
_EXECUTABLE_CACHE: dict[tuple, ExecutableCacheEntry] = {}


def clear_executable_cache() -> None:
    """Drop every cached plan executable (tests / memory pressure /
    retiring a plan generation in a long-lived process)."""
    _EXECUTABLE_CACHE.clear()


def executable_cache_stats() -> dict:
    """Aggregate stats over the process-wide executable cache."""
    entries = list(_EXECUTABLE_CACHE.values())
    return {
        "plans": len(entries),
        "executables": sum(len(e.executables) for e in entries),
        "builds": sum(e.builds for e in entries),
        "hits": sum(e.hits for e in entries),
        "trace_events": sum(e.trace_events for e in entries),
    }


# -- overlap decision (paper Fig. 7 generalized) -------------------------------

# (node name, reason) pairs already warned about — "warn once" holds across
# the sub-executors a loop segment re-creates for the same node
_warned_overlap: set[tuple[str, str]] = set()


@dataclass(frozen=True)
class _OverlapDecision:
    """Whether an ``overlap=True`` split node gets the interior/boundary
    lowering: ``strips`` = ((space_dim, max halo width), ...) ascending,
    or None with a ``reason`` (``warn`` when real transfers get degraded
    to the synchronous path rather than there being nothing to hide)."""

    strips: Optional[tuple[tuple[int, int], ...]]
    reason: Optional[str] = None
    warn: bool = False


def _decide_overlap(node: Node, mesh: Optional[Mesh], eff) -> _OverlapDecision:
    if mesh is None:
        return _OverlapDecision(
            None, "graph has no mesh — nothing to overlap", False)
    padded = [eff(t) for _, t, mode in node.tensor_args() if mode.padded]
    if not padded:
        return _OverlapDecision(
            None, "no padded-access tensor arg to overlap", True)
    strips: dict[int, int] = {}
    for t in padded:
        for e in _halo_plan(t, mesh):
            if e.mesh_axis is not None:
                strips[e.dim] = max(strips.get(e.dim, 0), e.width)
    if not strips:
        return _OverlapDecision(
            None, "no mesh-partitioned halo axis (single shard along every "
            "haloed dim)", False)
    ref = padded[0]
    tensors = [eff(t) for _, t, _ in node.tensor_args()]
    for d in sorted(strips):
        w = strips[d]
        ax_name = ref.partition[d]
        for t in tensors:
            if len(t.space) <= d or t.space[d] != ref.space[d] \
                    or t.partition[d] != ax_name:
                return _OverlapDecision(
                    None, f"arg {t.name!r} does not align with "
                    f"partitioned halo dim {d} of {ref.name!r}", True)
            try:
                t.storage_axis(d)
            except ValueError as exc:
                return _OverlapDecision(None, str(exc), True)
        m = ref.space[d] // mesh.shape[ax_name]
        if m <= 2 * w:
            return _OverlapDecision(
                None, f"shard extent {m} along dim {d} leaves no interior "
                f"behind boundary strips of width {w}", True)
    return _OverlapDecision(tuple(sorted(strips.items())))


class Executor:
    """Compile + run a Graph against an optional mesh.

    ``schedule`` selects how graph nodes become jit segments:

    * ``"dag"`` (default) — dependency-DAG scheduling
      (``core/schedule.py``): antichains of independent device nodes fuse
      into shared waves/segments, and host / loop nodes break the chain
      only where a dependency path forces it;
    * ``"sequential"`` — the legacy program-order lowering (every level a
      barrier, every host node a break) — the escape hatch and the
      reference semantics the property tests compare against.

    ``regions`` (default True) enables the region compiler: maximal runs
    of device/loop segments become one jitted executable each, with the
    boundary relayouts traced inside, cached process-wide by plan
    signature.  ``regions=False`` falls back to per-segment dispatch with
    eager Python relayout glue (the pre-region behavior, and the baseline
    ``benchmarks/dispatch_overhead.py`` measures against).

    Both schedules (and both region modes) produce bitwise-identical
    state for any valid graph; the DAG schedule just gives XLA more to
    overlap per dispatch, and regions cut the per-step dispatch count.

    ``tune`` selects the measured autotuner (``repro.tuning``):

    * ``"off"`` (default) — heuristic layout solver, default kernel
      tiles (exactly the pre-tuner behavior);
    * ``"load"`` — apply a tuned configuration from the persistent
      cache when one exists for this plan signature × device × jax
      version; fall back to heuristics on a miss (never measures —
      safe for latency-sensitive construction paths);
    * ``"auto"`` — like ``"load"``, but on a cache miss run the JOINT
      search: propose the cross product of per-key halo-feasible
      layouts × per-kernel ``tile_candidates()`` (plus per-segment
      layout refinements), rank every proposal with the HLO cost model
      so only the cheapest fraction is ever measured, time the
      survivors with real executions of the region executables (each
      candidate's timing loop stops early once it is statistically
      dominated), commit the argmin into the plan, and persist it, so
      the *next* construction — this process or another — pays zero
      measurements.

    ``tune_budget`` bounds the ``"auto"`` search — a
    ``repro.tuning.TuneBudget`` (or a dict of its fields): the fraction
    of proposals measured, the early-stop domination factor, and how
    many consecutive non-improving candidates end the search.
    ``plan.describe_tuning()`` renders the decision, including the
    proposed / pruned / measured counts and any per-segment layout
    assignments; ``tile_overrides`` forces specific kernel tiles
    (kernel name -> tile config, what the tuner itself uses to stage
    candidates); ``segment_layout_overrides`` pins layouts for
    individual segments (segment index -> key -> layout, the tuner's
    per-segment decision axis); and ``tune_inputs`` optionally supplies
    ``init_state`` overrides for the tuner's timed executions so
    measurement runs on realistic data.

    Example::

        ex = Executor(graph, tune="auto")     # measures once, persists
        print(ex.plan.describe_tuning())      # what won, and why
        ex2 = Executor(graph, tune="auto")    # cache hit: 0 measurements
    """

    def __init__(self, graph: Graph, mesh: Optional[Mesh] = None,
                 donate: bool = True,
                 layout_overrides: Optional[dict[str, Layout]] = None,
                 schedule: str = "dag", regions: bool = True,
                 async_regions: bool = True,
                 tune: str = "off",
                 tune_budget: Optional[Any] = None,
                 tile_overrides: Optional[dict[str, Any]] = None,
                 tune_inputs: Optional[dict[str, Any]] = None,
                 segment_layout_overrides: Optional[
                     dict[int, dict[str, Layout]]] = None,
                 host_timeout: Optional[float] = None,
                 degrade: bool = True,
                 demote_after: int = 2, promote_after: int = 8):
        if schedule not in ("dag", "sequential"):
            raise ValueError(
                f"schedule must be 'dag' or 'sequential', got {schedule!r}")
        if tune not in ("off", "load", "auto"):
            raise ValueError(
                f"tune must be 'off', 'load' or 'auto', got {tune!r}")
        self.graph = graph
        self.mesh = mesh
        self.donate = donate
        self.schedule = schedule
        self.regions_enabled = bool(regions)
        # event-driven region dispatch (host callbacks on the pool, no
        # inter-region block_until_ready); False = synchronous escape
        # hatch with bitwise-identical results.  Not part of the plan
        # signature: both modes run the SAME cached executables.
        self.async_regions = bool(async_regions)
        self.tune = tune
        # hung-callback watchdog (seconds; None = wait forever): bounds
        # every wait on a pooled host callback — see _AsyncRun
        self.host_timeout = host_timeout
        # graceful-degradation ladder: repeated TRANSIENT failures at
        # one site demote the runtime one level at a time
        # (async_regions -> sync -> sequential schedule -> heuristic
        # layouts), and promote_after consecutive clean passes promote
        # back up; every transition lands in plan.degradations.
        self.degrade = bool(degrade)
        self.demote_after = int(demote_after)
        self.promote_after = int(promote_after)
        self.tensors = graph.all_tensors()
        self.results = graph.all_results()
        self.dag = schedule_lib.build_dag(graph)
        # the user's configured operating point — the top of the ladder
        # (level 0); _apply_ladder_level restores toward these
        self._cfg_schedule = schedule
        self._cfg_async = bool(async_regions)
        self._user_layout_overrides = dict(layout_overrides or {})
        self._user_segment_overrides = {
            int(i): dict(v)
            for i, v in (segment_layout_overrides or {}).items()}
        self._user_tile_config = dict(tile_overrides or {})
        self.ladder_level = 0
        self._site_failures: dict[str, int] = {}
        self._clean_passes = 0
        self._pass_counter = 0
        self._degradations: list[DegradationEvent] = []
        self._apply_schedule(schedule)
        self._sharded = mesh is not None and any(
            ax is not None for t in self.tensors.values()
            for ax in t.partition)
        self._layout_overrides = dict(layout_overrides or {})
        self._segment_overrides = {
            int(i): dict(v)
            for i, v in (segment_layout_overrides or {}).items()}
        self._tile_config = dict(tile_overrides or {})
        self._tune_inputs = dict(tune_inputs or {})
        self._tune_budget = tune_budget
        self._build_plan()
        if tune != "off":
            from ..tuning.search import resolve_tuning

            decision = resolve_tuning(self, tune, budget=tune_budget)
            if decision.applied:
                # rebuild the plan under the measured-best configuration
                # (relayout steps, halo schedule, signature and cache
                # entry all follow the tuned layouts/tiles — including
                # the per-segment assignments of the joint search)
                self._layout_overrides.update(decision.layouts)
                for si, d in decision.segment_layouts.items():
                    self._segment_overrides.setdefault(
                        int(si), {}).update(d)
                self._tile_config.update(decision.tiles)
                self._build_plan()
            self.plan.tuning = decision

    #: Ladder levels, fastest first: the configured operating point,
    #: then synchronous region dispatch, then the sequential reference
    #: schedule, then heuristic (un-tuned) layouts and tiles.  Demotion
    #: moves one level down after ``demote_after`` transient failures at
    #: one site; ``promote_after`` consecutive clean passes move one
    #: level back up.  Every transition is a DegradationEvent in
    #: ``plan.degradations``.
    LADDER = ("async_regions", "sync", "sequential", "heuristic")

    def _apply_schedule(self, schedule: str) -> None:
        """(Re)build the segment schedule — shared by __init__ and the
        ladder's "sequential" demotion/repromotion."""
        self.schedule = schedule
        if schedule == "dag":
            self._segments = schedule_lib.dag_segments(self.dag)
        else:
            self._segments = schedule_lib.sequential_segments(self.graph)
            schedule_lib.place_units(self.dag, self._segments)

    def _apply_ladder_level(self, level: int) -> None:
        """Reconfigure the runtime for one ladder level.  Level 0 is the
        user's configured operating point; deeper levels stack: 1 turns
        async region dispatch off, 2 additionally falls back to the
        sequential reference schedule, 3 additionally drops tuned
        layout/tile overrides back to the heuristics.  Plan rebuilds
        reuse the process-wide executable cache keyed by the resulting
        signature, so bouncing between levels retraces nothing after
        the first visit."""
        self.ladder_level = level
        self.async_regions = self._cfg_async and level < 1
        want_schedule = self._cfg_schedule if level < 2 else "sequential"
        want_overrides = dict(self._layout_overrides) if level < 3 \
            else dict(self._user_layout_overrides)
        want_tiles = dict(self._tile_config) if level < 3 \
            else dict(self._user_tile_config)
        want_seg = {i: dict(v) for i, v in (
            self._segment_overrides if level < 3
            else self._user_segment_overrides).items()}
        rebuild = (want_schedule != self.schedule
                   or want_overrides != self._layout_overrides
                   or want_tiles != self._tile_config
                   or want_seg != self._segment_overrides)
        if level >= 3:
            # drop the tuned configuration (keep it recoverable for
            # re-promotion in _tuned_layouts/_tuned_tiles)
            self._tuned_layouts = dict(self._layout_overrides)
            self._tuned_tiles = dict(self._tile_config)
            self._tuned_segment_overrides = {
                i: dict(v) for i, v in self._segment_overrides.items()}
        elif getattr(self, "_tuned_layouts", None) is not None:
            want_overrides = dict(self._tuned_layouts)
            want_tiles = dict(self._tuned_tiles)
            want_seg = {i: dict(v) for i, v in
                        self._tuned_segment_overrides.items()}
            rebuild = rebuild or want_overrides != self._layout_overrides \
                or want_seg != self._segment_overrides
            self._tuned_layouts = None
            self._tuned_tiles = None
            self._tuned_segment_overrides = None
        if rebuild:
            tuning = self.plan.tuning
            self._apply_schedule(want_schedule)
            self._layout_overrides = want_overrides
            self._tile_config = want_tiles
            self._segment_overrides = want_seg
            self._build_plan()
            self.plan.tuning = tuning

    def record_failure(self, exc: BaseException, site: str = "") -> bool:
        """Ladder bookkeeping for one failed pass: transient failures
        (``TransientError`` — injected chaos, host watchdog timeouts,
        preemptions) count per ``site``; ``demote_after`` of them at one
        site demote the executor one ladder level.  Deterministic
        errors never move the ladder.  Returns True when a demotion
        happened.  Called automatically by ``__call__``/``run``; public
        so external drivers (Batcher, Supervisor) can attribute
        failures they caught themselves."""
        if not self.degrade or not isinstance(exc, TransientError):
            return False
        site = site or getattr(exc, "site", "") or "executor"
        self._clean_passes = 0
        n = self._site_failures.get(site, 0) + 1
        self._site_failures[site] = n
        if n < self.demote_after \
                or self.ladder_level >= len(self.LADDER) - 1:
            return False
        frm = self.LADDER[self.ladder_level]
        self._apply_ladder_level(self.ladder_level + 1)
        self._site_failures[site] = 0
        self._degradations.append(DegradationEvent(
            self._pass_counter, "demote", frm,
            self.LADDER[self.ladder_level], site,
            f"{n} transient failures at {site} ({exc})"))
        self.plan.degradations = self._degradations
        return True

    def _note_clean_pass(self) -> None:
        """One successful top-level pass: after ``promote_after`` in a
        row at a degraded level, promote one level back up."""
        self._pass_counter += 1
        if self.ladder_level == 0:
            return
        self._clean_passes += 1
        if self._clean_passes < self.promote_after:
            return
        frm = self.LADDER[self.ladder_level]
        self._apply_ladder_level(self.ladder_level - 1)
        self._clean_passes = 0
        self._site_failures.clear()
        self._degradations.append(DegradationEvent(
            self._pass_counter, "promote", frm,
            self.LADDER[self.ladder_level], "",
            f"{self.promote_after} clean passes"))
        self.plan.degradations = self._degradations

    def _build_plan(self) -> None:
        """Solve layouts under the current overrides and derive everything
        that depends on them: halo/overlap schedule, region grouping,
        plan signature, executable-cache entry.  Run once at
        construction, and a second time when the autotuner commits a
        configuration that differs from the heuristics."""
        self.plan = solve_layouts(self._segments, self.tensors,
                                  overrides=self._layout_overrides,
                                  segment_overrides=self._segment_overrides)
        self.plan.dag = self.dag
        # physical layout of each record tensor's state entry right now
        self._state_layouts: dict[str, Layout] = dict(self.plan.initial)
        if self.mesh is not None:
            for name, t in self.tensors.items():
                lays = {self.plan.initial.get(name, t.layout)}
                lays.update(seg[name] for seg in self.plan.per_segment
                            if name in seg)
                for lay in lays:
                    (t.with_(layout=lay) if t.is_record
                     else t).validate_mesh(self.mesh)
        self._overlap_decisions: dict[str, _OverlapDecision] = {}
        self._collect_halo_schedule()
        # region compiler: segment runs -> fused executables, cached
        # process-wide by plan signature
        self._regions = schedule_lib.group_regions(
            [k for k, _ in self._segments])
        self.plan.regions = self._regions
        # region-level DAG: lifted from the unit edges so regions — not
        # just nodes — carry explicit dependencies; the async dispatcher
        # uses the per-region barrier bit, describe() the ready waves
        self.plan.region_edges = schedule_lib.region_dag(self.dag,
                                                         self._regions)
        self._region_access = schedule_lib.region_access(self.dag,
                                                         self._regions)
        self._plan_sig = plan_signature(self)
        self.plan.signature = hashlib.sha1(
            repr(self._plan_sig).encode()).hexdigest()[:12]
        self._cache = _EXECUTABLE_CACHE.setdefault(
            self._plan_sig, ExecutableCacheEntry())
        self.plan.cache = self._cache
        # the ladder's transition log survives plan rebuilds (a demotion
        # to "sequential"/"heuristic" re-solves the whole plan)
        self.plan.degradations = self._degradations
        self._fetched: set = set()        # executable keys this instance saw
        self._sub_execs: dict[int, "Executor"] = {}   # per loop segment
        self._jitted: dict[int, Callable] = {}        # regions=False path
        self.eager_relayouts = 0   # conversions dispatched outside a trace

    def _collect_halo_schedule(self) -> None:
        """Static pass: record every scheduled halo transfer per segment in
        ``plan.halo_transfers``, decide overlap per node, and surface every
        declined ``overlap=True`` in ``plan.overlap_fallbacks`` (warning
        once when the fallback actually degrades scheduling)."""
        mesh = self.mesh
        for si, (kind, payload) in enumerate(self._segments):
            seg_layouts = self.plan.per_segment[si]

            def eff(t, _lays=seg_layouts):
                if t.is_record:
                    lay = _lays.get(t.name, t.layout)
                    if lay is not t.layout:
                        return t.with_(layout=lay)
                return t

            for node in _segment_nodes(kind, payload):
                if node.kind not in ("split", "op"):
                    continue
                dec = None
                if node.kind == "split" and node.overlap:
                    dec = _decide_overlap(node, mesh, eff)
                    self._overlap_decisions[node.name] = dec
                    if dec.strips is None:
                        self.plan.overlap_fallbacks.append(
                            OverlapFallback(si, node.name, dec.reason))
                        key = (node.name, dec.reason)
                        if dec.warn and key not in _warned_overlap:
                            _warned_overlap.add(key)
                            warnings.warn(
                                f"node {node.name!r}: overlap=True falls "
                                f"back to synchronous halo exchange — "
                                f"{dec.reason}", RuntimeWarning,
                                stacklevel=3)
                overlapped = dec is not None and dec.strips is not None
                for _, t, mode in node.tensor_args():
                    if not mode.padded:
                        continue
                    eff_t = eff(t)
                    entries = _halo_plan(eff_t, mesh)
                    if not entries:
                        continue
                    axes = _halo_axes(entries)
                    shard = _shard_storage_shape(eff_t, mesh)
                    itemsize = np.dtype(eff_t.dtype).itemsize
                    for phase, bkey, shape in halo_lib.schedule_blocks(
                            shard, axes):
                        last, _side = bkey[-1]
                        self.plan.halo_transfers.append(HaloTransfer(
                            si, node.name, t.name, phase,
                            tuple((entries[j].dim, s) for j, s in bkey),
                            entries[last].mesh_axis, entries[last].width,
                            overlapped,
                            nbytes=math.prod(shape) * itemsize))

    # -- layout plumbing ---------------------------------------------------
    def _eff_in(self, t: DistTensor, layouts: dict[str, Layout]) -> DistTensor:
        """The tensor handle under an explicit layout assignment (region
        lowering threads the assignment; nothing reads mutable state)."""
        if not t.is_record:
            return t
        lay = layouts.get(t.name, t.layout)
        return t if lay is t.layout else t.with_(layout=lay)

    def _eff(self, t: DistTensor) -> DistTensor:
        """The tensor handle in its *current physical* layout."""
        return self._eff_in(t, self._state_layouts)

    def _layouts_for_segment(self, i: int) -> dict[str, Layout]:
        """The full layout assignment a segment's body is lowered under."""
        return {**self.plan.initial, **self.plan.per_segment[i]}

    def _apply_segment_layouts(self, state: dict, seg: int) -> dict:
        """Insert the solver's relayout steps before segment ``seg``:
        convert every tensor whose physical layout disagrees with the
        segment's chosen layout (paper: explicit layout-interop nodes)."""
        return self._convert_layouts(state, self.plan.per_segment[seg])

    def _restore_initial_layouts(self, state: dict) -> dict:
        """Undo trailing conversions so that outside a call every state
        dict is in the plan's initial layouts — state dicts stay
        interchangeable between calls, re-inits, and ``read``."""
        return self._convert_layouts(state, self.plan.initial)

    def _convert_layouts(self, state: dict,
                         targets: dict[str, Layout]) -> dict:
        for name, lay in targets.items():
            t = self.tensors[name]
            cur = self._state_layouts.get(name, t.layout)
            if cur is lay:
                continue
            arr = relayout(RecordArray(state[name], t.spec, cur), lay)
            data = arr.data
            self._state_layouts[name] = lay
            self.eager_relayouts += 1
            if self.mesh is not None:
                data = jax.device_put(data,
                                      self._eff(t).sharding(self.mesh))
            state[name] = data
        return state

    def _state_specs(self, state: dict, layouts: dict[str, Layout]) -> dict:
        """PartitionSpec per state entry under a layout assignment."""
        return {k: (self._eff_in(self.tensors[k], layouts).pspec()
                    if k in self.tensors else P())
                for k in state}

    # -- state management ------------------------------------------------
    def init_state(self, **overrides) -> dict[str, Any]:
        """Allocate all tensors/results (zeros unless overridden).

        Record tensors are materialized directly in the layout the solver
        chose for their first consuming segment; a RecordArray override in
        another layout is relayouted on the way in."""
        self._state_layouts = dict(self.plan.initial)
        state: dict[str, Any] = {}
        for name, t in self.tensors.items():
            eff = self._eff(t)
            if name in overrides:
                v = overrides[name]
                if isinstance(v, RecordArray):
                    data = relayout(v, eff.layout).data
                elif t.is_record:
                    v = jnp.asarray(v)
                    src = self._infer_override_layout(t, v.shape)
                    data = relayout(RecordArray(v, t.spec, src),
                                    eff.layout).data
                else:
                    data = jnp.asarray(v)
                if self.mesh is not None:
                    data = jax.device_put(data, eff.sharding(self.mesh))
                state[name] = data
            else:
                v = eff.init(self.mesh)
                state[name] = v.data if isinstance(v, RecordArray) else v
        for name, r in self.results.items():
            state[name] = jnp.asarray(r.init, dtype=r.dtype)
        return state

    def _infer_override_layout(self, t: DistTensor, shape) -> Layout:
        """Which layout a raw (non-RecordArray) record override is stored
        in, by matching the storage shape against each layout's.  The two
        plausible sources are the solver's initial layout (an executor-
        produced state entry outside a call is always in it) and the
        declared layout (hand-built arrays).  When those differ and the
        shape matches both, guessing could silently scramble the data, so
        we refuse and ask for a RecordArray; otherwise the unique
        matching candidate wins."""
        def fits(lay):
            return tuple(shape) == RecordArray.storage_shape(
                t.spec, t.space, lay)

        preferred = list(dict.fromkeys(
            [self.plan.initial.get(t.name, t.layout), t.layout]))
        matches = [lay for lay in preferred if fits(lay)]
        if len(matches) == 1:
            return matches[0]
        if len(matches) > 1:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in matches]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        others = [lay for lay in Layout
                  if lay not in preferred and fits(lay)]
        if len(others) == 1:
            return others[0]
        if others:
            raise ValueError(
                f"{t.name}: override shape {tuple(shape)} is ambiguous "
                f"between layouts {[m.name for m in others]} for space "
                f"{t.space} — pass a RecordArray to make it explicit")
        raise ValueError(
            f"{t.name}: override shape {tuple(shape)} matches no layout's "
            f"storage shape for space {t.space} "
            f"(pass a RecordArray to make the layout explicit)")

    def state_shardings(self, state: dict) -> dict:
        """NamedSharding per state entry (None entries without a mesh) —
        what ``jax.device_put`` placement of a checkpoint should use."""
        if self.mesh is None:
            return {k: None for k in state}
        out = {}
        for k in state:
            t = self.tensors.get(k)
            spec = self._eff(t).pspec() if t is not None else P()
            out[k] = NamedSharding(self.mesh, spec)
        return out

    def read(self, state: dict, t: DistTensor):
        """Wrap a state entry back into its RecordArray view (in the
        tensor's current physical layout; accessors hide the difference)."""
        return self._eff(t).wrap(state[t.name])

    # -- schedule introspection -------------------------------------------
    def describe_dag(self) -> str:
        """Render the dependency DAG, its segment/wave placement under the
        active schedule, the relayouts / halo blocks hoisted to each
        segment entry, the region grouping, and the executable-cache
        state (see ``core/schedule.py``)."""
        return self.plan.describe_dag()

    def describe_tuning(self) -> str:
        """Render the measured autotuner's decision for this plan
        (``plan.describe_tuning()``): baseline vs tuned steady-state
        times, every measured candidate, and what was committed."""
        return self.plan.describe_tuning()

    def cache_stats(self) -> dict:
        """Live executable-cache stats for this plan signature.

        ``trace_events`` counts actual jit traces of this plan's
        executables; a steady-state ``run()`` must leave it unchanged.
        ``hits`` counts executables this (or another) Executor fetched
        without building — the re-instantiated-executor reuse path."""
        c = self._cache
        return {"signature": self.plan.signature,
                "executables": len(c.executables), "builds": c.builds,
                "hits": c.hits, "trace_events": c.trace_events}

    # -- node lowering (called inside shard_map / plain trace) ----------------
    def _resolve_args(self, node: Node, state: dict, sharded: bool,
                      layouts: dict[str, Layout]):
        """Build the python args passed to a node fn; haloed where needed."""
        mesh = self.mesh if sharded else None
        vals = []
        for i, a in enumerate(node.args):
            if isinstance(a, ReductionResult):
                vals.append(state[a.name])
                continue
            t = None
            mode = AccessMode.DEFAULT
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t = a
            if t is None:
                vals.append(a)
                continue
            t = self._eff_in(t, layouts)
            data = state[t.name]
            if mode.padded:
                data = _apply_halo(data, t, mesh)
            vals.append(t.wrap(data) if t.is_record else data)
        return vals

    def _lower_split(self, node: Node, state: dict, sharded: bool,
                     layouts: dict[str, Layout]) -> None:
        writes = node.default_writes()
        write_tensors = []
        for i in writes:
            a = node.args[i]
            write_tensors.append(a.tensor if isinstance(a, TensorArg) else a)

        dec = self._overlap_decisions.get(node.name)
        if node.overlap and sharded and dec is not None \
                and dec.strips is not None:
            self._lower_split_overlapped(node, state, write_tensors,
                                         dec.strips, layouts)
            return

        vals = self._resolve_args(node, state, sharded, layouts)
        out = node.fn(*vals)
        self._store_writes(node, state, write_tensors, out, layouts)

    def _store_writes(self, node, state, write_tensors, out, layouts) -> None:
        if not write_tensors:
            return
        if len(write_tensors) == 1:
            out = (out,)
        if len(out) != len(write_tensors):
            raise ValueError(
                f"{node.name}: fn returned {len(out)} values for "
                f"{len(write_tensors)} writes")
        for t, v in zip(write_tensors, out):
            state[t.name] = self._coerce_write(t, v, layouts)

    def _coerce_write(self, t, v, layouts: dict[str, Layout]):
        """Raw storage for one written value.  A RecordArray output that
        disagrees with the segment's assigned layout for the write tensor
        is converted in-trace — a node fn returns records in whatever
        layout it computed them (usually its input's), and the plan's
        per-key layout choice (heuristic or tuned) must win."""
        if isinstance(v, RecordArray):
            if t.is_record:
                want = layouts.get(t.name, t.layout)
                if v.layout is not want:
                    v = relayout(v, want)
            return v.data
        return jnp.asarray(v)

    def _lower_split_overlapped(self, node: Node, state: dict,
                                write_tensors,
                                strips: tuple[tuple[int, int], ...],
                                layouts: dict[str, Layout]) -> None:
        """Interior/boundary split over N partitioned halo axes: every
        halo block's ppermute is issued up front (phase 1 edge strips,
        phase 2+ corner hops), the interior program runs on the unextended
        shard while they fly, then one boundary-strip program per
        (axis, side) consumes the received blocks and the results are
        stitched (paper Fig. 7 generalized to the multi-dimensional
        transfer space of §5.4).

        ``strips`` is ((space_dim, W), ...) ascending; ``fn`` must be a
        shape-polymorphic stencil mapping (m + 2w) -> m cells along every
        haloed dim.  fn sees, per variant, exactly the sub-region of the
        extended array that its output cells read, so overlap output ==
        synchronous output value-for-value."""
        mesh = self.mesh
        strip_dims = [d for d, _ in strips]
        w_strip = dict(strips)

        # Resolve every arg once: all transfer-schedule sends are issued
        # here, before any variant program is traced.
        preps: list[tuple[str, Any]] = []
        for a in node.args:
            if isinstance(a, ReductionResult):
                preps.append(("raw", state[a.name]))
                continue
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t, mode = a, AccessMode.DEFAULT
            else:
                preps.append(("raw", a))
                continue
            t = self._eff_in(t, layouts)
            data = state[t.name]
            entries = ({e.dim: e for e in _halo_plan(t, mesh)}
                       if mode.padded else {})
            dims = sorted(set(entries) | set(strip_dims))
            axes = [halo_lib.HaloAxis(
                t.storage_axis(d),
                entries[d].width if d in entries else 0,
                entries[d].mesh_axis if d in entries else None)
                for d in dims]
            blocks = (halo_lib.exchange_blocks(
                data, axes, boundary=t.boundary,
                constant=t.boundary_constant)
                if any(ax.width for ax in axes) else {(): data})
            preps.append(("tensor", (t, dims, axes, blocks)))

        def ranges_for(variant, dims, axes, blocks):
            """Per-axis extended-coordinate input range for one variant.

            A variant's output domain is: the full boundary slab along its
            own dim, the interior along every earlier strip dim (those
            slabs were peeled off by earlier variants), the full extent
            elsewhere; the input range widens it by this arg's own halo."""
            vd = None if variant == "interior" else variant[0]
            out = []
            for d, ax in zip(dims, axes):
                m = blocks[()].shape[ax.axis]
                w, big_w = ax.width, w_strip.get(d, 0)
                if d == vd:
                    out.append((0, big_w + 2 * w) if variant[1] == "low"
                               else (m - big_w, m + 2 * w))
                elif big_w and (vd is None or d < vd):
                    out.append((big_w, m - big_w + 2 * w))
                else:
                    out.append((0, m + 2 * w))
            return out

        def run(variant):
            vals = []
            for kind, payload in preps:
                if kind == "raw":
                    vals.append(payload)
                    continue
                t, dims, axes, blocks = payload
                data = halo_lib.assemble_region(
                    blocks, axes, ranges_for(variant, dims, axes, blocks))
                vals.append(t.wrap(data) if t.is_record else data)
            out = node.fn(*vals)
            if len(write_tensors) == 1:
                out = (out,)
            if len(out) != len(write_tensors):
                raise ValueError(
                    f"{node.name}: fn returned {len(out)} values for "
                    f"{len(write_tensors)} writes")
            return [self._coerce_write(wt, v, layouts)
                    for wt, v in zip(write_tensors, out)]

        interior = run("interior")
        strip_outs = {
            (k, side): run((d, side))
            for k, (d, _) in enumerate(strips) for side in ("low", "high")}

        for wi, wt in enumerate(write_tensors):
            wt_eff = self._eff_in(wt, layouts)

            def stitch(k: int):
                if k == len(strips):
                    return interior[wi]
                d = strips[k][0]
                return jnp.concatenate(
                    [strip_outs[(k, "low")][wi], stitch(k + 1),
                     strip_outs[(k, "high")][wi]],
                    axis=wt_eff.storage_axis(d))

            state[wt.name] = stitch(0)

    def _lower_reduce(self, node: Node, state: dict, sharded: bool,
                      layouts: dict[str, Layout]) -> None:
        t, field = node.args
        data = state[t.name]
        if t.is_record and field is not None:
            data = self._eff_in(t, layouts).wrap(data).field(field)
        local = node.reducer.local(data)
        if sharded:
            axes = tuple({ax for ax in t.partition if ax is not None
                          and self.mesh.shape[ax] > 1})
            if axes:
                local = _combine_over_axes(local, axes,
                                           node.reducer.combine)
        state[node.result.name] = jnp.asarray(local, dtype=node.result.dtype)

    def _lower_levels(self, levels, state: dict, sharded: bool,
                      layouts: dict[str, Layout]) -> dict:
        state = dict(state)
        for level in levels:
            # paper: nodes on a level are independent -> lower all against the
            # same input snapshot, then merge (XLA runs them in parallel).
            snapshot = dict(state)
            for node in level:
                if node.kind == "split":
                    tmp = dict(snapshot)
                    self._lower_split(node, tmp, sharded, layouts)
                    for k, v in tmp.items():
                        if k not in snapshot or v is not snapshot[k]:
                            state[k] = v
                elif node.kind == "reduce":
                    tmp = dict(snapshot)
                    self._lower_reduce(node, tmp, sharded, layouts)
                    state[node.result.name] = tmp[node.result.name]
                elif node.kind == "op":
                    tmp = dict(snapshot)
                    vals = self._resolve_args(node, tmp, sharded, layouts)
                    writes = node.default_writes()
                    wt = []
                    for i in writes:
                        a = node.args[i]
                        wt.append(a.tensor if isinstance(a, TensorArg) else a)
                    out = node.fn(*vals) if node.fn is not None else None
                    if wt:
                        self._store_writes(node, tmp, wt, out, layouts)
                        for t in wt:
                            state[t.name] = tmp[t.name]
                else:
                    raise ValueError(f"unexpected node kind {node.kind}")
        return state

    # -- loop (conditional subgraph) lowering --------------------------------
    def _sub_executor(self, i: int) -> "Executor":
        """The sub-Executor of loop segment ``i`` — built ONCE per segment
        and cached (it used to be re-constructed, and its segments
        re-jitted, on every host_loop pass)."""
        sub = self._sub_execs.get(i)
        if sub is None:
            _kind, payload = self._segments[i]
            sub = self._sub_execs[i] = Executor(
                payload, self.mesh, donate=False,
                layout_overrides=self.plan.per_segment[i],
                schedule=self.schedule, regions=self.regions_enabled,
                async_regions=self.async_regions,
                tile_overrides=self._tile_config)
        return sub

    def _lower_loop(self, sub_graph: Graph, seg: int, state: dict) -> dict:
        """Trace a device ``loop`` segment (a ``lax.while_loop`` over the
        sub-graph's segments) directly into the enclosing program — no
        extra jit wrapper, so a region containing loops is still one
        executable.  The sub-executor must agree with the enclosing plan:
        layouts are loop-invariant inside one compiled while body."""
        sub = self._sub_executor(seg)
        sharded = sub._sharded   # sub-specific: the loop body may be
        # unpartitioned even when the enclosing graph is sharded

        def body_fn(s):
            for k, (kind, payload) in enumerate(sub._segments):
                if kind != "device":
                    raise ValueError("device loop with host segment")
                s = sub._lower_levels(payload, s, sharded,
                                      sub._layouts_for_segment(k))
            return s

        if sharded:
            specs = sub._state_specs(state, sub.plan.initial)

            def shard_body(s):
                # while semantics: predicate gates the FIRST iteration
                # too (an initially-false condition runs nothing)
                return lax.while_loop(sub_graph.condition, body_fn, s)

            fn = shard_map(shard_body, mesh=self.mesh,
                           in_specs=(specs,), out_specs=specs,
                           check_vma=False)
            return fn(state)
        return lax.while_loop(sub_graph.condition, body_fn, state)

    # -- region compiler -----------------------------------------------------
    def _layout_sig(self, layouts: dict[str, Layout]) -> tuple:
        return tuple(sorted((n, lay.name) for n, lay in layouts.items()))

    def _segment_chain(self, seg_indices, entry_layouts: dict[str, Layout]):
        """Static layout evolution through a run of segments: per segment
        the boundary conversions to trace and the full layout assignment
        its body is lowered under; plus the exit layouts."""
        current = dict(entry_layouts)
        chain = []
        for si in seg_indices:
            targets = self.plan.per_segment[si]
            conv = [(n, current[n], lay)
                    for n, lay in sorted(targets.items())
                    if current[n] is not lay]
            current.update(targets)
            chain.append((si, conv, dict(current)))
        return chain, current

    def _traced_convert(self, state: dict, conv, layouts) -> dict:
        """Apply boundary relayouts INSIDE a trace (pure ops; the sharding
        constraint mirrors what the eager path's device_put enforced)."""
        for name, src, dst in conv:
            t = self.tensors[name]
            data = relayout_data(state[name], t.spec, src, dst)
            if self.mesh is not None:
                data = lax.with_sharding_constraint(
                    data, self._eff_in(t, layouts).sharding(self.mesh))
            state[name] = data
        return state

    def _donate_split(self, entry_layouts, exit_layouts):
        """State keys whose storage shape is stable across a region (same
        layout at entry and exit) — only those are donated, so XLA can
        actually alias them and jax never warns about unusable donations."""
        return frozenset(
            k for k in list(self.tensors) + list(self.results)
            if k not in entry_layouts
            or entry_layouts[k] is exit_layouts.get(k, entry_layouts[k]))

    def _fetch(self, key, build: Callable) -> Callable:
        """One executable from the plan-wide cache, building on miss.
        A fetch that finds an executable this instance never requested
        counts as a reuse hit (the re-instantiated-executor path)."""
        fn = self._cache.executables.get(key)
        if fn is None:
            fn = self._cache.executables[key] = build()
            self._cache.builds += 1
        elif key not in self._fetched:
            self._cache.hits += 1
        self._fetched.add(key)
        return fn

    def _build_region_fn(self, region: Region,
                         entry_layouts: dict[str, Layout]) -> Callable:
        """Lower one device region to a single jitted executable: for each
        segment in the run, the boundary relayouts (traced, not eagerly
        dispatched) then the segment body — device levels under one
        shard_map, loop segments as inlined while_loops."""
        chain, exit_layouts = self._segment_chain(region.segments,
                                                  entry_layouts)
        donate_keys = self._donate_split(entry_layouts, exit_layouts)
        cache_entry = self._cache
        sharded = self._sharded

        def region_call(donated, kept):
            cache_entry.trace_events += 1   # Python body runs per trace only
            state = {**donated, **kept}
            for si, conv, layouts in chain:
                state = self._traced_convert(dict(state), conv, layouts)
                kind, payload = self._segments[si]
                if kind == "device":
                    if sharded:
                        specs = self._state_specs(state, layouts)
                        fn = shard_map(
                            partial(self._lower_levels, payload,
                                    sharded=True, layouts=layouts),
                            mesh=self.mesh, in_specs=(specs,),
                            out_specs=specs, check_vma=False)
                        state = fn(state)
                    else:
                        state = self._lower_levels(payload, state, False,
                                                   layouts)
                else:  # 'loop'
                    state = self._lower_loop(payload, si, state)
            return state

        jfn = jax.jit(region_call,
                      donate_argnums=(0,) if self.donate else ())
        tile_config = self._tile_config

        def invoke(state):
            donated = {k: v for k, v in state.items() if k in donate_keys}
            kept = {k: v for k, v in state.items() if k not in donate_keys}
            # the (tuned) tile config only matters while the body traces;
            # steady-state calls hit the jit cache and never read it
            with tile_scope(tile_config):
                return jfn(donated, kept)

        invoke.jit_fn = jfn
        invoke.donate_keys = donate_keys
        invoke.exit_layouts = exit_layouts
        return invoke

    def _region_executable(self, region: Region):
        """The compiled executable for a region at the CURRENT entry
        layouts (cached process-wide), plus its exit layouts."""
        entry = {n: self._state_layouts[n] for n in self.plan.initial}
        key = ("region", region.index, self._layout_sig(entry))
        fn = self._fetch(key, lambda: self._build_region_fn(region, entry))
        return fn, fn.exit_layouts

    def region_hlo(self, state: dict, index: int = 0) -> str:
        """Compiled HLO text of a device region's executable for ``state``
        (benchmark/analysis introspection; reuses the jit cache)."""
        region = self._regions[index]
        if region.kind != "device":
            raise ValueError(f"region {index} is {region.kind!r}, "
                             f"not a device region")
        fn, _ = self._region_executable(region)
        donated = {k: v for k, v in state.items() if k in fn.donate_keys}
        kept = {k: v for k, v in state.items() if k not in fn.donate_keys}
        with tile_scope(self._tile_config):
            return fn.jit_fn.lower(donated, kept).compile().as_text()

    # -- segment compilation (regions=False per-segment dispatch) -----------
    def _device_fn(self, levels) -> Callable:
        sharded = self._sharded

        def body(state):
            return self._lower_levels(levels, state, sharded,
                                      dict(self._state_layouts))

        if not sharded:
            return jax.jit(body, donate_argnums=0 if self.donate else ())

        # specs must cover exactly the state dict; build lazily per call
        def call(state):
            specs = self._state_specs(state, self._state_layouts)
            fn = shard_map(body, mesh=self.mesh, in_specs=(specs,),
                           out_specs=specs, check_vma=False)
            return fn(state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    def _loop_fn(self, sub: Graph, seg: int) -> Callable:
        def call(state):
            return self._lower_loop(sub, seg, state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    # -- public execution -----------------------------------------------------
    @contextmanager
    def _layout_epoch(self):
        """Invariant bracket: incoming states are in the plan's initial
        layouts, and whatever happens inside (including an exception),
        the bookkeeping ends at initial again — any state the caller
        still holds outside a call is in the initial layouts."""
        self._state_layouts = dict(self.plan.initial)
        try:
            yield
        finally:
            self._state_layouts = dict(self.plan.initial)

    def _async_ctx(self) -> Optional[_AsyncRun]:
        """A fresh dispatcher context when the event-driven runtime is
        active (async on, region path, and a host region exists to
        overlap) — None means the pass runs exactly as before."""
        if not (self.async_regions and self.regions_enabled):
            return None
        if not any(r.kind == "host" for r in self._regions):
            return None
        return _AsyncRun(self.donate, self.host_timeout)

    def __call__(self, state: dict) -> dict:
        with self._layout_epoch():
            ctx = self._async_ctx()
            try:
                state = self._pass_once(dict(state), ctx)
                state = self._restore_initial_layouts(dict(state))
                if ctx is not None:
                    ctx.drain()
                self._note_clean_pass()
                return state
            except BaseException as exc:
                if ctx is not None:
                    ctx.abort()
                self.record_failure(exc)
                raise

    def _pass_once(self, state: dict,
                   ctx: Optional[_AsyncRun] = None) -> dict:
        if self.regions_enabled:
            return self._run_regions_once(state, ctx)
        return self._call_segments(state)

    def _run_regions_once(self, state: dict,
                          ctx: Optional[_AsyncRun] = None) -> dict:
        """One pass over the region schedule: each device region is ONE
        cached executable call (its relayouts and halo glue run inside
        the trace); host work runs eagerly between regions.  Layout
        bookkeeping is runtime-driven, so repeated passes re-dispatch
        nothing when consecutive iterations agree on layout.

        With a dispatcher context (``async_regions=True``) the pass is
        event-driven: device regions are issued without any
        ``block_until_ready`` (the device stream serializes them through
        their data dependencies), non-barrier host regions become pooled
        futures that block only on their OWN argument arrays, and only
        barrier/host_loop regions drain the in-flight callbacks.
        Device dispatch order is program order either way, so results
        are bitwise identical to the synchronous path."""
        for region in self._regions:
            if ctx is not None:
                ctx.check()
            if region.kind == "device":
                # trips BEFORE the executable call: the caller's state
                # dict is never half-donated, so a retry is safe
                _fault_trip("executor.region",
                            detail=f"region{region.index}")
                fn, exit_layouts = self._region_executable(region)
                state = fn(state)
                self._state_layouts.update(exit_layouts)
            elif region.kind == "host":
                si = region.start
                state = self._apply_segment_layouts(dict(state), si)
                node: Node = self._segments[si][1]
                barrier = self._region_access[region.index][2]
                if ctx is not None and not barrier:
                    vals = self._resolve_args(
                        node, state, False, self._state_layouts) \
                        if node.args else []
                    ctx.submit(region.index, node.fn, vals)
                    continue
                if ctx is not None:
                    ctx.drain()   # barrier: side-effect order vs pool
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                _fault_trip("executor.host",
                            detail=f"region{region.index}")
                if node.fn is not None:
                    vals = self._resolve_args(
                        node, state, False, self._state_layouts) \
                        if node.args else []
                    node.fn(*vals)
            else:  # host_loop
                si = region.start
                state = self._apply_segment_layouts(dict(state), si)
                if ctx is not None:
                    ctx.drain()   # the sub-executor writes state eagerly
                sub_graph: Graph = self._segments[si][1]
                sub = self._sub_executor(si)
                # while semantics: check before the first iteration too
                while bool(jax.device_get(sub_graph.condition(state))):
                    state = sub(state)
        return state

    def _call_segments(self, state: dict) -> dict:
        """Per-segment dispatch (``regions=False``): one jit call per
        segment with eager relayout glue between them; relayouts are
        runtime-driven from the current physical layouts, so repeated
        passes only convert where consecutive iterations disagree."""
        for i, (kind, payload) in enumerate(self._segments):
            state = self._apply_segment_layouts(state, i)
            if kind == "device":
                _fault_trip("executor.region", detail=f"segment{i}")
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._device_fn(payload)
                with tile_scope(self._tile_config):
                    state = fn(state)
            elif kind == "loop":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._loop_fn(payload, i)
                with tile_scope(self._tile_config):
                    state = fn(state)
            elif kind == "host_loop":
                sub_exec = self._sub_executor(i)
                # while semantics: check before the first iteration too
                while bool(jax.device_get(payload.condition(state))):
                    state = sub_exec(state)
            elif kind == "host":
                node: Node = payload
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                _fault_trip("executor.host", detail=f"segment{i}")
                if node.fn is not None:
                    vals = self._resolve_args(
                        node, state, False, self._state_layouts) \
                        if node.args else []
                    node.fn(*vals)
        return state

    def run(self, state: dict, steps: int) -> dict:
        """Execute the whole graph ``steps`` times (graphs are built once,
        executed many — paper §5.3).  Device-only graphs without a
        condition run as one fori_loop with ``steps`` a DYNAMIC argument
        (distinct step counts share a single trace); everything else
        loops over the cached region executables."""
        if steps <= 0:
            return state
        # the scheduler owns the fusability decision: only a DAG with no
        # host / sync / loop vertex lowers every segment to device code,
        # whatever the schedule mode (a host node anywhere must run
        # between jit calls every step, so it breaks the fori fusion).
        # regions=False escapes the fused/cached machinery entirely —
        # the escape hatch must not route through what it escapes.
        if self.regions_enabled and self.graph.condition is None \
                and self.dag.device_only:
            return self._run_fused(state, steps)
        with self._layout_epoch():
            ctx = self._async_ctx()
            state = dict(state)
            try:
                for _ in range(steps):
                    state = self._pass_once(dict(state), ctx)
                state = self._restore_initial_layouts(dict(state))
                if ctx is not None:
                    # completion point of the epoch: every pooled host
                    # callback has run (or its failure re-raises here)
                    ctx.drain()
                self._note_clean_pass()
                return state
            except BaseException as exc:
                if ctx is not None:
                    ctx.abort()
                self.record_failure(exc)
                raise

    def _build_fused_fn(self, entry_layouts: dict[str, Layout]) -> Callable:
        """Device-only fast path executable: entry relayouts traced up
        front, then all segments' levels inside one fori_loop whose trip
        count is a runtime argument — NOT closed over, so ``run(s, 3)``
        and ``run(s, 1000)`` share one trace.  (Device-only graphs have a
        single segment, so layouts are loop-invariant by construction.)"""
        current = dict(entry_layouts)
        convs = []
        for si in range(len(self._segments)):
            for n, lay in sorted(self.plan.per_segment[si].items()):
                if current[n] is not lay:
                    convs.append((n, current[n], lay))
                    current[n] = lay
        body_layouts = dict(current)
        levels = [lv for _, seg in self._segments for lv in seg]
        donate_keys = self._donate_split(entry_layouts, body_layouts)
        cache_entry = self._cache
        sharded = self._sharded

        def call(donated, kept, steps):
            cache_entry.trace_events += 1
            state = self._traced_convert({**donated, **kept}, convs,
                                         body_layouts)

            def body(_, s):
                return self._lower_levels(levels, s, sharded, body_layouts)

            if sharded:
                specs = self._state_specs(state, body_layouts)
                fn = shard_map(
                    lambda st, n: lax.fori_loop(0, n, body, st),
                    mesh=self.mesh, in_specs=(specs, P()),
                    out_specs=specs, check_vma=False)
                return fn(state, steps)
            return lax.fori_loop(0, steps, body, state)

        jfn = jax.jit(call, donate_argnums=(0,) if self.donate else ())
        tile_config = self._tile_config

        def invoke(state, steps):
            donated = {k: v for k, v in state.items() if k in donate_keys}
            kept = {k: v for k, v in state.items() if k not in donate_keys}
            with tile_scope(tile_config):
                return jfn(donated, kept, jnp.asarray(steps, jnp.int32))

        invoke.jit_fn = jfn
        invoke.donate_keys = donate_keys
        invoke.exit_layouts = body_layouts
        return invoke

    def _run_fused(self, state: dict, steps: int) -> dict:
        """Device-only fast path: all steps in one jitted fori_loop,
        cached by plan signature + entry layouts."""
        with self._layout_epoch():
            try:
                _fault_trip("executor.region", detail="fused")
                entry = dict(self._state_layouts)
                key = ("fused", self._layout_sig(entry))
                fn = self._fetch(key, lambda: self._build_fused_fn(entry))
                out = fn(dict(state), steps)
                self._state_layouts.update(fn.exit_layouts)
                out = self._restore_initial_layouts(dict(out))
            except BaseException as exc:
                self.record_failure(exc)
                raise
            self._note_clean_pass()
            return out


def execute(graph: Graph, mesh: Optional[Mesh] = None, steps: int = 1,
            **state_overrides) -> dict:
    """One-shot convenience: init state, run, return final state."""
    ex = Executor(graph, mesh)
    state = ex.init_state(**state_overrides)
    return ex.run(state, steps) if steps != 1 else ex(state)
