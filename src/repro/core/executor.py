"""Graph executor (paper §6) — compiles a Ripple Graph to jitted SPMD code.

The paper schedules graph nodes dynamically with a heterogeneous
work-stealing pool.  Under SPMD/XLA that role collapses into *lowering
decisions* (DESIGN.md §2/§4), which this executor makes explicitly:

* consecutive device levels are fused into one jit *segment* so XLA's
  latency-hiding scheduler can overlap collectives with compute across the
  paper's level boundaries (the paper's "compact GPU pipelines");
* a segment with partitioned tensors is lowered through one ``shard_map``
  — the paper's one-node-per-partition becomes one program per shard;
* ``concurrent_padded_access`` + ``overlap=True`` splits the stencil into
  interior/boundary programs so the halo ppermute flies during interior
  compute (paper Fig. 7);
* ``exclusive_padded_access`` captures the pre-update halo first and
  threads it as a data dependency (paper Fig. 9's extra edges);
* host (Cpu) nodes and ``sync()`` break segments — the host work runs
  between jit calls (heterogeneous execution);
* a graph with ``conditional`` becomes a ``lax.while_loop`` (device) or a
  host do/while (if it contains host nodes);
* state buffers are donated to each segment (the paper's allocator-reuse,
  C6): steps update state in place.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import halo as halo_lib
from .graph import AccessMode, ExecutionKind, Graph, Node
from .layout import RecordArray
from .tensor import DistTensor, ReductionResult

__all__ = ["Executor", "execute", "make_mesh"]


def make_mesh(shape, axis_names) -> Mesh:
    """make_mesh with JAX<->0.9 compatible Auto axis types."""
    return jax.make_mesh(
        tuple(shape),
        tuple(axis_names),
        axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
    )


@dataclass
class _HaloEntry:
    dim: int
    storage_axis: int
    width: int
    mesh_axis: Optional[str]  # None -> boundary-pad only


def _halo_plan(t: DistTensor, mesh: Optional[Mesh]) -> list[_HaloEntry]:
    plan = []
    for d, w in enumerate(t.halo):
        if w == 0:
            continue
        ax = t.partition[d]
        if mesh is None or ax is None or mesh.shape[ax] == 1:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, None))
        else:
            plan.append(_HaloEntry(d, t.storage_axis(d), w, ax))
    return plan


def _apply_halo(data: jax.Array, t: DistTensor, mesh: Optional[Mesh]) -> jax.Array:
    for e in _halo_plan(t, mesh):
        if e.mesh_axis is None:
            data = halo_lib.pad_boundary_only(
                data, axis=e.storage_axis, width=e.width,
                boundary=t.boundary, constant=t.boundary_constant)
        else:
            data = halo_lib.exchange(
                data, axis=e.storage_axis, width=e.width, axis_name=e.mesh_axis,
                boundary=t.boundary, constant=t.boundary_constant)
    return data


def _slice(x, axis, start, size):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + size)
    return x[tuple(idx)]


class Executor:
    """Compile + run a Graph against an optional mesh."""

    def __init__(self, graph: Graph, mesh: Optional[Mesh] = None,
                 donate: bool = True):
        self.graph = graph
        self.mesh = mesh
        self.donate = donate
        self.tensors = graph.all_tensors()
        self.results = graph.all_results()
        if mesh is not None:
            for t in self.tensors.values():
                t.validate_mesh(mesh)
        self._segments = self._build_segments(graph)
        self._jitted: dict[int, Callable] = {}

    # -- state management ------------------------------------------------
    def init_state(self, **overrides) -> dict[str, Any]:
        """Allocate all tensors/results (zeros unless overridden)."""
        state: dict[str, Any] = {}
        for name, t in self.tensors.items():
            if name in overrides:
                v = overrides[name]
                data = v.data if isinstance(v, RecordArray) else jnp.asarray(v)
                if self.mesh is not None:
                    data = jax.device_put(data, t.sharding(self.mesh))
                state[name] = data
            else:
                v = t.init(self.mesh)
                state[name] = v.data if isinstance(v, RecordArray) else v
        for name, r in self.results.items():
            state[name] = jnp.asarray(r.init, dtype=r.dtype)
        return state

    def state_shardings(self, state: dict) -> dict:
        if self.mesh is None:
            return {k: None for k in state}
        out = {}
        for k in state:
            t = self.tensors.get(k)
            spec = t.pspec() if t is not None else P()
            out[k] = NamedSharding(self.mesh, spec)
        return out

    def read(self, state: dict, t: DistTensor):
        """Wrap a state entry back into its RecordArray view."""
        return t.wrap(state[t.name])

    # -- segmentation ------------------------------------------------------
    def _build_segments(self, graph: Graph):
        """Split levels into host/device segments.

        Returns a list of ('device', [levels...]) / ('host', node) /
        ('loop', subgraph) entries.  Subgraphs without conditions are
        inlined into the level stream.
        """
        segments: list[tuple[str, Any]] = []
        device_levels: list[list[Node]] = []

        def flush():
            nonlocal device_levels
            if device_levels:
                segments.append(("device", device_levels))
                device_levels = []

        def walk(g: Graph):
            nonlocal device_levels
            for level in g.levels:
                dev_nodes: list[Node] = []
                for node in level:
                    if node.kind == "subgraph":
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        walk(node.subgraph)
                    elif node.kind == "loop":
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        if node.subgraph.is_device_only():
                            flush()
                            segments.append(("loop", node.subgraph))
                        else:
                            flush()
                            segments.append(("host_loop", node.subgraph))
                    elif node.kind == "sync" or node.exec_kind is ExecutionKind.Cpu:
                        if dev_nodes:
                            device_levels.append(dev_nodes)
                            dev_nodes = []
                        flush()
                        segments.append(("host", node))
                    else:
                        dev_nodes.append(node)
                if dev_nodes:
                    device_levels.append(dev_nodes)
            return

        walk(graph)
        flush()
        return segments

    # -- node lowering (called inside shard_map / plain trace) ----------------
    def _resolve_args(self, node: Node, state: dict, sharded: bool):
        """Build the python args passed to a node fn; haloed where needed."""
        mesh = self.mesh if sharded else None
        vals = []
        for i, a in enumerate(node.args):
            if isinstance(a, ReductionResult):
                vals.append(state[a.name])
                continue
            t = None
            mode = AccessMode.DEFAULT
            from .graph import TensorArg
            if isinstance(a, TensorArg):
                t, mode = a.tensor, a.mode
            elif isinstance(a, DistTensor):
                t = a
            if t is None:
                vals.append(a)
                continue
            data = state[t.name]
            if mode.padded:
                data = _apply_halo(data, t, mesh)
            vals.append(t.wrap(data) if t.is_record else data)
        return vals

    def _lower_split(self, node: Node, state: dict, sharded: bool) -> None:
        writes = node.default_writes()
        write_tensors = []
        for i in writes:
            a = node.args[i]
            from .graph import TensorArg
            write_tensors.append(a.tensor if isinstance(a, TensorArg) else a)

        if node.overlap and sharded and self._overlap_entry(node) is not None:
            self._lower_split_overlapped(node, state, write_tensors)
            return

        vals = self._resolve_args(node, state, sharded)
        out = node.fn(*vals)
        self._store_writes(node, state, write_tensors, out)

    def _store_writes(self, node, state, write_tensors, out) -> None:
        if not write_tensors:
            return
        if len(write_tensors) == 1:
            out = (out,)
        if len(out) != len(write_tensors):
            raise ValueError(
                f"{node.name}: fn returned {len(out)} values for "
                f"{len(write_tensors)} writes")
        for t, v in zip(write_tensors, out):
            data = v.data if isinstance(v, RecordArray) else jnp.asarray(v)
            state[t.name] = data

    def _overlap_entry(self, node: Node) -> Optional[tuple[DistTensor, _HaloEntry]]:
        """Overlap lowering applies when exactly one padded-access arg has
        exactly one mesh-partitioned halo dim."""
        cands = []
        for i, t, mode in node.tensor_args():
            if not mode.padded:
                continue
            entries = [e for e in _halo_plan(t, self.mesh) if e.mesh_axis]
            if len(entries) == 1:
                cands.append((t, entries[0]))
            elif entries:
                return None
        return cands[0] if len(cands) == 1 else None

    def _lower_split_overlapped(self, node: Node, state: dict,
                                write_tensors) -> None:
        """Interior/boundary split: ppermute of halos overlaps the interior
        stencil program (paper Fig. 7).  fn must be a stencil mapping
        (m + 2w) -> m cells along the partitioned dim."""
        t, entry = self._overlap_entry(node)
        ax, w = entry.storage_axis, entry.width
        from .graph import TensorArg

        def arg_variant(variant: str):
            """Resolve args with the padded arg replaced per variant."""
            vals = []
            for i, a in enumerate(node.args):
                if isinstance(a, ReductionResult):
                    vals.append(state[a.name])
                    continue
                at, mode = (a.tensor, a.mode) if isinstance(a, TensorArg) else (
                    (a, AccessMode.DEFAULT) if isinstance(a, DistTensor) else (None, None))
                if at is None:
                    vals.append(a)
                    continue
                data = state[at.name]
                if at.name == t.name and mode.padded:
                    # boundary-pad the non-partitioned haloed dims first
                    for e in _halo_plan(at, self.mesh):
                        if e.mesh_axis is None:
                            data = halo_lib.pad_boundary_only(
                                data, axis=e.storage_axis, width=e.width,
                                boundary=at.boundary,
                                constant=at.boundary_constant)
                    left, right = halo_lib.halo_blocks(
                        data, axis=ax, width=w, axis_name=entry.mesh_axis,
                        boundary=at.boundary, constant=at.boundary_constant)
                    n = data.shape[ax]
                    if variant == "interior":
                        data = data  # (n,) -> fn -> n - 2w interior cells
                    elif variant == "left":
                        data = jnp.concatenate(
                            [left, _slice(data, ax, 0, 2 * w)], axis=ax)
                    else:
                        data = jnp.concatenate(
                            [_slice(data, ax, n - 2 * w, 2 * w), right], axis=ax)
                elif mode.padded:
                    data = _apply_halo(data, at, self.mesh)
                else:
                    # non-padded args must be sliced to match output extent
                    if at.name != t.name and variant != "interior":
                        n_out = state[t.name].shape[ax]
                        s_ax = ax
                        if variant == "left":
                            data = _slice(data, s_ax, 0, w)
                        else:
                            data = _slice(data, s_ax, n_out - w, w)
                    elif variant == "interior" and at.name != t.name:
                        n_out = state[t.name].shape[ax]
                        data = _slice(data, ax, w, n_out - 2 * w)
                vals.append(at.wrap(data) if at.is_record else data)
            return vals

        def run(variant: str):
            out = node.fn(*arg_variant(variant))
            if len(write_tensors) == 1:
                out = (out,)
            return [v.data if isinstance(v, RecordArray) else jnp.asarray(v)
                    for v in out]

        interior = run("interior")
        left = run("left")
        right = run("right")
        for wt, li, ii, ri in zip(write_tensors, left, interior, right):
            state[wt.name] = jnp.concatenate([li, ii, ri],
                                             axis=wt.storage_axis(entry.dim))

    def _lower_reduce(self, node: Node, state: dict, sharded: bool) -> None:
        t, field = node.args
        data = state[t.name]
        if t.is_record and field is not None:
            data = t.wrap(data).field(field)
        local = node.reducer.local(data)
        if sharded:
            axes = tuple({ax for ax in t.partition if ax is not None
                          and self.mesh.shape[ax] > 1})
            if axes:
                op = {"add": lax.psum, "max": lax.pmax, "min": lax.pmin}[
                    node.reducer.combine]
                local = op(local, axes)
        state[node.result.name] = jnp.asarray(local, dtype=node.result.dtype)

    def _lower_levels(self, levels, state: dict, sharded: bool) -> dict:
        state = dict(state)
        for level in levels:
            # paper: nodes on a level are independent -> lower all against the
            # same input snapshot, then merge (XLA runs them in parallel).
            snapshot = dict(state)
            for node in level:
                if node.kind == "split":
                    tmp = dict(snapshot)
                    self._lower_split(node, tmp, sharded)
                    for k, v in tmp.items():
                        if k not in snapshot or v is not snapshot[k]:
                            state[k] = v
                elif node.kind == "reduce":
                    tmp = dict(snapshot)
                    self._lower_reduce(node, tmp, sharded)
                    state[node.result.name] = tmp[node.result.name]
                elif node.kind == "op":
                    tmp = dict(snapshot)
                    vals = self._resolve_args(node, tmp, sharded)
                    writes = node.default_writes()
                    wt = []
                    from .graph import TensorArg
                    for i in writes:
                        a = node.args[i]
                        wt.append(a.tensor if isinstance(a, TensorArg) else a)
                    out = node.fn(*vals) if node.fn is not None else None
                    if wt:
                        self._store_writes(node, tmp, wt, out)
                        for t in wt:
                            state[t.name] = tmp[t.name]
                else:
                    raise ValueError(f"unexpected node kind {node.kind}")
        return state

    # -- segment compilation -----------------------------------------------
    def _device_fn(self, levels) -> Callable:
        sharded = self.mesh is not None and any(
            ax is not None for t in self.tensors.values() for ax in t.partition)

        def body(state):
            return self._lower_levels(levels, state, sharded)

        if not sharded:
            return jax.jit(body, donate_argnums=0 if self.donate else ())

        in_specs = {}
        # specs must cover exactly the state dict; build lazily per call
        def call(state):
            specs = {k: (self.tensors[k].pspec() if k in self.tensors else P())
                     for k in state}
            fn = jax.shard_map(body, mesh=self.mesh, in_specs=(specs,),
                               out_specs=specs, check_vma=False)
            return fn(state)

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    def _loop_fn(self, sub: Graph) -> Callable:
        sub_exec = Executor(sub, self.mesh, donate=False)
        sharded = self.mesh is not None and any(
            ax is not None for t in sub_exec.tensors.values()
            for ax in t.partition)

        def body_fn(state):
            s = state
            for kind, payload in sub_exec._segments:
                if kind != "device":
                    raise ValueError("device loop with host segment")
                s = sub_exec._lower_levels(payload, s, sharded)
            return s

        def call(state):
            if sharded:
                specs = {k: (sub_exec.tensors[k].pspec()
                             if k in sub_exec.tensors else P())
                         for k in state}

                def shard_body(s):
                    return lax.while_loop(sub.condition, body_fn, body_fn(s))

                fn = jax.shard_map(shard_body, mesh=self.mesh,
                                   in_specs=(specs,), out_specs=specs,
                                   check_vma=False)
                return fn(state)
            return lax.while_loop(sub.condition, body_fn, body_fn(state))

        return jax.jit(call, donate_argnums=0 if self.donate else ())

    # -- public execution -----------------------------------------------------
    def __call__(self, state: dict) -> dict:
        for i, (kind, payload) in enumerate(self._segments):
            if kind == "device":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._device_fn(payload)
                state = fn(state)
            elif kind == "loop":
                fn = self._jitted.get(i)
                if fn is None:
                    fn = self._jitted[i] = self._loop_fn(payload)
                state = fn(state)
            elif kind == "host_loop":
                sub_exec = Executor(payload, self.mesh, donate=False)
                state = sub_exec(state)
                while bool(jax.device_get(payload.condition(state))):
                    state = sub_exec(state)
            elif kind == "host":
                node: Node = payload
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                if node.fn is not None:
                    vals = self._resolve_args(node, state, sharded=False) \
                        if node.args else []
                    node.fn(*vals)
        return state

    def run(self, state: dict, steps: int) -> dict:
        """Execute the whole graph ``steps`` times (graphs are built once,
        executed many — paper §5.3).  Device-only graphs without a condition
        are compiled as one fori_loop."""
        if steps <= 0:
            return state
        if (self.graph.is_device_only() and self.graph.condition is None
                and all(k == "device" for k, _ in self._segments)):
            levels = [lv for _, seg in self._segments for lv in seg]
            sharded = self.mesh is not None and any(
                ax is not None for t in self.tensors.values()
                for ax in t.partition)

            def body(_, s):
                return self._lower_levels(levels, s, sharded)

            def call(s):
                if sharded:
                    specs = {k: (self.tensors[k].pspec()
                                 if k in self.tensors else P())
                             for k in s}
                    fn = jax.shard_map(
                        lambda st: lax.fori_loop(0, steps, body, st),
                        mesh=self.mesh, in_specs=(specs,), out_specs=specs,
                        check_vma=False)
                    return fn(s)
                return lax.fori_loop(0, steps, body, s)

            return jax.jit(call, donate_argnums=0 if self.donate else ())(state)
        for _ in range(steps):
            state = self(state)
        return state


def execute(graph: Graph, mesh: Optional[Mesh] = None, steps: int = 1,
            **state_overrides) -> dict:
    """One-shot convenience: init state, run, return final state."""
    ex = Executor(graph, mesh)
    state = ex.init_state(**state_overrides)
    return ex.run(state, steps) if steps != 1 else ex(state)
