"""repro — Ripple (Clucas et al., 2021) reproduced as a multi-pod JAX
framework: polymorphic data layout, haloed distributed tensors, graph
scheduling, Pallas TPU kernels, and an LM train/serve stack on top."""

from . import compat as _compat

_compat.install()  # version-guarded jax shims (no-op on modern JAX)

__version__ = "0.1.0"
