"""2-D compressible Euler equations + FORCE flux (Toro) — paper §7.3/§8.

State is a 4-component record over the grid: conserved variables
``rho`` (density), ``E`` (total energy), ``mom`` (momentum vector, 2).
All functions operate on a *stacked* component-major array ``U`` of shape
``(4, *space)`` — which is exactly the SoA storage of the record, so the
SoA path is zero-copy while AoS pays a transpose (the paper's layout
effect, made structural).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layout import RecordArray, RecordSpec, Vector

GAMMA = 1.4

EULER_SPEC = RecordSpec.create("rho", "E", Vector("mom", 2))

RHO, EN, MX, MY = 0, 1, 2, 3


def stack_state(state: RecordArray) -> jax.Array:
    """(4, *space) component-major view of an Euler state record
    (layout-generic: AoS/SoA are views, AoSoA relayouts)."""
    from repro.core.layout import Layout, relayout

    if state.layout is Layout.SOA:
        return state.data  # already (4, *space)
    if state.layout is Layout.AOS:
        return jnp.moveaxis(state.data, -1, 0)
    return relayout(state, Layout.SOA).data


def unstack_state(U: jax.Array, like: RecordArray) -> RecordArray:
    from repro.core.layout import Layout, relayout

    if like.layout is Layout.SOA:
        return RecordArray(U, like.spec, Layout.SOA)
    if like.layout is Layout.AOS:
        return RecordArray(jnp.moveaxis(U, 0, -1), like.spec, Layout.AOS)
    return relayout(RecordArray(U, like.spec, Layout.SOA), like.layout)


def pressure(U: jax.Array) -> jax.Array:
    ke = 0.5 * (U[MX] ** 2 + U[MY] ** 2) / U[RHO]
    return (GAMMA - 1.0) * (U[EN] - ke)


def sound_speed(U: jax.Array) -> jax.Array:
    return jnp.sqrt(GAMMA * pressure(U) / U[RHO])


def max_wavespeed(U: jax.Array) -> jax.Array:
    """max(|u_d| + c) over the grid — sets the CFL time step."""
    c = sound_speed(U)
    sx = jnp.abs(U[MX] / U[RHO]) + c
    sy = jnp.abs(U[MY] / U[RHO]) + c
    return jnp.maximum(sx.max(), sy.max())


def flux(U: jax.Array, dim: int) -> jax.Array:
    """Physical flux along grid dim (0=x, 1=y) of the stacked state."""
    p = pressure(U)
    m_d = U[MX + dim]
    u_d = m_d / U[RHO]
    return jnp.stack(
        [
            m_d,
            (U[EN] + p) * u_d,
            U[MX] * u_d + (p if dim == 0 else 0.0),
            U[MY] * u_d + (p if dim == 1 else 0.0),
        ],
        axis=0,
    )


def force_flux(UL: jax.Array, UR: jax.Array, dim: int, lam) -> jax.Array:
    """FORCE flux (first-ORder CEntred, Toro): mean of Lax-Friedrichs and
    Richtmyer fluxes at the interface.  ``lam = dt / dx``."""
    FL, FR = flux(UL, dim), flux(UR, dim)
    f_lf = 0.5 * (FL + FR) - 0.5 / lam * (UR - UL)
    u_rm = 0.5 * (UL + UR) - 0.5 * lam * (FR - FL)
    return 0.5 * (f_lf + flux(u_rm, dim))


def _shift(U: jax.Array, dim: int, off: int, n: int) -> jax.Array:
    """Slice of length n starting at ``off`` along space dim (axis dim+1)."""
    idx = [slice(None)] * U.ndim
    idx[dim + 1] = slice(off, off + n)
    return U[tuple(idx)]


def flux_difference_dim(U_haloed: jax.Array, dim: int, lam) -> jax.Array:
    """lam * (F_{i+1/2} - F_{i-1/2}) along ``dim``; input haloed by 1 in
    ``dim`` only."""
    n = U_haloed.shape[dim + 1] - 2
    Um = _shift(U_haloed, dim, 0, n + 1)  # cells i-1 .. (for faces)
    Up = _shift(U_haloed, dim, 1, n + 1)  # cells i ..
    F = force_flux(Um, Up, dim, lam)      # faces i-1/2 .. i+n-1/2 (n+1 faces)
    return lam * (_shift(F, dim, 1, n) - _shift(F, dim, 0, n))


def flux_difference(U_haloed: jax.Array, lam_x, lam_y) -> jax.Array:
    """Sum of directional flux differences (paper Table 4 kernel).

    Input haloed by 1 in BOTH space dims: shape (4, nx+2, ny+2)."""
    nx = U_haloed.shape[1] - 2
    ny = U_haloed.shape[2] - 2
    dx = flux_difference_dim(U_haloed[:, :, 1:-1], 0, lam_x)  # (4, nx, ny)
    dy = flux_difference_dim(U_haloed[:, 1:-1, :], 1, lam_y)
    return dx + dy


def update_dim(U_haloed: jax.Array, dim: int, lam) -> jax.Array:
    """Dimension-split FORCE update (paper Listing 12: update_state_x/y):
    U' = U - lam (F_{+} - F_{-}).  Haloed by 1 in ``dim`` only."""
    n = U_haloed.shape[dim + 1] - 2
    return _shift(U_haloed, dim, 1, n) - flux_difference_dim(U_haloed, dim, lam)


def update_full(U_haloed: jax.Array, lam_x, lam_y) -> jax.Array:
    """Unsplit FORCE update: U' = U - lam_x dF_x - lam_y dF_y in one shot.

    Haloed by 1 in BOTH space dims — one node whose input spans the full
    2-D extended shard, so a 2-D-partitioned run exercises the whole
    multi-axis transfer schedule and the N-axis overlapped lowering.
    Shape-polymorphic: (4, m+2, n+2) -> (4, m, n).  Stability: use
    dt <= cfl / (s * (1/dx + 1/dy)) rather than the split scheme's CFL."""
    center = U_haloed[:, 1:-1, 1:-1]
    return center - flux_difference(U_haloed, lam_x, lam_y)


def shock_bubble_init(nx: int, ny: int, *, mach: float = 3.81) -> jax.Array:
    """Initial conditions: Mach-3.81 shock hitting a low-density bubble
    (paper Fig. 11), on [0,2]x[0,1]."""
    x = (jnp.arange(nx) + 0.5) * (2.0 / nx)
    y = (jnp.arange(ny) + 0.5) * (1.0 / ny)
    X, Y = jnp.meshgrid(x, y, indexing="ij")

    # ambient air
    rho = jnp.ones((nx, ny))
    p = jnp.ones((nx, ny))
    u = jnp.zeros((nx, ny))
    v = jnp.zeros((nx, ny))

    # low-density bubble at (0.8, 0.5), r = 0.2
    bubble = (X - 0.8) ** 2 + (Y - 0.5) ** 2 < 0.2**2
    rho = jnp.where(bubble, 0.1, rho)

    # post-shock state (left of x = 0.3), normal shock relations, Ms = mach
    ms = mach
    g = GAMMA
    rho_r, p_r = 1.0, 1.0
    p_l = p_r * (2 * g * ms**2 - (g - 1)) / (g + 1)
    rho_l = rho_r * ((g + 1) * ms**2) / ((g - 1) * ms**2 + 2)
    c_r = jnp.sqrt(g * p_r / rho_r)
    u_l = ms * c_r * (1 - rho_r / rho_l)
    shock = X < 0.3
    rho = jnp.where(shock, rho_l, rho)
    p = jnp.where(shock, p_l, p)
    u = jnp.where(shock, u_l, u)

    E = p / (GAMMA - 1.0) + 0.5 * rho * (u**2 + v**2)
    return jnp.stack([rho, E, rho * u, rho * v], axis=0)
