"""Deterministic shard-aware data pipeline.

Two sources:

* :class:`SyntheticLM` — a counter-hash token stream (splitmix64): batch i
  is a pure function of (seed, step, shard), so every data-parallel host
  regenerates exactly its shard with no coordination, and checkpoint/
  restart resumes mid-epoch by step counter alone.  This is the
  fault-tolerance-friendly design: data state is one integer.
* :class:`MemmapCorpus` — a binary token file (np.memmap) chunked into
  fixed-length windows, sharded round-robin by DP rank.

:class:`Prefetcher` double-buffers host->device transfer on a background
thread (the paper's C6 idea — never let the accelerator wait on
allocation/transfer — applied to input data).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


@dataclass
class SyntheticLM:
    """Deterministic synthetic LM batches: tokens[b, s] = h(seed, step,
    global_row, s) % vocab; labels = next token (teacher forcing)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0            # this host's DP shard index
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rows = (self.shard * self.local_batch
                + np.arange(self.local_batch, dtype=np.uint64))
        s = np.arange(self.seq_len + 1, dtype=np.uint64)
        base = (np.uint64(self.seed) * np.uint64(0x9E3779B1)
                + np.uint64(step) * np.uint64(0x85EBCA77))
        key = base + rows[:, None] * np.uint64(1 << 32) + s[None, :]
        toks = (_splitmix64(key) % np.uint64(self.vocab_size)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class MemmapCorpus:
    """Fixed-window LM batches from a flat binary token file."""

    path: str
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    shard: int = 0
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards
        self.tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.windows = (len(self.tokens) - 1) // self.seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        # round-robin windows across (step, shard, row): deterministic,
        # disjoint across shards
        row0 = (step * self.global_batch
                + self.shard * self.local_batch)
        idx = (row0 + np.arange(self.local_batch)) % self.windows
        starts = idx * self.seq_len
        toks = np.stack([self.tokens[s : s + self.seq_len + 1]
                         for s in starts]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N pipeline) over a batch source.

    Robustness contract: batches are never dropped (the producer blocks
    — with a stop-aware timeout — until the consumer frees a slot, and
    a sentinel is only enqueued after the batch it replaces), producer
    exceptions do not vanish (they re-raise in the consumer from
    :meth:`next`, wrapped with the failing step), and :meth:`close`
    leaves no runnable thread behind: the producer checks the stop
    event between batches AND while blocked on a full queue, so the
    final ``join`` always completes without relying on daemon teardown.
    """

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 transform=None):
        self.source = source
        self.depth = depth
        self.transform = transform or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._error_step: Optional[int] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that still honors close(); True if enqueued."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.transform(self.source.batch_at(step))
            except BaseException as e:
                self._error_step = step
                self._error = e
                self._put((step, e))  # wake the consumer; next() raises
                return
            if not self._put((step, batch)):
                return
            step += 1

    def next(self) -> tuple[int, dict]:
        """The next ``(step, batch)`` in order; re-raises any producer
        exception (chained, with the failing step) instead of hanging."""
        if self._error is not None and self._q.empty():
            raise RuntimeError(
                f"prefetch producer failed at step {self._error_step}"
            ) from self._error
        step, batch = self._q.get()
        if isinstance(batch, BaseException):
            raise RuntimeError(
                f"prefetch producer failed at step {step}") from batch
        return step, batch

    def close(self):
        """Stop the producer and reap the thread.  The stop event is
        checked inside the producer's put-retry loop, so the drain below
        cannot race it back to sleep; if the thread is mid-``batch_at``
        we keep draining until it notices the event and exits."""
        self._stop.set()
        while self._thread.is_alive():
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.2)
        self._thread.join()


def make_batches(source, steps: int, start_step: int = 0):
    for s in range(start_step, start_step + steps):
        yield s, source.batch_at(s)
