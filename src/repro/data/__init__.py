"""Data pipeline: deterministic, shard-aware token streams with prefetch."""

from .pipeline import (MemmapCorpus, SyntheticLM, Prefetcher, make_batches)

__all__ = ["MemmapCorpus", "SyntheticLM", "Prefetcher", "make_batches"]
