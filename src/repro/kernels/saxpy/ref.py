"""Pure-jnp oracles for the SAXPY kernels (flat + record forms)."""

import jax
import jax.numpy as jnp

from repro.core.layout import RecordArray


def saxpy_ref(a, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.asarray(a, dtype=x.dtype) * x + y


def saxpy_record_ref(rec: RecordArray, a) -> RecordArray:
    return rec.set_field("y", saxpy_ref(a, rec.field("x"), rec.field("y")))
