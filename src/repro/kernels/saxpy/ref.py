"""Pure-jnp oracle for the SAXPY kernel."""

import jax
import jax.numpy as jnp


def saxpy_ref(a, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.asarray(a, dtype=x.dtype) * x + y
