"""Jitted public wrappers for SAXPY (flat arrays + layout-polymorphic
record form; the record form is the paper's Table 2 layout axis)."""

from functools import partial

import jax

from repro.core.layout import dispatch_with_relayout
from repro.tuning.tiles import resolve_tile
from .kernel import (DEFAULT_BLOCK, PREFERRED_LAYOUT, SAXPY_SPEC,
                     SUPPORTED_LAYOUTS, TILE_KERNEL, saxpy_pallas,
                     saxpy_record_pallas)
from .ref import saxpy_record_ref, saxpy_ref


@partial(jax.jit, static_argnames=("block", "bounds_check", "use_pallas",
                                   "interpret"))
def saxpy(a, x, y, *, block: int = 1024, bounds_check: bool = True,
          use_pallas: bool = True, interpret: bool = True):
    """``a * x + y`` over flat arrays (paper Table 2's iterator-overhead
    probe; the record form below is the layout axis)."""
    if use_pallas:
        return saxpy_pallas(a, x, y, block=block, bounds_check=bounds_check,
                            interpret=interpret)
    return saxpy_ref(a, x, y)


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def _saxpy_record_jit(rec, a, *, block: int, use_pallas: bool,
                      interpret: bool):
    if not use_pallas:
        return saxpy_record_ref(rec, a)
    return dispatch_with_relayout(
        saxpy_record_pallas, rec, a, supported=SUPPORTED_LAYOUTS,
        preferred=PREFERRED_LAYOUT, block=block, interpret=interpret)


def saxpy_record(rec, a, *, block=None, use_pallas: bool = True,
                 interpret: bool = True):
    """``y = a*x + y`` on a RecordArray with fields ``x``/``y`` — same
    kernel body under AoS, SoA and AoSoA (paper's polymorphism claim).
    A layout outside SUPPORTED_LAYOUTS is staged through PREFERRED_LAYOUT
    (all three are native today, so this is the contract, not a copy).

    ``block=None`` resolves the VMEM tile through the autotuner's
    ambient tile scope (``repro.tuning.tiles``): an ``Executor`` with a
    tuned plan traces this call under its measured-best block; outside
    any scope the kernel default applies.  An explicit ``block`` always
    wins."""
    block = resolve_tile(TILE_KERNEL, block, DEFAULT_BLOCK, shape=rec.space)
    return _saxpy_record_jit(rec, a, block=block, use_pallas=use_pallas,
                             interpret=interpret)
