"""Jitted public wrapper for SAXPY."""

from functools import partial

import jax

from .kernel import saxpy_pallas
from .ref import saxpy_ref


@partial(jax.jit, static_argnames=("block", "bounds_check", "use_pallas",
                                   "interpret"))
def saxpy(a, x, y, *, block: int = 1024, bounds_check: bool = True,
          use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return saxpy_pallas(a, x, y, block=block, bounds_check=bounds_check,
                            interpret=interpret)
    return saxpy_ref(a, x, y)
