"""Jitted public wrappers for SAXPY (flat arrays + layout-polymorphic
record form; the record form is the paper's Table 2 layout axis)."""

from functools import partial

import jax

from repro.core.layout import dispatch_with_relayout
from .kernel import (PREFERRED_LAYOUT, SAXPY_SPEC, SUPPORTED_LAYOUTS,
                     saxpy_pallas, saxpy_record_pallas)
from .ref import saxpy_record_ref, saxpy_ref


@partial(jax.jit, static_argnames=("block", "bounds_check", "use_pallas",
                                   "interpret"))
def saxpy(a, x, y, *, block: int = 1024, bounds_check: bool = True,
          use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return saxpy_pallas(a, x, y, block=block, bounds_check=bounds_check,
                            interpret=interpret)
    return saxpy_ref(a, x, y)


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def saxpy_record(rec, a, *, block: int = 1024, use_pallas: bool = True,
                 interpret: bool = True):
    """``y = a*x + y`` on a RecordArray with fields ``x``/``y`` — same
    kernel body under AoS, SoA and AoSoA (paper's polymorphism claim).
    A layout outside SUPPORTED_LAYOUTS is staged through PREFERRED_LAYOUT
    (all three are native today, so this is the contract, not a copy)."""
    if not use_pallas:
        return saxpy_record_ref(rec, a)
    return dispatch_with_relayout(
        saxpy_record_pallas, rec, a, supported=SUPPORTED_LAYOUTS,
        preferred=PREFERRED_LAYOUT, block=block, interpret=interpret)
