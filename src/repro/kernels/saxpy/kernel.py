"""SAXPY Pallas kernel (paper §7.1, Table 2).

The paper uses SAXPY to measure the overhead of its iterator abstraction
(bounds checking) vs raw CUDA/cuBLAS.  The TPU analogue of the paper's
"NBC" (no-boundary-check) variant is a grid that exactly tiles the array
(no masking); the checked variant masks the tail block with
``pl.program_id``-derived indices — the same cost model: one extra
predicated lane op per element.

Block size is the VMEM tiling knob (paper's single-line memory-space
config): blocks must be multiples of 128 lanes for full VREG occupancy.
"""

from __future__ import annotations

from functools import partial as _partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import (Layout, RecordArray, RecordRef, RecordSpec,
                               record_grid_1d)
from repro.tuning.tiles import register_tile_kernel

# record form: x and y live in ONE record buffer (paper §4.2's layout axis
# for Table 2); metadata consumed by the ops.py wrapper, which relayouts
# inputs whose layout is not natively supported
SAXPY_SPEC = RecordSpec.create("x", "y")
SUPPORTED_LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)
PREFERRED_LAYOUT = Layout.SOA
TILE_KERNEL = "saxpy"     # name in the autotuner's tile registry
DEFAULT_BLOCK = 1024


def tile_candidates(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Feasible VMEM block sizes for a 1-d record space of extent ``n``
    (the autotuner's search axis for this kernel): lane-width multiples
    that tile ``n`` exactly, the kernel's default included when it
    fits."""
    (n,) = shape
    return tuple(b for b in (256, 512, 1024, 2048, 4096, 8192)
                 if b <= n and n % b == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def _saxpy_kernel_masked(size, block, a_ref, x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    idx = i * block + jax.lax.iota(jnp.int32, block)
    valid = idx < size  # paper's iterator validity check
    v = a_ref[0] * x_ref[...] + y_ref[...]
    o_ref[...] = jnp.where(valid, v, y_ref[...])


def saxpy_pallas(
    a: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    block: int = 1024,
    bounds_check: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """y_out = a * x + y over a 1-d array, VMEM-tiled in ``block`` chunks."""
    size = x.shape[0]
    if size % block:
        # pad to the grid; masked variant keeps tail exact
        pad = block - size % block
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    grid = (x.shape[0] // block,)
    a_arr = jnp.asarray(a, dtype=x.dtype).reshape(1)

    if bounds_check:
        from functools import partial

        kern = partial(_saxpy_kernel_masked, size, block)
    else:
        kern = _saxpy_kernel

    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(a_arr, x, y)
    return out[:size]


def _saxpy_record_kernel(spec: RecordSpec, layout: Layout, a_ref, p_ref,
                         o_ref):
    p = RecordRef(p_ref, spec, layout)
    o = RecordRef(o_ref, spec, layout)
    a = a_ref[0]
    x = p.get("x")
    o.set("x", x)
    o.set("y", a * x + p.get("y"))


def saxpy_record_pallas(
    rec: RecordArray,
    a,
    *,
    block: int = 1024,
    interpret: bool = True,
) -> RecordArray:
    """``y = a*x + y`` over a two-field record in any of the three layouts
    — the kernel body is a single :class:`RecordRef` program."""
    (n,) = rec.space
    spec, layout = rec.spec, rec.layout
    assert n % block == 0, f"n={n} must tile by block={block}"
    grid, bspec = record_grid_1d(spec, layout, n, block)

    a_arr = jnp.asarray(a, dtype=rec.dtype).reshape(1)
    out = pl.pallas_call(
        _partial(_saxpy_record_kernel, spec, layout),
        out_shape=jax.ShapeDtypeStruct(rec.data.shape, rec.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), bspec],
        out_specs=bspec,
        interpret=interpret,
    )(a_arr, rec.data)
    return RecordArray(out, spec, layout)
