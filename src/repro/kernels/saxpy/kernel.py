"""SAXPY Pallas kernel (paper §7.1, Table 2).

The paper uses SAXPY to measure the overhead of its iterator abstraction
(bounds checking) vs raw CUDA/cuBLAS.  The TPU analogue of the paper's
"NBC" (no-boundary-check) variant is a grid that exactly tiles the array
(no masking); the checked variant masks the tail block with
``pl.program_id``-derived indices — the same cost model: one extra
predicated lane op per element.

Block size is the VMEM tiling knob (paper's single-line memory-space
config): blocks must be multiples of 128 lanes for full VREG occupancy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def _saxpy_kernel_masked(size, block, a_ref, x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    idx = i * block + jax.lax.iota(jnp.int32, block)
    valid = idx < size  # paper's iterator validity check
    v = a_ref[0] * x_ref[...] + y_ref[...]
    o_ref[...] = jnp.where(valid, v, y_ref[...])


def saxpy_pallas(
    a: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    block: int = 1024,
    bounds_check: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """y_out = a * x + y over a 1-d array, VMEM-tiled in ``block`` chunks."""
    size = x.shape[0]
    if size % block:
        # pad to the grid; masked variant keeps tail exact
        pad = block - size % block
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    grid = (x.shape[0] // block,)
    a_arr = jnp.asarray(a, dtype=x.dtype).reshape(1)

    if bounds_check:
        from functools import partial

        kern = partial(_saxpy_kernel_masked, size, block)
    else:
        kern = _saxpy_kernel

    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(a_arr, x, y)
    return out[:size]
