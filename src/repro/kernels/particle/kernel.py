"""Particle update Pallas kernel (paper §7.2, Table 3) — layout polymorphic.

``x += v * dt`` for N particles with 3-d position/velocity stored in ONE
record buffer as AoS ``(n, 6)``, SoA ``(6, n)`` or AoSoA
``(n_tiles, 6, tile)``.  The kernel body is written once against
:class:`RecordRef`; the layout only changes the BlockSpec.  On TPU the
SoA block streams 128-lane contiguous VREGs per component while the AoS
block wastes lanes on the 6-wide minor dim — the paper's coalescing
argument, relocated to lane tiling (DESIGN.md §2).  AoSoA keeps the
lane-filling tile minor AND whole records contiguous per tile, which is
the preferred streaming layout when no cross-particle stencil exists.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import (Layout, RecordArray, RecordRef, RecordSpec,
                               Vector, record_grid_1d)
from repro.tuning.tiles import register_tile_kernel

PARTICLE_SPEC = RecordSpec.create(Vector("x", 3), Vector("v", 3))

# metadata consumed by the ops.py wrapper, which relayouts inputs whose
# layout is not natively supported
SUPPORTED_LAYOUTS = (Layout.AOS, Layout.SOA, Layout.AOSOA)
PREFERRED_LAYOUT = Layout.AOSOA
TILE_KERNEL = "particle"  # name in the autotuner's tile registry
DEFAULT_BLOCK = 512


def tile_candidates(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Feasible particles-per-program block sizes for ``n`` particles
    (the autotuner's search axis): exact tilings only, so no variant
    ever needs the masked tail path."""
    (n,) = shape
    return tuple(b for b in (128, 256, 512, 1024, 2048, 4096)
                 if b <= n and n % b == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def _particle_kernel(spec: RecordSpec, layout: Layout, dt_ref, p_ref, o_ref):
    p = RecordRef(p_ref, spec, layout)
    o = RecordRef(o_ref, spec, layout)
    dt = dt_ref[0]
    for c in range(3):
        x = p.get("x", c)
        v = p.get("v", c)
        o.set("x", x + v * dt, c)
        o.set("v", v, c)


def particle_update_pallas(
    particles: RecordArray,
    dt: float,
    *,
    block: int = 512,
    interpret: bool = True,
) -> RecordArray:
    (n,) = particles.space
    spec, layout = particles.spec, particles.layout
    assert n % block == 0, f"n={n} must tile by block={block}"
    grid, bspec = record_grid_1d(spec, layout, n, block)

    dt_arr = jnp.asarray(dt, dtype=particles.dtype).reshape(1)
    out = pl.pallas_call(
        partial(_particle_kernel, spec, layout),
        out_shape=jax.ShapeDtypeStruct(particles.data.shape, particles.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), bspec],
        out_specs=bspec,
        interpret=interpret,
    )(dt_arr, particles.data)
    return RecordArray(out, spec, layout)
