"""Jitted public wrapper for the particle update."""

from functools import partial

import jax

from .kernel import PARTICLE_SPEC, particle_update_pallas
from .ref import particle_update_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def particle_update(particles, dt, *, block: int = 512, use_pallas: bool = True,
                    interpret: bool = True):
    if use_pallas:
        return particle_update_pallas(particles, dt, block=block,
                                      interpret=interpret)
    return particle_update_ref(particles, dt)
