"""Jitted public wrapper for the particle update (layout polymorphic:
AoS / SoA / AoSoA, same kernel body; a layout outside SUPPORTED_LAYOUTS
would be staged through PREFERRED_LAYOUT, mirroring the stencil wrapper)."""

from functools import partial

import jax

from repro.core.layout import dispatch_with_relayout
from repro.tuning.tiles import resolve_tile
from .kernel import (DEFAULT_BLOCK, PARTICLE_SPEC, PREFERRED_LAYOUT,
                     SUPPORTED_LAYOUTS, TILE_KERNEL, particle_update_pallas)
from .ref import particle_update_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def _particle_update_jit(particles, dt, *, block: int, use_pallas: bool,
                         interpret: bool):
    if not use_pallas:
        return particle_update_ref(particles, dt)
    return dispatch_with_relayout(
        particle_update_pallas, particles, dt, supported=SUPPORTED_LAYOUTS,
        preferred=PREFERRED_LAYOUT, block=block, interpret=interpret)


def particle_update(particles, dt, *, block=None, use_pallas: bool = True,
                    interpret: bool = True):
    """``x += v * dt`` over a particle RecordArray (paper Table 3) — one
    kernel body for AoS / SoA / AoSoA.

    ``block=None`` resolves the particles-per-program tile through the
    autotuner's ambient tile scope (``repro.tuning.tiles``); an explicit
    ``block`` always wins, and outside any scope the kernel default
    applies."""
    block = resolve_tile(TILE_KERNEL, block, DEFAULT_BLOCK,
                         shape=particles.space)
    return _particle_update_jit(particles, dt, block=block,
                                use_pallas=use_pallas, interpret=interpret)
