"""Jitted public wrapper for the particle update (layout polymorphic:
AoS / SoA / AoSoA, same kernel body; a layout outside SUPPORTED_LAYOUTS
would be staged through PREFERRED_LAYOUT, mirroring the stencil wrapper)."""

from functools import partial

import jax

from repro.core.layout import dispatch_with_relayout
from .kernel import (PARTICLE_SPEC, PREFERRED_LAYOUT, SUPPORTED_LAYOUTS,
                     particle_update_pallas)
from .ref import particle_update_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def particle_update(particles, dt, *, block: int = 512, use_pallas: bool = True,
                    interpret: bool = True):
    if not use_pallas:
        return particle_update_ref(particles, dt)
    return dispatch_with_relayout(
        particle_update_pallas, particles, dt, supported=SUPPORTED_LAYOUTS,
        preferred=PREFERRED_LAYOUT, block=block, interpret=interpret)
