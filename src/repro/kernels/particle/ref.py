"""Pure-jnp oracle for the particle update."""

from repro.core.layout import RecordArray


def particle_update_ref(particles: RecordArray, dt: float) -> RecordArray:
    x = particles.field("x")
    v = particles.field("v")
    return particles.set_field("x", x + v * dt)
