"""Mamba-2 SSD intra-chunk Pallas kernel.

The chunked dual form splits SSD into (a) an intra-chunk quadratic part —
the FLOPs-dominant, MXU-friendly piece, computed here per (batch, head,
chunk) tile in VMEM — and (b) a cheap inter-chunk state scan left to XLA
(see ref.ssd_chunked).  The kernel also emits each chunk's outgoing state
contribution so the host-side scan needs no second data pass.

Tile: x (L, P), dt (L,), B/C (L, N) with L = chunk, all staged in VMEM;
matmuls (L,N)x(N,L) and (L,L)x(L,P) map to the MXU at L,P,N multiples
of 128 (L=chunk is the block knob).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tuning.tiles import register_tile_kernel

TILE_KERNEL = "ssd"       # name in the autotuner's tile registry
DEFAULT_CHUNK = 64


def tile_candidates(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Feasible chunk lengths for a sequence of ``S`` positions (the
    autotuner's search axis): the chunk is the L of the intra-chunk
    quadratic part, so it trades MXU tile efficiency against the
    O(L^2) score matrix; exact tilings only."""
    (s,) = shape
    return tuple(c for c in (32, 64, 128, 256) if c <= s and s % c == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def _ssd_chunk_kernel(chunk: int,
                      x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, s_ref):
    b = pl.program_id(0)
    c = pl.program_id(1)
    h = pl.program_id(2)
    L = chunk

    x = x_ref[b, pl.ds(c * L, L), h, :].astype(jnp.float32)      # (L, P)
    dt = dt_ref[b, pl.ds(c * L, L), h].astype(jnp.float32)       # (L,)
    A = a_ref[h].astype(jnp.float32)                             # ()
    Bm = b_ref[b, pl.ds(c * L, L), :].astype(jnp.float32)        # (L, N)
    C = c_ref[b, pl.ds(c * L, L), :].astype(jnp.float32)         # (L, N)

    cs = jnp.cumsum(dt * A)                                      # (L,)
    seg = cs[:, None] - cs[None, :]
    mask = jax.lax.iota(jnp.int32, L)[:, None] >= \
        jax.lax.iota(jnp.int32, L)[None, :]
    decay = jnp.where(mask, jnp.exp(seg), 0.0)                   # (L, L)
    cb = jax.lax.dot_general(C, Bm, (((1,), (1,)), ((), ())))    # (L, L)
    scores = cb * decay
    dx = dt[:, None] * x                                         # (L, P)
    y = scores @ dx                                              # (L, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # outgoing state contribution: sum_j exp(cs_L - cs_j) dt_j x_j B_j^T
    d2e = jnp.exp(cs[-1] - cs)                                   # (L,)
    w = (dt * d2e)[:, None] * x                                  # (L, P)
    s = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())))     # (P, N)
    s_ref[0, 0, 0] = s.astype(s_ref.dtype)


def ssd_intra_chunk_pallas(x, dt, A, Bm, C, *, chunk: int = 64,
                           interpret: bool = True):
    """Returns (y_intra (B,S,H,P), s_chunk (B,nc,H,P,N)) — feed s_chunk to
    the inter-chunk scan in ref.ssd_chunked form."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    grid = (B_, nc, H)

    kern = functools.partial(_ssd_chunk_kernel, chunk)
    y, s = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((B_, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B_, nc, H, P, N), jnp.float32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 5,
        out_specs=(
            pl.BlockSpec((1, chunk, 1, P), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ),
        interpret=interpret,
    )(x, dt, A, Bm, C)
    return y, s


def ssd_pallas(x, dt, A, Bm, C, D=None, init_state=None, *, chunk: int = 64,
               interpret: bool = True):
    """Full SSD with the Pallas intra-chunk kernel + XLA inter-chunk scan."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    nc = S // chunk
    y_intra, s_chunk = ssd_intra_chunk_pallas(
        x, dt, A, Bm, C, chunk=chunk, interpret=interpret)

    dtc = dt.reshape(B_, nc, chunk, H).astype(f32)
    cs = jnp.cumsum(dtc * A, axis=2)
    total = jnp.exp(cs[:, :, -1, :])  # (B, nc, H)
    state0 = (jnp.zeros((B_, H, P, N), f32)
              if init_state is None else init_state.astype(f32))

    def step(state, inp):
        s_c, tot = inp
        return state * tot[..., None, None] + s_c, state

    final_state, entering = jax.lax.scan(
        step, state0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)  # (B, nc, H, P, N)

    cc = C.reshape(B_, nc, chunk, N).astype(f32)
    in_decay = jnp.exp(cs)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, entering, in_decay)
    y = y_intra.astype(f32) + y_inter.reshape(B_, nc, chunk, H, P).reshape(
        B_, S, H, P)
    if D is not None:
        y = y + x.astype(f32) * D[None, None, :, None]
    return y.astype(x.dtype), final_state
