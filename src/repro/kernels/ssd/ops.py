"""Jitted public wrapper for SSD."""

from functools import partial

import jax

from .kernel import ssd_pallas
from .ref import ssd_chunked, ssd_decode_step, ssd_naive


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd(x, dt, A, Bm, C, D=None, init_state=None, *, chunk: int = 64,
        use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return ssd_pallas(x, dt, A, Bm, C, D, init_state, chunk=chunk,
                          interpret=interpret)
    return ssd_chunked(x, dt, A, Bm, C, D, init_state, chunk=chunk)
