"""Jitted public wrapper for SSD."""

from functools import partial

import jax

from repro.tuning.tiles import resolve_tile
from .kernel import DEFAULT_CHUNK, TILE_KERNEL, ssd_pallas
from .ref import ssd_chunked, ssd_decode_step, ssd_naive


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def _ssd_jit(x, dt, A, Bm, C, D=None, init_state=None, *, chunk: int,
             use_pallas: bool, interpret: bool):
    if use_pallas:
        return ssd_pallas(x, dt, A, Bm, C, D, init_state, chunk=chunk,
                          interpret=interpret)
    return ssd_chunked(x, dt, A, Bm, C, D, init_state, chunk=chunk)


def ssd(x, dt, A, Bm, C, D=None, init_state=None, *, chunk=None,
        use_pallas: bool = True, interpret: bool = True):
    """Mamba-2 SSD: Pallas intra-chunk quadratic part + XLA inter-chunk
    scan; returns ``(y, final_state)``.

    ``chunk=None`` resolves the chunk length through the autotuner's
    ambient tile scope (kernel ``"ssd"``); an explicit ``chunk`` always
    wins, and outside any scope the kernel default applies."""
    chunk = resolve_tile(TILE_KERNEL, chunk, DEFAULT_CHUNK,
                         shape=(x.shape[1],))
    return _ssd_jit(x, dt, A, Bm, C, D, init_state, chunk=chunk,
                    use_pallas=use_pallas, interpret=interpret)
