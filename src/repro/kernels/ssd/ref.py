"""Pure-jnp oracles for Mamba-2 SSD (state-space duality, arXiv:2405.21060).

``ssd_naive``   — token-by-token linear recurrence (ground truth).
``ssd_chunked`` — chunked dual form: intra-chunk (quadratic within L) +
                  inter-chunk state scan; exact, and the structure the
                  Pallas kernel implements.

Shapes (n_groups = 1):
  x  (B, S, H, P)   dt (B, S, H)    A (H,) negative
  Bm (B, S, N)      C  (B, S, N)    D (H,) skip
  y  (B, S, H, P)   state (B, H, P, N)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_naive(x, dt, A, Bm, C, D=None, init_state=None):
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    state0 = (jnp.zeros((B_, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def step(state, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A)  # (B,H)
        upd = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        state = state * da[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), state


def _segsum(cs):
    """segsum(cs)[i, j] = cs[i] - cs[j] (lower-triangular mask applied by
    caller); cs is the inclusive cumulative sum of dA_log within a chunk."""
    return cs[..., :, None] - cs[..., None, :]


def ssd_chunked(x, dt, A, Bm, C, D=None, init_state=None, chunk: int = 64):
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(B_, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(B_, nc, chunk, H).astype(f32)
    bc = Bm.reshape(B_, nc, chunk, N).astype(f32)
    cc = C.reshape(B_, nc, chunk, N).astype(f32)

    da_log = dtc * A  # (B, nc, L, H)
    cs = jnp.cumsum(da_log, axis=2)  # inclusive

    # -- intra-chunk (the FLOPs-dominant dual form) -------------------------
    seg = _segsum(jnp.moveaxis(cs, 3, 2))  # (B, nc, H, L, L)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, nc, L, L)
    scores = cb[:, :, None] * decay  # (B, nc, H, L, L)
    dx = dtc[..., None] * xc  # (B, nc, L, H, P)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, dx)

    # -- chunk states ---------------------------------------------------
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (B, nc, L, H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, dtc * decay_to_end, xc)

    # -- inter-chunk state scan ------------------------------------------
    total = jnp.exp(cs[:, :, -1, :])  # (B, nc, H) decay across whole chunk
    state0 = (jnp.zeros((B_, H, P, N), f32)
              if init_state is None else init_state.astype(f32))

    def step(state, inp):
        s_c, tot = inp  # (B,H,P,N), (B,H)
        new = state * tot[..., None, None] + s_c
        return new, state  # emit the state *entering* the chunk

    states_seq = (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0))
    final_state, entering = jax.lax.scan(step, state0, states_seq)
    entering = jnp.moveaxis(entering, 0, 1)  # (B, nc, H, P, N)

    # -- inter-chunk contribution ----------------------------------------
    in_decay = jnp.exp(cs)  # decay from chunk start to position i
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, entering, in_decay)

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    if D is not None:
        y = y + x.astype(f32) * D[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, xt, dtt, A, bt, ct, D=None):
    """Single-token recurrent step for serving (constant memory).

    state (B,H,P,N); xt (B,H,P); dtt (B,H); bt/ct (B,N)."""
    f32 = jnp.float32
    state = state.astype(f32)
    da = jnp.exp(dtt.astype(f32) * A)
    upd = (dtt.astype(f32)[..., None] * xt.astype(f32))[..., None] \
        * bt.astype(f32)[:, None, None, :]
    state = state * da[..., None, None] + upd
    yt = jnp.einsum("bhpn,bn->bhp", state, ct.astype(f32))
    if D is not None:
        yt = yt + xt.astype(f32) * D[None, :, None]
    return state, yt.astype(xt.dtype)
