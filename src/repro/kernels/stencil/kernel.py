"""FORCE flux-difference Pallas kernel (paper §7.3, Table 4).

Stencil over a haloed 2-D Euler state record, layout polymorphic:

* the haloed input stays in ``ANY`` (HBM) memory space; each grid program
  DMAs its halo-inclusive tile ``(bx+2, by+2)`` into VMEM — this IS the
  paper's ``in_shared()`` staging on TPU (DESIGN.md §2 C2);
* SoA tiles arrive component-major (zero relayout); AoS tiles are
  transposed on load — the layout cost the paper measures;
* block shape = the paper's sub-partition knob (§4.1), hardware-aligned
  to multiples of (8, 128) for the VPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import Layout, RecordArray
from repro.physics import euler
from repro.tuning.tiles import register_tile_kernel

# dispatch metadata consumed by ops.py and the executor's layout solver:
# the halo-inclusive tile walk needs per-axis storage, so AoSoA inputs are
# relayouted at the wrapper boundary (exactly what the solver would emit)
SUPPORTED_LAYOUTS = (Layout.AOS, Layout.SOA)
PREFERRED_LAYOUT = Layout.SOA
TILE_KERNEL = "flux"      # name in the autotuner's tile registry
DEFAULT_BLOCK = (8, 128)


def tile_candidates(shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """Feasible ``(bx, by)`` VMEM tile shapes for an interior of
    ``(nx, ny)`` cells (the autotuner's search axis): VPU-aligned
    multiples of (8, sublane) × (lane-divisor) that tile the interior
    exactly — the halo-inclusive load handles the +2 ring."""
    nx, ny = shape
    return tuple((bx, by)
                 for bx in (8, 16, 32, 64) if bx <= nx and nx % bx == 0
                 for by in (64, 128, 256) if by <= ny and ny % by == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def _flux_kernel(layout: Layout, bx: int, by: int, u_ref, lam_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    # stage halo-inclusive tile into VMEM (paper's shared-memory load)
    if layout is Layout.SOA:
        tile = u_ref[:, pl.ds(i * bx, bx + 2), pl.ds(j * by, by + 2)]
    else:
        tile = u_ref[pl.ds(i * bx, bx + 2), pl.ds(j * by, by + 2), :]
        tile = jnp.moveaxis(tile, -1, 0)  # AoS relayout cost
    lam_x = lam_ref[0]
    lam_y = lam_ref[1]
    out = euler.flux_difference(tile, lam_x, lam_y)  # (4, bx, by)
    if layout is Layout.SOA:
        o_ref[...] = out
    else:
        o_ref[...] = jnp.moveaxis(out, 0, -1)


def flux_difference_pallas(
    state_haloed: RecordArray,
    lam_x: float,
    lam_y: float,
    *,
    block: tuple[int, int] = (8, 128),
    interpret: bool = True,
) -> RecordArray:
    """Paper Table 4: sum of FORCE flux differences over both dims.

    ``state_haloed`` has space ``(nx+2, ny+2)``; returns space ``(nx, ny)``.
    """
    layout = state_haloed.layout
    nx, ny = (s - 2 for s in state_haloed.space)
    bx, by = block
    bx, by = min(bx, nx), min(by, ny)
    assert nx % bx == 0 and ny % by == 0, (nx, ny, bx, by)
    grid = (nx // bx, ny // by)

    out_shape = RecordArray.storage_shape(state_haloed.spec, (nx, ny), layout)
    if layout is Layout.SOA:
        out_spec = pl.BlockSpec((4, bx, by), lambda i, j: (0, i, j))
    else:
        out_spec = pl.BlockSpec((bx, by, 4), lambda i, j: (i, j, 0))

    lam = jnp.asarray([lam_x, lam_y], dtype=state_haloed.dtype)
    out = pl.pallas_call(
        partial(_flux_kernel, layout, bx, by),
        out_shape=jax.ShapeDtypeStruct(out_shape, state_haloed.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=out_spec,
        interpret=interpret,
    )(state_haloed.data, lam)
    return RecordArray(out, state_haloed.spec, layout)
