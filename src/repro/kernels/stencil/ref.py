"""Pure-jnp oracle for the FORCE flux-difference stencil."""

from repro.core.layout import RecordArray
from repro.physics import euler


def flux_difference_ref(
    state_haloed: RecordArray, lam_x: float, lam_y: float
) -> RecordArray:
    U = euler.stack_state(state_haloed)
    out = euler.flux_difference(U, lam_x, lam_y)
    like = RecordArray(
        state_haloed.data, state_haloed.spec, state_haloed.layout
    )
    # build an un-haloed record with the same layout
    import jax.numpy as jnp

    from repro.core.layout import Layout

    data = out if state_haloed.layout is Layout.SOA else jnp.moveaxis(out, 0, -1)
    return RecordArray(data, state_haloed.spec, state_haloed.layout)
