"""Pure-jnp oracle for the FORCE flux-difference stencil."""

from repro.core.layout import RecordArray
from repro.physics import euler


def flux_difference_ref(
    state_haloed: RecordArray, lam_x: float, lam_y: float
) -> RecordArray:
    U = euler.stack_state(state_haloed)
    out = euler.flux_difference(U, lam_x, lam_y)
    # un-haloed record in the same layout as the input (layout-generic)
    return euler.unstack_state(out, state_haloed)
