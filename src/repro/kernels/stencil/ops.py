"""Jitted public wrapper for the FORCE flux-difference stencil."""

from functools import partial

import jax

from .kernel import flux_difference_pallas
from .ref import flux_difference_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def flux_difference(state_haloed, lam_x, lam_y, *, block=(8, 128),
                    use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return flux_difference_pallas(state_haloed, lam_x, lam_y, block=block,
                                      interpret=interpret)
    return flux_difference_ref(state_haloed, lam_x, lam_y)
