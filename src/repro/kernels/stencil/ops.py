"""Jitted public wrapper + graph builder for the FORCE flux-difference
stencil.

Layout dispatch: the Pallas kernel walks halo-inclusive tiles, which
needs per-axis storage (AoS or SoA).  An AoSoA input is relayouted to the
kernel's preferred layout on the way in and back on the way out — the
same boundary conversion the executor's layout solver emits, so results
are numerically identical under all three layouts.
"""

from functools import partial
from typing import Optional

import jax

from repro.core.graph import Graph, concurrent_padded_access
from repro.core.layout import dispatch_with_relayout
from repro.core.tensor import DistTensor
from repro.tuning.tiles import resolve_tile
from .kernel import (DEFAULT_BLOCK, PREFERRED_LAYOUT, SUPPORTED_LAYOUTS,
                     TILE_KERNEL, flux_difference_pallas)
from .ref import flux_difference_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def _flux_difference_jit(state_haloed, lam_x, lam_y, *, block,
                         use_pallas: bool, interpret: bool):
    if not use_pallas:
        return flux_difference_ref(state_haloed, lam_x, lam_y)
    return dispatch_with_relayout(
        flux_difference_pallas, state_haloed, lam_x, lam_y,
        supported=SUPPORTED_LAYOUTS, preferred=PREFERRED_LAYOUT,
        block=block, interpret=interpret)


def flux_difference(state_haloed, lam_x, lam_y, *, block=None,
                    use_pallas: bool = True, interpret: bool = True):
    """Sum of FORCE flux differences over both dims of a haloed 2-D
    Euler record (paper Table 4): ``(nx+2, ny+2)`` space in, ``(nx, ny)``
    out, layout polymorphic (AoSoA staged through the kernel's preferred
    per-axis layout).

    ``block=None`` resolves the ``(bx, by)`` VMEM tile through the
    autotuner's ambient tile scope (``repro.tuning.tiles``); an explicit
    ``block`` always wins, and outside any scope the kernel default
    applies."""
    interior = tuple(s - 2 for s in state_haloed.space)
    block = resolve_tile(TILE_KERNEL, block, DEFAULT_BLOCK, shape=interior)
    return _flux_difference_jit(state_haloed, lam_x, lam_y, block=block,
                                use_pallas=use_pallas, interpret=interpret)


def make_flux_difference_graph(
    u: DistTensor,
    out: DistTensor,
    lam_x,
    lam_y,
    *,
    overlap: bool = True,
    use_pallas: bool = False,
    block=None,
    interpret: bool = True,
    graph: Optional[Graph] = None,
) -> Graph:
    """One-node Ripple graph: FORCE flux difference over a (possibly
    2-D-partitioned) Euler record ``u`` with halo ``(1, 1)`` into ``out``.

    With ``overlap=True`` the executor's transfer schedule sends every
    halo block (edge strips + corners) up front and hides the flights
    behind the interior program; the per-(axis, side) boundary strips are
    stitched afterwards.  The Pallas path asserts block-divisible extents
    (boundary strips are 1 cell thin), so the default here is the
    shape-polymorphic reference path — flip ``use_pallas`` where the
    interior extents divide ``block``.

    ``graph=`` appends the node to an existing builder instead of
    creating a fresh one: compose several kernel nodes into one graph and
    the dependency-DAG scheduler fuses the independent ones into a shared
    jit segment (``core/schedule.py``).
    """

    def flux_node(rec, _out):
        return flux_difference(rec, lam_x, lam_y, block=block,
                               use_pallas=use_pallas, interpret=interpret)

    g = graph if graph is not None else Graph(name="flux_difference")
    g.split(flux_node, concurrent_padded_access(u), out, overlap=overlap)
    return g
