"""Jitted public wrapper for the FORCE flux-difference stencil.

Layout dispatch: the Pallas kernel walks halo-inclusive tiles, which
needs per-axis storage (AoS or SoA).  An AoSoA input is relayouted to the
kernel's preferred layout on the way in and back on the way out — the
same boundary conversion the executor's layout solver emits, so results
are numerically identical under all three layouts.
"""

from functools import partial

import jax

from repro.core.layout import dispatch_with_relayout
from .kernel import (PREFERRED_LAYOUT, SUPPORTED_LAYOUTS,
                     flux_difference_pallas)
from .ref import flux_difference_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def flux_difference(state_haloed, lam_x, lam_y, *, block=(8, 128),
                    use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return flux_difference_ref(state_haloed, lam_x, lam_y)
    return dispatch_with_relayout(
        flux_difference_pallas, state_haloed, lam_x, lam_y,
        supported=SUPPORTED_LAYOUTS, preferred=PREFERRED_LAYOUT,
        block=block, interpret=interpret)
