"""Pure-jnp oracle for the FIM kernel: identical block semantics
(frozen-halo inner sweeps per tile), plus a global-Jacobi reference used
for convergence testing."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import godunov_update


def eikonal_fim_ref(
    phi_haloed: jax.Array,
    source_mask: jax.Array,
    h: float,
    *,
    inner: int = 4,
    block: tuple[int, int] = (8, 128),
) -> jax.Array:
    nx, ny = (s - 2 for s in phi_haloed.shape)
    bx, by = (min(block[0], nx), min(block[1], ny))
    gx, gy = nx // bx, ny // by

    def tile_update(i, j):
        tile = jax.lax.dynamic_slice(phi_haloed, (i * bx, j * by),
                                     (bx + 2, by + 2))
        mask = jax.lax.dynamic_slice(source_mask, (i * bx, j * by), (bx, by))

        def body(_, t):
            return t.at[1:-1, 1:-1].set(godunov_update(t, mask, h))

        tile = jax.lax.fori_loop(0, inner, body, tile)
        return tile[1:-1, 1:-1]

    rows = []
    for i in range(gx):
        cols = [tile_update(i, j) for j in range(gy)]
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


def eikonal_global_jacobi(
    phi: jax.Array, source_mask: jax.Array, h: float, iters: int
) -> jax.Array:
    """Whole-grid Jacobi iteration (transmissive edges) — convergence
    oracle: both block-FIM and this converge to the same viscosity
    solution (the distance field for f = 1)."""

    def body(_, p):
        pad = jnp.pad(p, 1, mode="edge")
        return godunov_update(pad, source_mask, h)

    return jax.lax.fori_loop(0, iters, body, phi)
