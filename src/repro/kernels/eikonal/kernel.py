"""Eikonal FIM Pallas kernel (paper §7.4, Table 5).

Solves ``|grad phi| = 1/f`` (f = 1: signed-distance reinit) with the Fast
Iterative Method.  The paper's winning configuration stages a tile in
shared memory and runs several update sweeps on it before writing back;
on TPU each grid program DMAs a halo-inclusive tile into VMEM and runs
``inner`` Jacobi sweeps with frozen halos (the FIM ghost-zone trade),
then the outer loop (graph-level, with halo exchange + convergence
reduction — paper's conditional MapReduce) repeats until converged.

The Godunov upwind update in 2-D (f=1, grid step h):

    a = min(phi_W, phi_E);  b = min(phi_S, phi_N)
    phi' = min(a, b) + h                      if |a - b| >= h
         = (a + b + sqrt(2 h^2 - (a-b)^2))/2  otherwise
    phi  = min(phi, phi')   (monotone descent; sources pinned)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tuning.tiles import register_tile_kernel

TILE_KERNEL = "eikonal"   # name in the autotuner's tile registry
DEFAULT_BLOCK = (8, 128)


def tile_candidates(shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """Feasible ``(bx, by)`` FIM tile shapes for an interior of
    ``(nx, ny)`` cells (the autotuner's search axis).  Bigger tiles
    amortize the frozen-halo inner sweeps over more cells (the paper's
    ghost-zone trade); candidates tile the interior exactly."""
    nx, ny = shape
    return tuple((bx, by)
                 for bx in (8, 16, 32, 64) if bx <= nx and nx % bx == 0
                 for by in (64, 128, 256) if by <= ny and ny % by == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def godunov_update(phi: jax.Array, mask: jax.Array, h: float) -> jax.Array:
    """One Jacobi sweep on a haloed tile; interior cells updated only.

    ``phi``: (m+2, n+2); ``mask``: (m, n) True where source (pinned).
    Returns the updated *interior* (m, n).
    """
    w = phi[:-2, 1:-1]
    e = phi[2:, 1:-1]
    s = phi[1:-1, :-2]
    n = phi[1:-1, 2:]
    c = phi[1:-1, 1:-1]
    a = jnp.minimum(w, e)
    b = jnp.minimum(s, n)
    lo = jnp.minimum(a, b)
    diff = jnp.abs(a - b)
    two = jnp.asarray(2.0, phi.dtype)
    quad = 0.5 * (a + b + jnp.sqrt(jnp.maximum(two * h * h - diff * diff, 0.0)))
    new = jnp.where(diff >= h, lo + h, quad)
    new = jnp.minimum(c, new)
    return jnp.where(mask, c, new)


def _fim_kernel(bx: int, by: int, inner: int, h: float,
                phi_ref, mask_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    tile = phi_ref[pl.ds(i * bx, bx + 2), pl.ds(j * by, by + 2)]
    mask = mask_ref[pl.ds(i * bx, bx), pl.ds(j * by, by)]

    def body(_, t):
        interior = godunov_update(t, mask, h)
        return t.at[1:-1, 1:-1].set(interior)

    tile = jax.lax.fori_loop(0, inner, body, tile)
    o_ref[...] = tile[1:-1, 1:-1]


def eikonal_fim_pallas(
    phi_haloed: jax.Array,
    source_mask: jax.Array,
    h: float,
    *,
    inner: int = 4,
    block: tuple[int, int] = (8, 128),
    interpret: bool = True,
) -> jax.Array:
    """``inner`` VMEM-staged FIM sweeps per tile.  ``phi_haloed`` is
    (nx+2, ny+2); ``source_mask`` is (nx, ny); returns (nx, ny)."""
    nx, ny = (s - 2 for s in phi_haloed.shape)
    bx, by = (min(block[0], nx), min(block[1], ny))
    assert nx % bx == 0 and ny % by == 0, (nx, ny, bx, by)
    grid = (nx // bx, ny // by)
    return pl.pallas_call(
        partial(_fim_kernel, bx, by, inner, h),
        out_shape=jax.ShapeDtypeStruct((nx, ny), phi_haloed.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bx, by), lambda i, j: (i, j)),
        interpret=interpret,
    )(phi_haloed, source_mask)
