"""Jitted public wrapper for the eikonal FIM sweep."""

from functools import partial

import jax

from .kernel import eikonal_fim_pallas
from .ref import eikonal_fim_ref


@partial(jax.jit,
         static_argnames=("h", "inner", "block", "use_pallas", "interpret"))
def eikonal_fim_sweep(phi_haloed, source_mask, h, *, inner: int = 4,
                      block=(8, 128), use_pallas: bool = True,
                      interpret: bool = True):
    if use_pallas:
        return eikonal_fim_pallas(phi_haloed, source_mask, h, inner=inner,
                                  block=block, interpret=interpret)
    return eikonal_fim_ref(phi_haloed, source_mask, h, inner=inner, block=block)
