"""Jitted public wrapper + graph builder for the eikonal FIM sweep."""

from functools import partial
from typing import Optional

import jax

from repro.core.graph import Graph, exclusive_padded_access
from repro.core.tensor import DistTensor
from repro.tuning.tiles import resolve_tile
from .kernel import DEFAULT_BLOCK, TILE_KERNEL, eikonal_fim_pallas
from .ref import eikonal_fim_ref


@partial(jax.jit,
         static_argnames=("h", "inner", "block", "use_pallas", "interpret"))
def _eikonal_fim_jit(phi_haloed, source_mask, h, *, inner: int, block,
                     use_pallas: bool, interpret: bool):
    if use_pallas:
        return eikonal_fim_pallas(phi_haloed, source_mask, h, inner=inner,
                                  block=block, interpret=interpret)
    return eikonal_fim_ref(phi_haloed, source_mask, h, inner=inner, block=block)


def eikonal_fim_sweep(phi_haloed, source_mask, h, *, inner: int = 4,
                      block=None, use_pallas: bool = True,
                      interpret: bool = True):
    """``inner`` VMEM-staged FIM Jacobi sweeps per tile over a haloed
    ``(nx+2, ny+2)`` level-set array (paper Table 5); returns the
    updated ``(nx, ny)`` interior.

    ``block=None`` resolves the ``(bx, by)`` tile through the
    autotuner's ambient tile scope (``repro.tuning.tiles``); an explicit
    ``block`` always wins, and outside any scope the kernel default
    applies."""
    interior = tuple(s - 2 for s in phi_haloed.shape)
    block = resolve_tile(TILE_KERNEL, block, DEFAULT_BLOCK, shape=interior)
    return _eikonal_fim_jit(phi_haloed, source_mask, h, inner=inner,
                            block=block, use_pallas=use_pallas,
                            interpret=interpret)


def make_eikonal_graph(
    phi: DistTensor,
    mask: DistTensor,
    h: float,
    *,
    inner: int = 1,
    overlap: bool = True,
    use_pallas: bool = False,
    block=None,
    interpret: bool = True,
    graph: Optional[Graph] = None,
) -> Graph:
    """One outer FIM sweep as a Ripple graph node: ``phi`` (halo ``(1, 1)``,
    possibly 2-D partitioned) updated in place, ``source_mask`` riding as
    an unpadded output-aligned arg (the overlapped lowering slices it per
    boundary strip).  Run the graph repeatedly — or wrap it in
    ``conditional`` with a residual reduction — for the paper's
    convergence loop.

    ``inner > 1`` runs frozen-halo sweeps per tile, which makes the
    result depend on the tile decomposition (paper's FIM ghost-zone
    trade) — so only the default ``inner=1`` (a pure radius-1 stencil,
    lowered without any tile grid so boundary strips of any thickness
    work) is decomposition-invariant and value-identical between the
    overlapped and synchronous lowerings; with ``inner > 1`` the caller
    must pick a ``block`` that tiles every strip extent.

    ``graph=`` appends the sweep node to an existing builder (see
    ``make_flux_difference_graph``) so independent kernel nodes can share
    one DAG-scheduled jit segment.
    """
    from .kernel import godunov_update

    def sweep(p_haloed, m):
        if inner == 1:
            return godunov_update(p_haloed, m, h)
        return eikonal_fim_sweep(p_haloed, m, h, inner=inner, block=block,
                                 use_pallas=use_pallas, interpret=interpret)

    g = graph if graph is not None else Graph(name="eikonal_sweep")
    g.split(sweep, exclusive_padded_access(phi), mask, writes=(0,),
            overlap=overlap)
    return g
