"""Jitted public wrapper + graph builder for the eikonal FIM sweep."""

from functools import partial
from typing import Optional

import jax

from repro.core.graph import Graph, exclusive_padded_access
from repro.core.tensor import DistTensor
from .kernel import eikonal_fim_pallas
from .ref import eikonal_fim_ref


@partial(jax.jit,
         static_argnames=("h", "inner", "block", "use_pallas", "interpret"))
def eikonal_fim_sweep(phi_haloed, source_mask, h, *, inner: int = 4,
                      block=(8, 128), use_pallas: bool = True,
                      interpret: bool = True):
    if use_pallas:
        return eikonal_fim_pallas(phi_haloed, source_mask, h, inner=inner,
                                  block=block, interpret=interpret)
    return eikonal_fim_ref(phi_haloed, source_mask, h, inner=inner, block=block)


def make_eikonal_graph(
    phi: DistTensor,
    mask: DistTensor,
    h: float,
    *,
    inner: int = 1,
    overlap: bool = True,
    use_pallas: bool = False,
    block=(8, 128),
    interpret: bool = True,
    graph: Optional[Graph] = None,
) -> Graph:
    """One outer FIM sweep as a Ripple graph node: ``phi`` (halo ``(1, 1)``,
    possibly 2-D partitioned) updated in place, ``source_mask`` riding as
    an unpadded output-aligned arg (the overlapped lowering slices it per
    boundary strip).  Run the graph repeatedly — or wrap it in
    ``conditional`` with a residual reduction — for the paper's
    convergence loop.

    ``inner > 1`` runs frozen-halo sweeps per tile, which makes the
    result depend on the tile decomposition (paper's FIM ghost-zone
    trade) — so only the default ``inner=1`` (a pure radius-1 stencil,
    lowered without any tile grid so boundary strips of any thickness
    work) is decomposition-invariant and value-identical between the
    overlapped and synchronous lowerings; with ``inner > 1`` the caller
    must pick a ``block`` that tiles every strip extent.

    ``graph=`` appends the sweep node to an existing builder (see
    ``make_flux_difference_graph``) so independent kernel nodes can share
    one DAG-scheduled jit segment.
    """
    from .kernel import godunov_update

    def sweep(p_haloed, m):
        if inner == 1:
            return godunov_update(p_haloed, m, h)
        return eikonal_fim_sweep(p_haloed, m, h, inner=inner, block=block,
                                 use_pallas=use_pallas, interpret=interpret)

    g = graph if graph is not None else Graph(name="eikonal_sweep")
    g.split(sweep, exclusive_padded_access(phi), mask, writes=(0,),
            overlap=overlap)
    return g
