"""Public attention ops: pallas flash for training/prefill, jnp fallback,
fused-AoS and split-SoA KV entry points."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import decode_ref, mha_ref


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "scale",
                                   "block_q", "block_k", "use_pallas",
                                   "interpret"))
def flash_attention(q, k, v=None, *, causal=True, window=None, q_offset=0,
                    scale=None, block_q=128, block_k=128,
                    use_pallas=True, interpret=True):
    """SOA path: (q, k, v); AOS path: (q, kv_fused, None) with kv
    (B, Hkv, S, 2, D)."""
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=interpret)
    if v is None:
        k, v = k[..., 0, :], k[..., 1, :]
    return mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset,
                   scale=scale)


attention_decode = decode_ref
