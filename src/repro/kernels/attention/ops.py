"""Public attention ops: pallas flash for training/prefill, jnp fallback,
fused-AoS and split-SoA KV entry points."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.tuning.tiles import resolve_tile
from .kernel import DEFAULT_BLOCKS, TILE_KERNEL, flash_attention_pallas
from .ref import decode_ref, mha_ref


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "scale",
                                   "block_q", "block_k", "use_pallas",
                                   "interpret"))
def _flash_attention_jit(q, k, v=None, *, causal, window, q_offset,
                         scale, block_q, block_k, use_pallas, interpret):
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=interpret)
    if v is None:
        k, v = k[..., 0, :], k[..., 1, :]
    return mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset,
                   scale=scale)


def flash_attention(q, k, v=None, *, causal=True, window=None, q_offset=0,
                    scale=None, block_q=None, block_k=None,
                    use_pallas=True, interpret=True):
    """Flash attention over layout-polymorphic KV storage.  SOA path:
    ``(q, k, v)``; AOS path: ``(q, kv_fused, None)`` with kv
    ``(B, Hkv, S, 2, D)``.

    ``block_q``/``block_k`` default to the autotuner's ambient tile
    scope (kernel ``"attention"``, one ``(block_q, block_k)`` config);
    explicit values always win, and outside any scope the kernel
    defaults apply."""
    explicit = ((block_q or DEFAULT_BLOCKS[0],
                 block_k or DEFAULT_BLOCKS[1])
                if block_q is not None or block_k is not None else None)
    block_q, block_k = resolve_tile(TILE_KERNEL, explicit, DEFAULT_BLOCKS,
                                    shape=(q.shape[2], k.shape[2]))
    return _flash_attention_jit(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        scale=scale, block_q=block_q, block_k=block_k,
        use_pallas=use_pallas, interpret=interpret)


attention_decode = decode_ref
