"""Pure-jnp oracle for flash attention (GQA / causal / window / decode)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k,v: (B, Hkv, Skv, D).  GQA via head repetition.

    ``kv_len`` (per-batch, int) masks cache positions >= len (decode)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    group = Hq // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    if kv_len is not None:
        valid = k_pos[None, :] < kv_len[:, None]  # (B, Skv)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_len: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-token decode: q (B, Hq, 1, D) against a cache of capacity S;
    positions >= kv_len are masked; window measured from kv_len - 1."""
    out = mha_ref(q, k_cache, v_cache, causal=False, window=None, scale=scale,
                  kv_len=kv_len if window is None else None)
    if window is not None:
        Skv = k_cache.shape[2]
        k_pos = jnp.arange(Skv)
        cur = kv_len - 1  # (B,)
        valid = (k_pos[None] <= cur[:, None]) & (
            k_pos[None] > cur[:, None] - window)
        B, Hq, _, D = q.shape
        scale_ = scale if scale is not None else 1.0 / math.sqrt(D)
        group = Hq // k_cache.shape[1]
        k = jnp.repeat(k_cache, group, axis=1) if group > 1 else k_cache
        v = jnp.repeat(v_cache, group, axis=1) if group > 1 else v_cache
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale_
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p,
                         v.astype(jnp.float32)).astype(q.dtype)
    return out
