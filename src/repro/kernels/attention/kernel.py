"""Flash attention Pallas kernel — GQA / causal / sliding-window, with
layout-polymorphic KV storage (Ripple C1 applied to the KV cache).

TPU mapping: q tiles of (block_q, head_dim) live in VMEM; K/V stay in
``ANY`` (HBM) and are streamed block-by-block with running-softmax
accumulation (online softmax).  block_q/block_k are the VMEM knobs and
should be multiples of 128 for MXU alignment.

KV layouts (DESIGN.md §5):
  * SOA — separate ``k`` and ``v`` arrays (B, Hkv, S, D): streaming reads
    are contiguous per tensor;
  * AOS — one fused array (B, Hkv, S, 2, D) interleaving k/v per position:
    one DMA fetches both, at the cost of a strided minor dim.

Causal masking supports a query-position offset so the same kernel serves
training (offset 0), chunked prefill (offset = chunk start) and scoring.
Sliding-window (``window``) implements gemma3 / recurrentgemma local
attention; the kv block loop is *clipped* to the causal/window range so
skipped blocks cost nothing (the paper's dependency-minimal scheduling,
at the kernel level).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tuning.tiles import register_tile_kernel

NEG_INF = -1e30

TILE_KERNEL = "attention"  # name in the autotuner's tile registry
DEFAULT_BLOCKS = (128, 128)


def tile_candidates(shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """Feasible ``(block_q, block_k)`` pairs for query/kv sequence
    lengths ``(sq, skv)`` (the autotuner's search axis): MXU-aligned
    multiples of 64 that tile both sequences exactly."""
    sq, skv = shape
    return tuple((bq, bk)
                 for bq in (64, 128, 256) if bq <= sq and sq % bq == 0
                 for bk in (64, 128, 256) if bk <= skv and skv % bk == 0)


register_tile_kernel(TILE_KERNEL, tile_candidates)


def _attn_kernel(
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    skv: int,
    q_offset: int,
    fused_kv: bool,
    q_ref,
    *kv_refs,
):
    o_ref = kv_refs[-1]
    kv_refs = kv_refs[:-1]
    b = pl.program_id(0)
    h = pl.program_id(1)
    qi = pl.program_id(2)
    group = q_ref.shape[1]  # == 1 block over q heads; see caller
    del group

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, D)
    d = q.shape[-1]
    n_kv_heads = kv_refs[0].shape[1]
    n_q_heads = pl.num_programs(1)
    hkv = h // max(1, n_q_heads // n_kv_heads)

    q_pos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)

    # clip the kv loop to the causal / window range (block skipping)
    if causal:
        hi_pos = q_offset + (qi + 1) * block_q  # exclusive
        hi = (hi_pos + block_k - 1) // block_k
        hi = min(hi, skv // block_k) if isinstance(hi, int) else jnp.minimum(
            hi, skv // block_k)
    else:
        hi = skv // block_k
    if window is not None:
        lo_pos = q_offset + qi * block_q - window
        lo = jnp.maximum(lo_pos // block_k, 0) if not isinstance(
            lo_pos, int) else max(lo_pos // block_k, 0)
    else:
        lo = 0

    def load_kv(kb):
        start = kb * block_k
        if fused_kv:
            kv = kv_refs[0][b, hkv, pl.ds(start, block_k)]  # (bk, 2, D)
            return kv[:, 0].astype(jnp.float32), kv[:, 1].astype(jnp.float32)
        k = kv_refs[0][b, hkv, pl.ds(start, block_k)].astype(jnp.float32)
        v = kv_refs[1][b, hkv, pl.ds(start, block_k)].astype(jnp.float32)
        return k, v

    def body(kb, carry):
        acc, m, l = carry
        k, v = load_kv(kb)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((q.shape[0], d), jnp.float32)
    m = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    l = jnp.zeros((q.shape[0],), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc, m, l))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array | None = None,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, Sq, D).  SOA: k,v each (B, Hkv, Skv, D).
    AOS: pass fused kv as ``k`` with shape (B, Hkv, Skv, 2, D), v=None."""
    fused = v is None
    B, Hq, Sq, D = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, skv)
    assert Sq % block_q == 0 and skv % block_k == 0
    grid = (B, Hq, Sq // block_q)

    kern = functools.partial(
        _attn_kernel, scale, causal, window, block_q, block_k, skv,
        q_offset, fused)
    in_specs = [pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
                pl.BlockSpec(memory_space=pl.ANY)]
    operands = [q, k]
    if not fused:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        operands.append(v)

    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        interpret=interpret,
    )(*operands)
