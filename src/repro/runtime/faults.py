"""Deterministic fault injection + the shared retry/backoff policy.

The runtime's fault tolerance used to be exercised only by tests raising
:class:`TransientError` from user step functions — none of the real
failure surfaces (in-flight host futures, dispatched device regions,
per-block halo transfers, the tuning cache, checkpoint writes) could be
made to fail on demand.  This module makes failures first-class and
*deterministic*:

* a :class:`FaultPlan` schedules named :class:`Fault`\\ s at specific
  ``(step, site)`` coordinates.  Sites are fixed strings compiled into
  the runtime layers (see :data:`SITES`): each layer calls
  :func:`trip` at its injection point, which is a no-op until a plan is
  installed (:func:`fault_scope`).  A fault either raises (transient or
  deterministic), sleeps (straggler/hang), or asks the site to corrupt
  its artifact (tuning-cache files) — always at the same coordinates
  for the same plan, so chaos tests are bitwise-reproducible;
* a :class:`RetryPolicy` centralizes transient-vs-deterministic error
  classification and exponential backoff with *deterministic* jitter
  (seeded splitmix, not ``random.random``), replacing the ad-hoc
  retry loops in ``Supervisor.run`` and ``Batcher.step``.

Everything here is stdlib-only (no jax) so every runtime layer — core
executor, halo exchange, tuning cache, checkpoint store — can import it
without cycles.

Example::

    plan = FaultPlan([Fault("executor.region", nth=3),
                      Fault("batcher.step", step=7, times=2)])
    with fault_scope(plan):
        run_the_workload()          # faults fire at those coordinates
    assert plan.fired  # [(site, detail, step, Fault), ...]
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Optional

__all__ = [
    "SITES", "TransientError", "InjectedFault", "InjectedDeterministicFault",
    "HostTimeoutError", "Fault", "FaultPlan", "fault_scope", "install",
    "current_plan", "trip", "RetryPolicy",
]

#: The named injection points compiled into the runtime layers.  A
#: :class:`Fault` whose ``site`` is not in this registry is rejected at
#: plan construction (catches typos before a chaos run silently no-ops).
SITES = {
    "executor.region":    "device-region dispatch (before the executable "
                          "call — caller state is never half-donated)",
    "executor.host":      "host-node callback invocation (sync inline or "
                          "on the ripple-host pool)",
    "executor.dispatch":  "host-pool submission from the event-driven "
                          "dispatcher",
    "halo.block":         "one scheduled halo-block transfer "
                          "(fires at trace/build time)",
    "batcher.step":       "decode step of the continuous batcher",
    "batcher.admit":      "admission scatter of one request into a slot",
    "supervisor.step":    "one supervised training step",
    "tuning.cache.load":  "tuning-cache file read (corrupt kind garbles "
                          "the file first)",
    "checkpoint.save":    "checkpoint directory write",
}


class TransientError(RuntimeError):
    """A retryable failure (preemption / link flap / injected chaos).

    Historically defined in ``runtime/supervisor.py`` (which still
    re-exports it); it lives here so stdlib-only layers can classify
    errors without importing the supervisor."""


class InjectedFault(TransientError):
    """A transient failure raised by :func:`trip` — subclasses
    :class:`TransientError` so every existing retry path recovers from
    injected chaos exactly as it would from a real preemption."""


class InjectedDeterministicFault(RuntimeError):
    """An injected NON-retryable failure: retry policies must re-raise it
    (the budget/classification tests use it)."""


class HostTimeoutError(TransientError):
    """A host callback (or the frontier drain waiting on it) exceeded the
    executor's ``host_timeout`` watchdog.  Transient: the callback's
    successors are cancelled, the executor remains usable, and a retry
    (possibly after ladder demotion) may succeed."""


def _splitmix(x: int) -> int:
    """Deterministic 64-bit mix (same generator the data pipeline uses)."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: *where* (``site`` + optional ``match`` on the
    site's detail string), *when* (``step`` — the site-reported step
    counter — or ``nth``, the 0-based visit index at that site, for
    layers that have no step notion), *what* (``kind``), and *how many*
    consecutive matching visits fire (``times``).

    Kinds:

    * ``"error"`` — raise :class:`InjectedFault` (transient) or, with
      ``transient=False``, :class:`InjectedDeterministicFault`;
    * ``"delay"`` — sleep ``delay_s`` seconds then continue (straggler /
      hung-callback injection; pair with the executor's ``host_timeout``
      watchdog to simulate a hang);
    * ``"corrupt"`` — no raise; :func:`trip` returns the fault and the
      site garbles its artifact (e.g. the tuning-cache JSON file).
    """

    site: str
    step: Optional[int] = None
    nth: Optional[int] = None
    kind: str = "error"
    transient: bool = True
    delay_s: float = 0.0
    match: Optional[str] = None
    times: int = 1

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} — "
                             f"known sites: {sorted(SITES)}")
        if self.kind not in ("error", "delay", "corrupt"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.step is None and self.nth is None:
            raise ValueError("a Fault needs a coordinate: step= or nth=")


class FaultPlan:
    """A deterministic schedule of :class:`Fault`\\ s plus the visit/fire
    log of one chaos run.

    Thread-safe (host callbacks trip from pool threads).  ``seed``
    derives deterministic per-fault delays when ``delay_s`` is a
    ``(lo, hi)`` range.  Introspection: :attr:`visits` counts trips per
    site, :attr:`fired` logs every fault that actually fired as
    ``(site, detail, step, fault)``, and :meth:`report` renders both."""

    def __init__(self, faults, seed: int = 0):
        self.faults = list(faults)
        self.seed = int(seed)
        self.visits: dict[str, int] = {}
        self.fired: list[tuple] = []
        self._shots: dict[int, int] = {}   # fault index -> times fired
        self._lock = threading.Lock()

    def _delay_of(self, idx: int, f: Fault) -> float:
        d = f.delay_s
        if isinstance(d, tuple):
            lo, hi = d
            u = _splitmix(self.seed * 0x10001 + idx) / float(1 << 64)
            return lo + (hi - lo) * u
        return float(d)

    def trip(self, site: str, detail: str = "",
             step: Optional[int] = None) -> Optional[Fault]:
        """One visit to ``site``: fire the first armed matching fault.

        Raises for ``error`` kinds, sleeps for ``delay`` kinds, returns
        the fault for ``corrupt`` kinds (the site acts on it), returns
        None when nothing fires."""
        with self._lock:
            n = self.visits.get(site, 0)
            self.visits[site] = n + 1
            hit = None
            for i, f in enumerate(self.faults):
                if f.site != site:
                    continue
                if self._shots.get(i, 0) >= f.times:
                    continue
                if f.match is not None and f.match not in detail:
                    continue
                if f.step is not None:
                    if step is None or step != f.step:
                        continue
                elif f.nth is not None and n < f.nth:
                    continue
                self._shots[i] = self._shots.get(i, 0) + 1
                self.fired.append((site, detail, step, f))
                hit = (i, f)
                break
        if hit is None:
            return None
        i, f = hit
        if f.kind == "delay":
            time.sleep(self._delay_of(i, f))
            return f
        if f.kind == "corrupt":
            return f
        where = f"{site}[{detail}]" if detail else site
        at = f"step {step}" if step is not None else f"visit {n}"
        if f.transient:
            err = InjectedFault(f"injected fault at {where} ({at})")
        else:
            err = InjectedDeterministicFault(
                f"injected deterministic fault at {where} ({at})")
        err.site = site  # lets the degradation ladder attribute failures
        raise err

    def exhausted(self) -> bool:
        """True when every scheduled fault has fired all its ``times``."""
        with self._lock:
            return all(self._shots.get(i, 0) >= f.times
                       for i, f in enumerate(self.faults))

    def report(self) -> str:
        """Human-readable visit counts and fired-fault log."""
        lines = ["fault plan:"]
        for site, n in sorted(self.visits.items()):
            lines.append(f"  visited {site} x{n}")
        for site, detail, step, f in self.fired:
            at = f"step {step}" if step is not None else f"nth={f.nth}"
            lines.append(f"  FIRED {f.kind} at {site}"
                         f"{f'[{detail}]' if detail else ''} ({at})")
        if not self.fired:
            lines.append("  (nothing fired)")
        return "\n".join(lines)


# the active plan is process-global (host callbacks trip from pool
# threads, so a thread-local would miss them)
_ACTIVE: Optional[FaultPlan] = None
_ACTIVE_LOCK = threading.Lock()


def install(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` as the process-wide active fault plan (None to
    uninstall).  Prefer the :func:`fault_scope` context manager."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = plan


def current_plan() -> Optional[FaultPlan]:
    """The active :class:`FaultPlan`, or None (no injection)."""
    return _ACTIVE


@contextmanager
def fault_scope(plan: FaultPlan):
    """Install ``plan`` for the duration of the block, always
    uninstalling on exit (even on an escaped injected fault)."""
    install(plan)
    try:
        yield plan
    finally:
        install(None)


def trip(site: str, detail: str = "", step: Optional[int] = None):
    """The injection point every runtime layer calls: a no-op (fast
    path: one global read) unless a plan is installed, else
    :meth:`FaultPlan.trip`."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.trip(site, detail, step)


# -- shared retry/backoff policy -----------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter + transient
    classification — the ONE retry policy the Supervisor, the Batcher,
    and the chaos harness share (each keeps its own recovery action:
    checkpoint restore, request-log replay, plain re-invoke).

    ``backoff(attempt)`` for attempt 1, 2, ... is
    ``min(max_delay, base_delay * multiplier**(attempt-1))`` scaled by
    ``1 + jitter * u`` where ``u in [0, 1)`` is a splitmix hash of
    ``(seed, attempt)`` — reproducible, unlike ``random.random``
    jitter, so chaos runs are bitwise-repeatable wall-clock included.
    ``sleep`` is injectable so tests can run backoff-free."""

    max_retries: int = 3
    base_delay: float = 0.02
    max_delay: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.25
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep
    transient_types: tuple = ()

    def is_transient(self, exc: BaseException) -> bool:
        """Retryable?  :class:`TransientError` (and its injected/watchdog
        subclasses) plus any ``transient_types`` extras; everything else
        — including :class:`InjectedDeterministicFault` — is
        deterministic and must re-raise."""
        if isinstance(exc, InjectedDeterministicFault):
            return False
        return isinstance(exc, TransientError) \
            or isinstance(exc, self.transient_types)

    def backoff(self, attempt: int) -> float:
        """The deterministic backoff delay before retry ``attempt``
        (1-based)."""
        base = min(self.max_delay,
                   self.base_delay * self.multiplier ** max(attempt - 1, 0))
        u = _splitmix(self.seed * 0x9E3779B1 + attempt) / float(1 << 64)
        return base * (1.0 + self.jitter * u)

    def backoff_sleep(self, attempt: int) -> float:
        """Sleep the backoff delay for ``attempt``; returns the delay."""
        d = self.backoff(attempt)
        if d > 0:
            self.sleep(d)
        return d

    def call(self, fn: Callable, *args,
             on_retry: Optional[Callable[[int, BaseException], None]] = None):
        """Run ``fn(*args)``, retrying transient failures up to
        ``max_retries`` times with backoff.  Deterministic failures and
        budget exhaustion re-raise the original exception."""
        attempt = 0
        while True:
            try:
                return fn(*args)
            except Exception as exc:
                if not self.is_transient(exc):
                    raise
                attempt += 1
                if attempt > self.max_retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt, exc)
                self.backoff_sleep(attempt)
