"""Fault-tolerant training + serving runtime."""

from .faults import (Fault, FaultPlan, HostTimeoutError,
                     InjectedDeterministicFault, InjectedFault, RetryPolicy,
                     fault_scope, trip)
from .supervisor import StepStats, Supervisor, TransientError

__all__ = ["Batcher", "Request", "Supervisor", "StepStats",
           "TransientError", "Fault", "FaultPlan", "HostTimeoutError",
           "InjectedFault", "InjectedDeterministicFault", "RetryPolicy",
           "fault_scope", "trip"]


def __getattr__(name):
    # Batcher pulls in launch.steps (graph builders); import lazily so
    # `import repro.runtime` stays cheap for training-only users.
    if name in ("Batcher", "Request"):
        from . import batcher
        return getattr(batcher, name)
    raise AttributeError(name)
