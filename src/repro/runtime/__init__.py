"""Fault-tolerant training runtime."""

from .supervisor import StepStats, Supervisor, TransientError

__all__ = ["Supervisor", "StepStats", "TransientError"]
