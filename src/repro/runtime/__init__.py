"""Fault-tolerant training + serving runtime."""

from .supervisor import StepStats, Supervisor, TransientError

__all__ = ["Batcher", "Request", "Supervisor", "StepStats",
           "TransientError"]


def __getattr__(name):
    # Batcher pulls in launch.steps (graph builders); import lazily so
    # `import repro.runtime` stays cheap for training-only users.
    if name in ("Batcher", "Request"):
        from . import batcher
        return getattr(batcher, name)
    raise AttributeError(name)
