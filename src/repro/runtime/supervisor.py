"""Host-side supervisor: the dynamic-scheduling layer of this system.

The paper's work-stealing scheduler solves *within-step* dynamic load
balance on a single heterogeneous node.  Under SPMD/XLA the within-step
schedule is static, so the dynamic layer moves up a level: across steps
and across failures (DESIGN.md §2/C5).  The supervisor owns:

* **checkpoint/restart** — periodic async checkpoints; on a step failure
  the state is restored from the last checkpoint and the step replayed
  (the data pipeline is a pure function of the step counter, so replay is
  exact).
* **retry with backoff** — transient errors (preemption, DCN flaps,
  injected chaos via :mod:`repro.runtime.faults`) restore from the last
  checkpoint and retry through the shared :class:`~repro.runtime.faults.
  RetryPolicy`: exponential backoff with deterministic jitter, at most
  ``max_failures`` total failures per run and ``max_retries_per_step``
  consecutive failures of one step (the per-step budget resets when a
  restore rewinds to an *earlier* step — replayed steps start fresh).
  Deterministic errors re-raise immediately.
* **straggler detection** — per-step wall-time EMA + variance; steps
  slower than ``mean + straggler_zscore * std`` are logged with their
  step index.  On a real fleet this feeds the re-scheduling policy
  (demote/evict the slow host); here it feeds metrics and tests.
* **elastic re-mesh** — ``resize(new_mesh, state_shardings)`` device_puts
  the live state onto a new mesh mid-run (fewer/more DP shards after a
  failure), using the checkpoint store's reshard-on-load path when
  topology changed too much for live transfer.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import CheckpointManager
# TransientError historically lived here; it moved to the stdlib-only
# faults module so every layer can classify errors — re-exported for
# backward compatibility.
from repro.runtime.faults import RetryPolicy, TransientError, trip

__all__ = ["TransientError", "StepStats", "Supervisor"]


@dataclass
class StepStats:
    """Welford tracker of per-step COMPLETION wall time.

    Under the event-driven executor (``async_regions=True``) a step
    function RETURNS at dispatch — the device is still computing and
    host callbacks are still in flight — so timing the call alone would
    report near-zero latency and blind the straggler detector.  The
    contract is therefore: ``dt`` passed to :meth:`update` must be
    measured after ``jax.block_until_ready`` on the step's outputs
    (completion), and the dispatch-return time may be passed separately
    as ``dispatch=`` — ``dispatch_mean``/``last_dispatch`` then expose
    how much of each step the runtime successfully overlapped
    (completion − dispatch ≈ the work hidden behind the host)."""

    count: int = 0
    mean: float = 0.0
    m2: float = 0.0
    last: float = 0.0
    last_dispatch: float = 0.0
    dispatch_mean: float = 0.0
    stragglers: list = field(default_factory=list)

    def update(self, dt: float, step: int, zscore: float = 3.0,
               dispatch: Optional[float] = None) -> bool:
        """Welford update with a completion time ``dt``; returns True if
        this step was a straggler.  ``dispatch`` (optional) is the
        dispatch-return time of the same step, tracked separately —
        stragglers are always judged on completion."""
        self.last = dt
        self.count += 1
        d = dt - self.mean
        self.mean += d / self.count
        self.m2 += d * (dt - self.mean)
        if dispatch is not None:
            self.last_dispatch = dispatch
            self.dispatch_mean += (dispatch - self.dispatch_mean) \
                / self.count
        if self.count >= 8:
            std = math.sqrt(self.m2 / (self.count - 1))
            if std > 0 and dt > self.mean + zscore * std:
                self.stragglers.append((step, dt))
                return True
        return False

    @property
    def std(self) -> float:
        return math.sqrt(self.m2 / max(self.count - 1, 1))

    @property
    def overlap_ms(self) -> float:
        """Mean milliseconds per step hidden behind asynchronous
        dispatch (completion mean − dispatch mean; 0 when dispatch was
        never reported)."""
        if self.dispatch_mean <= 0.0:
            return 0.0
        return max(self.mean - self.dispatch_mean, 0.0) * 1e3


@dataclass
class Supervisor:
    """Drives ``state = step_fn(state, batch)`` with fault tolerance.

    Transient failures restore from the last checkpoint and retry under
    the shared ``retry`` :class:`~repro.runtime.faults.RetryPolicy`
    (exponential backoff, deterministic jitter).  Recovery episodes are
    logged in :attr:`recoveries` as ``(failed_step, resumed_step,
    recovery_ms)`` — ``recovery_ms`` is the wall time from the failure
    until the failed step next completes successfully — which the chaos
    benchmark aggregates into steps-lost / p99-recovery stats."""

    step_fn: Callable[[Any, Any], Any]
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_failures: int = 10
    max_retries_per_step: int = 3
    straggler_zscore: float = 3.0
    state_shardings: Any = None
    log: Callable[[str], None] = print
    retry: RetryPolicy = field(
        default_factory=lambda: RetryPolicy(base_delay=0.01, max_delay=0.25))

    stats: StepStats = field(default_factory=StepStats)
    failures: int = 0
    recoveries: list = field(default_factory=list)

    def run(self, state: Any, batch_at: Callable[[int], Any],
            start_step: int, num_steps: int,
            on_step: Optional[Callable[[int, Any], None]] = None) -> Any:
        """Run steps [start_step, start_step + num_steps); returns state."""
        step = start_step
        end = start_step + num_steps
        retries = 0
        pending = []  # (failed_step, t_fail) awaiting successful replay
        while step < end:
            try:
                t0 = time.perf_counter()
                trip("supervisor.step", step=step)
                state = self.step_fn(state, batch_at(step))
                # the async executor returns at dispatch; straggler
                # detection must see COMPLETION time (StepStats contract)
                t_dispatch = time.perf_counter() - t0
                jax.block_until_ready(jax.tree.leaves(state))
                dt = time.perf_counter() - t0
                if self.stats.update(dt, step, self.straggler_zscore,
                                     dispatch=t_dispatch):
                    self.log(f"[supervisor] straggler step {step}: "
                             f"{dt*1e3:.1f}ms (mean {self.stats.mean*1e3:.1f})")
                retries = 0
                now = time.perf_counter()
                for failed, t_fail in [p for p in pending if p[0] <= step]:
                    self.recoveries.append(
                        (failed, step, (now - t_fail) * 1e3))
                    pending.remove((failed, t_fail))
                step += 1
                if on_step is not None:
                    on_step(step, state)
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, extra={"step": step})
            except Exception as e:
                if not self.retry.is_transient(e):
                    raise
                t_fail = time.perf_counter()
                self.failures += 1
                retries += 1
                if self.failures > self.max_failures:
                    raise RuntimeError(
                        f"exceeded max_failures={self.max_failures}") from e
                if retries > self.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {retries} times") from e
                self.log(f"[supervisor] transient failure at step {step} "
                         f"({e}); restoring last checkpoint "
                         f"(retry {retries}, backoff "
                         f"{self.retry.backoff(retries)*1e3:.0f}ms)")
                self.retry.backoff_sleep(retries)
                state, new_step = self._restore(state, step)
                if new_step < step:
                    # rewound to an earlier checkpoint: the replayed
                    # steps start with a fresh per-step retry budget
                    retries = 0
                pending.append((step, t_fail))
                step = new_step
        self.ckpt.wait()
        return state

    def _restore(self, state, failed_step: int):
        last = self.ckpt.latest_step()
        if last is None:  # nothing saved yet: restart from given state
            return state, failed_step
        _, restored, extra = self.ckpt.restore_latest(
            state, target_shardings=self.state_shardings)
        self.log(f"[supervisor] resumed from checkpoint step {last}")
        return restored, int(extra.get("step", last))

    # -- elastic scaling ---------------------------------------------------
    def resize(self, state: Any, new_shardings: Any) -> Any:
        """Re-place live state onto a new mesh (elastic re-mesh).  Arrays
        are pulled to host then device_put with the new shardings — the
        slow-but-always-correct path; same-topology fast paths can use
        jax.device_put directly on the live arrays."""
        host = jax.device_get(state)
        flat, treedef = jax.tree.flatten(host)
        sh = treedef.flatten_up_to(new_shardings)
        out = [jax.device_put(h, s) if s is not None else jax.device_put(h)
               for h, s in zip(flat, sh)]
        self.state_shardings = new_shardings
        return jax.tree.unflatten(treedef, out)
