"""Continuous-batching front end over the graph-native serving executors.

Requests stream into fixed batch slots of ONE decode executor (the
retrace-free ``Executor`` fast path): a free slot triggers a B=1 prefill
graph whose per-layer caches are scattered into the decode state along the
batch *storage* axis — whatever layout the decode plan chose (AoS/AoSoA
keep batch leading; SoA puts it behind the component axis) — while
``tokens``/``pos``/``active`` are per-slot vectors, so every slot sits at
its own sequence depth (the paper's polymorphic-layout argument applied to
the serving state itself).

Retirement is host-side: after each step the harvested token is matched
against ``eos_token`` / ``max_new_tokens`` / the cache capacity, and the
slot's ``active`` flag is dropped (inactive slots keep overwriting one
stale cache row, which is harmless — their logits are discarded and the
slot is re-prefilled at admission).

Fault tolerance reuses the Supervisor's machinery (runtime/supervisor.py):
``StepStats`` Welford straggler detection per decode step, and transient
retries through the shared :class:`repro.runtime.faults.RetryPolicy`
(exponential backoff, deterministic jitter) under
``max_failures``/``max_retries_per_step`` budgets — admission faults
(``batcher.admit``) are retried the same way as decode-step faults
(``batcher.step``).  Recovery needs no checkpoint store: greedy decode is a pure
function of the request log, so ``_recover()`` rebuilds the decode state
by re-prefilling every in-flight request with prompt + generated tokens —
the request log IS the checkpoint.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.executor import Executor
from repro.core.layout import Layout, relayout_data
from repro.launch.steps import (make_decode_graph, make_prefill_graph)
from repro.models import kvcache as kvc
from repro.models.config import ModelConfig

from .faults import RetryPolicy, trip as _fault_trip
from .supervisor import StepStats, TransientError

__all__ = ["Request", "Batcher"]


@dataclass
class Request:
    """One generation request moving queued -> active -> done/evicted."""

    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    status: str = "queued"
    slot: int = -1
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0
    token_times: list = field(default_factory=list)   # wall time per token

    @property
    def text_tokens(self) -> list:
        return list(self.generated)


def _batch_axis(layout: Layout) -> int:
    """Storage axis holding the batch space dim (batch is never the tiled
    AoSoA dim, so only SoA's leading component axis shifts it)."""
    return 1 if layout is Layout.SOA else 0


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _scatter_slot(dst, src, slot, axis):
    start = (jnp.int32(0),) * axis + (slot,) + \
        (jnp.int32(0),) * (dst.ndim - axis - 1)
    return lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


class Batcher:
    """Admit/evict requests into the fixed batch slots of one decode
    executor; every admitted slot advances one greedy token per ``step()``.

    The decode executable is traced at most once per process — a fresh
    ``Batcher`` in a worker that reuses the same ``cfg``/``params`` objects
    serves straight from the process-wide executable cache with zero new
    traces (asserted in CI via ``cache_stats()["trace_events"]``).

    Admission overlaps decode (``prefill_ahead=True``): the decode call
    returns at dispatch (the executor's event-driven runtime), and the
    queue head's prefills are dispatched BEHIND the in-flight step on
    the device stream before the batcher blocks for the step's tokens —
    so a new request's prefill costs wall time only where it exceeds
    the decode step it hid behind.  Token results are unchanged:
    prefill is a pure function of the prompt, and recovery replays
    (prompt + generated) never reuse a prepared prefill.
    ``StepStats`` records completion times (measured after
    ``block_until_ready``), with dispatch-return tracked separately.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch: int,
                 max_seq: int, mesh=None, eos_token: Optional[int] = None,
                 max_failures: int = 10, max_retries_per_step: int = 3,
                 straggler_zscore: float = 3.0,
                 prefill_ahead: bool = True,
                 executor_opts: Optional[dict] = None,
                 step_hook: Optional[Callable[[int], None]] = None,
                 retry: Optional[RetryPolicy] = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.mesh = mesh
        self.eos_token = eos_token
        self.max_failures = max_failures
        self.max_retries_per_step = max_retries_per_step
        self.straggler_zscore = straggler_zscore
        self.step_hook = step_hook
        # shared transient-retry policy (exponential backoff with
        # deterministic jitter); the recovery ACTION stays the batcher's
        # own request-log replay (_recover)
        self.retry = retry if retry is not None \
            else RetryPolicy(base_delay=0.01, max_delay=0.25)
        self.log = log
        self._exec_opts = dict(executor_opts or {})
        self.dg = make_decode_graph(cfg, params, batch=batch,
                                    max_seq=max_seq, mesh=mesh)
        self.executor = Executor(self.dg.graph, mesh=mesh,
                                 **self._exec_opts)
        self.state = self.executor.init_state()
        self.slots: list = [None] * batch
        self.queue: deque = deque()
        self.retired: list = []
        self.stats = StepStats()
        self.steps = 0
        self.failures = 0
        self._next_rid = 0
        self._prefill: dict = {}          # prompt_len -> (PrefillGraph, Executor)
        # admit-while-in-flight: prefills computed behind a dispatched
        # decode step, keyed by request id, consumed at admission
        self.prefill_ahead = bool(prefill_ahead)
        self._prepared: dict = {}         # rid -> (PrefillGraph, Executor, state)

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 64) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_seq:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        req = Request(self._next_rid, prompt, max_new_tokens,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def evict(self, rid: int) -> bool:
        """Drop a request wherever it is (queue or live slot)."""
        for req in list(self.queue):
            if req.rid == rid:
                self.queue.remove(req)
                self._prepared.pop(rid, None)
                req.status = "evicted"
                self.retired.append(req)
                return True
        for slot, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                self._retire(slot, status="evicted")
                return True
        return False

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.slots)

    def pending(self) -> int:
        return len(self.queue)

    # -- admission ---------------------------------------------------------
    def _prefill_for(self, prompt_len: int):
        if prompt_len not in self._prefill:
            pg = make_prefill_graph(self.cfg, self.params,
                                    prompt_len=prompt_len,
                                    max_seq=self.max_seq, mesh=self.mesh)
            self._prefill[prompt_len] = (pg, Executor(pg.graph,
                                                      mesh=self.mesh))
        return self._prefill[prompt_len]

    def _admit_ready(self) -> None:
        for slot in range(self.batch):
            if not self.queue:
                return
            if self.slots[slot] is None:
                # peek-admit-pop: a failure mid-admission (faults.py's
                # "batcher.admit" site) leaves the request at the queue
                # head, so the retry re-admits instead of losing it
                self._admit(self.queue[0], slot)
                self.queue.popleft()

    def _prefill_state(self, prompt: np.ndarray):
        pg, exp = self._prefill_for(len(prompt))
        pst = exp.init_state(prompt=jnp.asarray(prompt, jnp.int32)[None])
        return pg, exp, exp(pst)

    def _prefill_ahead(self) -> None:
        """Compute prefills for the queue head while a decode step is in
        flight (the decode dispatch already returned; these prefill
        programs queue up behind it on the device stream, so admission
        work overlaps the step instead of serializing after it).
        Results are consumed by :meth:`_admit`; recovery replays
        (``req.generated`` non-empty) never use them — their prefill
        must include the generated tokens."""
        for req in list(self.queue)[:self.batch]:
            if req.generated or req.rid in self._prepared:
                continue
            self._prepared[req.rid] = self._prefill_state(req.prompt)

    def _admit(self, req: Request, slot: int) -> None:
        # trips BEFORE any state mutation: a failed admission is fully
        # retryable (the request is still queued / still in the replay
        # set, and no slot tensor has been scattered yet)
        _fault_trip("batcher.admit", detail=f"rid{req.rid}",
                    step=self.steps)
        prompt = np.concatenate([req.prompt,
                                 np.asarray(req.generated[:-1], np.int32)])
        prepared = self._prepared.pop(req.rid, None)
        if prepared is not None and not req.generated:
            pg, exp, pst = prepared
        else:
            pg, exp, pst = self._prefill_state(prompt)
        if req.generated:
            # recovery replay: the last generated token is the next input
            first = int(req.generated[-1])
        else:
            first = int(np.asarray(pst["first"])[0])
        for cslot in pg.slots:
            if cslot.kind in ("A", "L"):
                name = cslot.tensors[0].name
                src = pst[name]
                src_lay = exp.plan.initial[name]
                dst_lay = self.executor.plan.initial[name]
                if src_lay is not dst_lay:
                    src = relayout_data(src, kvc.kv_spec(self.cfg.head_dim),
                                        src_lay, dst_lay)
                self.state[name] = _scatter_slot(
                    self.state[name], src, jnp.int32(slot),
                    _batch_axis(dst_lay))
            else:
                for t in cslot.tensors:
                    self.state[t.name] = _scatter_slot(
                        self.state[t.name], pst[t.name], jnp.int32(slot), 0)
        pos = len(prompt)
        self.state["tokens"] = self.state["tokens"].at[slot].set(first)
        self.state["pos"] = self.state["pos"].at[slot].set(pos)
        self.state["active"] = self.state["active"].at[slot].set(True)
        req.slot = slot
        req.status = "active"
        now = time.perf_counter()
        if not req.t_admit:
            req.t_admit = now
        self.slots[slot] = req
        if not req.generated:
            req.generated.append(first)
            req.token_times.append(now)
            self._maybe_finish(slot, first, pos)

    def _retire(self, slot: int, status: str = "done") -> None:
        req = self.slots[slot]
        if req is None:
            return
        req.status = status
        req.t_done = time.perf_counter()
        req.slot = -1
        self.slots[slot] = None
        self.retired.append(req)
        self.state["active"] = self.state["active"].at[slot].set(False)

    def _maybe_finish(self, slot: int, token: int, pos: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        if (self.eos_token is not None and token == self.eos_token) \
                or len(req.generated) >= req.max_new_tokens \
                or pos + 1 >= self.max_seq:
            self._retire(slot)

    # -- decode steps ------------------------------------------------------
    def step(self) -> bool:
        """Admit what fits, advance every active slot one token.  Returns
        False when nothing was active (drained).

        Admission runs INSIDE the retried block, so a failure during the
        admission scatter (faults.py's "batcher.admit" site) recovers
        exactly like a failed decode step: backoff per the shared
        :class:`~repro.runtime.faults.RetryPolicy`, then request-log
        replay (``_recover``) — and since recovery itself re-admits,
        faults during recovery consume the same retry budget instead of
        escaping."""
        retries = 0
        need_recover = False
        while True:
            try:
                if need_recover:
                    need_recover = False
                    self._recover()
                self._admit_ready()
                if self.active_count == 0:
                    return False
                t0 = time.perf_counter()
                if self.step_hook is not None:
                    self.step_hook(self.steps)
                _fault_trip("batcher.step", step=self.steps)
                self.state = self.executor(self.state)
                t_dispatch = time.perf_counter() - t0
                # decode step in flight (async dispatch): admit-ahead —
                # prefill queued requests behind it on the device stream
                if self.prefill_ahead:
                    self._prefill_ahead()
                # StepStats contract: dt is COMPLETION time, measured
                # after block_until_ready (the async executor's call
                # above returned at dispatch)
                jax.block_until_ready(self.state["tokens"])
                dt = time.perf_counter() - t0
                if self.stats.update(dt, self.steps,
                                     self.straggler_zscore,
                                     dispatch=t_dispatch):
                    self.log(f"[batcher] straggler step {self.steps}: "
                             f"{dt * 1e3:.1f}ms "
                             f"(mean {self.stats.mean * 1e3:.1f})")
                break
            except Exception as e:
                if not self.retry.is_transient(e):
                    raise
                self.failures += 1
                retries += 1
                if self.failures > self.max_failures:
                    raise RuntimeError(
                        f"exceeded max_failures={self.max_failures}") from e
                if retries > self.max_retries_per_step:
                    raise RuntimeError(
                        f"decode step failed {retries} times") from e
                self.log(f"[batcher] transient failure ({e}); replaying "
                         f"{self.active_count} in-flight request(s) "
                         f"(retry {retries}, backoff "
                         f"{self.retry.backoff(retries) * 1e3:.0f}ms)")
                self.retry.backoff_sleep(retries)
                need_recover = True
        self.steps += 1
        self._harvest()
        return True

    def _harvest(self) -> None:
        tokens = np.asarray(self.state["tokens"])
        pos = np.asarray(self.state["pos"])
        now = time.perf_counter()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(tokens[slot])
            req.generated.append(tok)
            req.token_times.append(now)
            self._maybe_finish(slot, tok, int(pos[slot]))

    def _recover(self) -> None:
        """Rebuild the decode state from the request log (greedy decode is
        deterministic, so re-prefilling prompt + generated tokens restores
        the exact cache; the last generated token becomes the next input).

        Requests stay in ``self.slots`` throughout: recovery itself can
        take a fault (an injected or real failure during a replay
        prefill), and the retry calls ``_recover`` again — it must still
        see EVERY live request.  ``init_state()`` resets the device state
        wholesale, so a partially re-admitted previous attempt leaves no
        residue."""
        live = [(slot, req) for slot, req in enumerate(self.slots)
                if req is not None]
        self.state = self.executor.init_state()
        for slot, req in live:
            self._admit(req, slot)

    def run(self, max_steps: Optional[int] = None) -> list:
        """Drain: admit + step until every request retired (or the step
        budget runs out).  Returns the retired request list."""
        while self.queue or self.active_count:
            if max_steps is not None and self.steps >= max_steps:
                break
            if not self.step():
                if not self.queue:
                    break
        return self.retired

    # -- introspection -----------------------------------------------------
    def cache_stats(self) -> dict:
        out = {"decode": self.executor.cache_stats()}
        out["prefill"] = {S: ex.cache_stats()
                          for S, (_, ex) in sorted(self._prefill.items())}
        return out
