"""Version-guarded JAX compatibility shims.

The codebase is written against the modern JAX surface:

* ``jax.make_mesh(shape, names, axis_types=...)``  (``axis_types`` and
  ``jax.sharding.AxisType`` appeared after 0.4.x);
* ``jax.shard_map(..., check_vma=...)``  (previously
  ``jax.experimental.shard_map.shard_map(..., check_rep=...)``).

On older installs this module provides equivalents and — because tests
and user scripts also use the modern spellings directly — installs them
onto the ``jax`` namespace when absent.  Every patch is additive and
version-guarded: on a modern JAX this module is a no-op.

``install()`` runs once on ``import repro``.
"""

from __future__ import annotations

import enum
import inspect

import jax

__all__ = ["install", "make_mesh_auto", "make_mesh_compat",
           "shard_map_compat"]


class _AxisTypeShim(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (Auto/Explicit/Manual)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


_native_make_mesh = getattr(jax, "make_mesh", None)


def _make_mesh_accepts_axis_types() -> bool:
    if _native_make_mesh is None:
        return False
    try:
        return "axis_types" in inspect.signature(
            _native_make_mesh).parameters
    except (TypeError, ValueError):
        return False


def make_mesh_compat(shape, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` that tolerates installs without ``axis_types`` —
    or without ``jax.make_mesh`` at all (falls back to a device-grid
    ``Mesh``)."""
    shape = tuple(shape)
    axis_names = tuple(axis_names)
    if _native_make_mesh is None:
        import numpy as np

        n = int(np.prod(shape)) if shape else 1
        devices = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devices, axis_names)
    if axis_types is not None and _make_mesh_accepts_axis_types():
        return _native_make_mesh(shape, axis_names, axis_types=axis_types,
                                 **kw)
    return _native_make_mesh(shape, axis_names, **kw)


def make_mesh_auto(shape, axis_names):
    """Mesh with Auto axis types where the install supports them — the
    single version-guard used by ``repro.core.executor.make_mesh`` and
    ``repro.launch.mesh``."""
    shape, axis_names = tuple(shape), tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    axis_types = (None if axis_type is None
                  else (axis_type.Auto,) * len(axis_names))
    return make_mesh_compat(shape, axis_names, axis_types=axis_types)


def _wrap_legacy_shard_map(fn):
    params = inspect.signature(fn).parameters

    def shard_map(f=None, /, **kw):
        # check_vma -> check_rep is the one known-safe rename; any other
        # kwarg the legacy signature lacks must fail loudly, not silently
        # change program semantics
        if "check_vma" in kw and "check_vma" not in params:
            kw["check_rep"] = kw.pop("check_vma")
        unknown = [k for k in kw if k not in params]
        if unknown:
            raise TypeError(
                f"shard_map compat shim: kwargs {unknown} are not "
                f"supported by the installed JAX's shard_map")
        if f is None:
            return lambda g: shard_map(g, **kw)
        return fn(f, **kw)

    return shard_map


# captured before install() can patch the namespace, so repeated calls
# never re-wrap an already-shimmed function
_native_shard_map = getattr(jax, "shard_map", None)
_shard_map_cache = None


def shard_map_compat():
    """Return a ``shard_map`` callable accepting the modern kwarg set
    (idempotent: always derived from the pre-patch native function)."""
    global _shard_map_cache
    if _shard_map_cache is not None:
        return _shard_map_cache
    fn = _native_shard_map
    if fn is not None and "check_vma" in inspect.signature(fn).parameters:
        _shard_map_cache = fn
        return fn
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    _shard_map_cache = _wrap_legacy_shard_map(fn)
    return _shard_map_cache


_installed = False


def install() -> None:
    """Idempotently patch missing modern APIs onto ``jax``."""
    global _installed
    if _installed:
        return
    _installed = True
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypeShim
    if not _make_mesh_accepts_axis_types():
        jax.make_mesh = make_mesh_compat
    if _native_shard_map is None or "check_vma" not in inspect.signature(
            _native_shard_map).parameters:
        jax.shard_map = shard_map_compat()
