"""Mixture-of-Experts block: top-k routing with capacity-bucketed dispatch.

TPU-native dispatch (DESIGN.md §5): tokens are sorted by expert id and
scattered into a dense ``(E, C, d)`` buffer, expert FFNs run as one batched
einsum over the expert axis (MXU-friendly, experts sharded over "model" =
expert parallelism), and outputs are gathered back per (token, k) with the
router weights.  Tokens beyond an expert's capacity are dropped (standard
capacity-factor semantics); the router uses softmax-then-topk.

This is the paper's C1 at the MoE level: the expert weights are a
record-of-experts stacked on a leading axis (the SoA choice — one array,
expert-major) rather than a Python list of per-expert params (AoS), which
is what makes single-einsum compute and single-spec sharding possible.

``arctic`` style adds a *dense residual* FFN in parallel with the routed
experts (Snowflake Arctic's dense-MoE hybrid).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .common import ParamTree


def init_moe(pt: ParamTree, *, d_model: int, d_ff: int, n_experts: int,
             name: str = "moe") -> None:
    sub = pt.child()
    sub.dense("router", (d_model, n_experts), ("embed", None),
              fan_in=d_model)
    sub.dense("wi", (n_experts, d_model, 2, d_ff),
              ("experts", "embed", None, "expert_ff"), fan_in=d_model)
    sub.dense("wo", (n_experts, d_ff, d_model),
              ("experts", "expert_ff", "embed"), fan_in=d_ff)
    pt.sub(name, sub)


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = int(capacity_factor * top_k * n_tokens / n_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to sublane multiple


def _dispatch_slots(gate_idx: jax.Array, E: int, C: int):
    """Sort (token, k) pairs by expert and bucket to capacity C.

    Returns (slot (T*K,) int32 into a flat (E*C) buffer with E*C meaning
    'dropped', keep mask, and the sort order)."""
    TK = gate_idx.size
    flat_e = gate_idx.reshape(-1)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos_in_e = jnp.arange(TK, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    return slot, keep, order


def moe_block(params, x2d: jax.Array, *, top_k: int = 2,
              capacity_factor: float = 1.25, dropless: bool = False,
              dtype=None) -> tuple[jax.Array, jax.Array]:
    """x2d (T, d) -> (out (T, d), aux_loss ()).

    ``dropless=True`` sizes every expert's bucket to T*top_k (zero drops,
    exact routing) — used for decode steps where T = batch is small; the
    capacity-factor path is the training/prefill form.

    Returns the load-balancing auxiliary loss (Switch-style: E * sum_e
    f_e * p_e with f = token fraction, p = mean router prob)."""
    T, d = x2d.shape
    E = params["router"].shape[-1]
    C = T * top_k if dropless else moe_capacity(T, E, top_k, capacity_factor)
    cdt = dtype or x2d.dtype

    logits = (x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_w, gate_idx = lax.top_k(probs, top_k)                 # (T, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # -- aux loss ----------------------------------------------------------
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # (T, K, E)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    aux = E * jnp.sum(me * ce) / top_k

    # -- dispatch: sort (token, k) pairs by expert, bucket to capacity ------
    slot, keep, order = _dispatch_slots(gate_idx, E, C)
    src_tok = order // top_k                                   # token of pair

    buf = jnp.zeros((E * C, d), cdt)
    buf = buf.at[slot].set(x2d[src_tok].astype(cdt), mode="drop")
    buf = buf.reshape(E, C, d)

    # -- expert compute (batched over the expert axis; E sharded -> EP) -----
    wi = params["wi"].astype(cdt)                              # (E, d, 2, f)
    wo = params["wo"].astype(cdt)                              # (E, f, d)
    h = jnp.einsum("ecd,edtf->ectf", buf, wi)
    h = jax.nn.silu(h[:, :, 0]) * h[:, :, 1]
    eo = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E * C, d)

    # -- combine: gather each pair's slot output, weight, sum over k --------
    pair_out = jnp.where(keep[:, None], eo.at[slot].get(mode="fill",
                                                        fill_value=0.0), 0.0)
    # un-sort back to (T, K) order
    unsort = jnp.zeros_like(order).at[order].set(
        jnp.arange(T * top_k, dtype=order.dtype))
    pair_out = pair_out[unsort].reshape(T, top_k, d)
    out = jnp.sum(pair_out * gate_w[..., None].astype(cdt), axis=1)
    return out.astype(x2d.dtype), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# GShard-style expert parallelism: explicit all-to-all under shard_map
# ---------------------------------------------------------------------------

def make_moe_a2a(mesh, *, dp_axes, top_k: int, capacity_factor: float,
                 residual_tp: bool):
    """Production MoE block: EP over the data axes, expert-TP (d_ff) over
    "model", with the GShard all-to-all dispatch made explicit.

    Layout (DESIGN.md §5):
      wi (E, d, 2, f): E sharded over dp, f over model
      wo (E, f, d):    E over dp,        f over model

    Per data shard: local top-k -> local capacity buckets (E, C_l, d) ->
    ``all_to_all`` over dp (split E, concat C) -> local expert GEMMs with
    the model-sharded f (partial sums over f) -> reverse all_to_all ->
    local combine to (T_l, d) partials -> ONE psum over "model"
    (reduce-scattered onto the d_model-sharded residual when
    ``residual_tp``, halving the payload — Megatron-style: the block's
    only big collective is on token activations, not capacity buffers).

    This is the paper's coarse-grained thesis in LM form: making the data
    movement explicit in the program (instead of letting the partitioner
    infer a gather) is what keeps the collective minimal.
    """
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in dp_axes if mesh.shape.get(a, 1) > 1)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    # all_to_all over one logical axis: use the innermost dp axis for the
    # EP exchange; outer dp axes (pod) replicate experts (pure DP).
    ep_axis = dp[-1] if dp else None
    ep = mesh.shape[ep_axis] if ep_axis else 1

    def fn(params, x2d):
        T, d = x2d.shape
        E = params["wi"].shape[0]

        def local(router, wi, wo, x_l):
            T_l = x_l.shape[0]
            C_l = moe_capacity(T_l, E, top_k, capacity_factor)
            logits = x_l.astype(jnp.float32) @ router.astype(jnp.float32)
            probs = jax.nn.softmax(logits, axis=-1)
            gate_w, gate_idx = lax.top_k(probs, top_k)
            gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

            me = jnp.mean(probs, axis=0)
            oh = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
            ce = jnp.mean(jnp.sum(oh, axis=1), axis=0)
            aux = E * jnp.sum(me * ce) / top_k

            slot, keep, order = _dispatch_slots(gate_idx, E, C_l)
            src_tok = order // top_k
            buf = jnp.zeros((E * C_l, d), x_l.dtype)
            buf = buf.at[slot].set(x_l[src_tok], mode="drop")
            buf = buf.reshape(E, C_l, d)

            if ep_axis is not None:
                # (E, C_l, d) -> (E/ep, C_l * ep, d): each rank keeps its
                # own experts' tokens from every rank
                buf = lax.all_to_all(buf, ep_axis, split_axis=0,
                                     concat_axis=1, tiled=True)
            h = jnp.einsum("ecd,edtf->ectf", buf, wi.astype(buf.dtype))
            h = jax.nn.silu(h[:, :, 0]) * h[:, :, 1]
            out = jnp.einsum("ecf,efd->ecd", h, wo.astype(h.dtype))
            if ep_axis is not None:
                out = lax.all_to_all(out, ep_axis, split_axis=1,
                                     concat_axis=0, tiled=True)
            out = out.reshape(E * C_l, d)
            pair = jnp.where(keep[:, None],
                             out.at[slot].get(mode="fill", fill_value=0.0),
                             0.0)
            unsort = jnp.zeros_like(order).at[order].set(
                jnp.arange(order.size, dtype=order.dtype))
            pair = pair[unsort].reshape(T_l, top_k, d)
            y = jnp.sum(pair * gate_w[..., None].astype(pair.dtype), axis=1)
            # the block's one big collective: partial over f-shards
            if tp > 1:
                if residual_tp:
                    y = lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
                else:
                    y = lax.psum(y, "model")
            for a in dp:
                aux = lax.pmean(aux, a)
            if tp > 1:
                aux = lax.pmean(aux, "model")
            return y, aux

        out_d = P(dp if dp else None, "model" if (residual_tp and tp > 1)
                  else None)
        y, aux = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(None, None),
                      P(ep_axis, None, None, "model"),
                      P(ep_axis, "model", None),
                      P(dp if dp else None, None)),
            out_specs=(out_d, P()),
            check_vma=False,
        )(params["router"], params["wi"], params["wo"], x2d)
        return y, aux

    return fn
