"""Model substrate: param trees with logical sharding axes, norms, RoPE.

Parameters are plain dict pytrees.  Alongside every param tree we carry a
parallel *spec tree* whose leaves are tuples of logical axis names
(e.g. ``("layer", "embed", "q_heads", "head_dim")``).  A per-config rules
table maps logical axes -> mesh axes, giving each arch its TP/EP layout
without touching layer code (same philosophy as the paper's polymorphic
layout: the storage decision is a single declarative knob, the compute is
written once).

Logical axes used across the stack:
  layer / group      scan axis over (groups of) layers           -> never sharded
  embed              d_model                                      -> never sharded
  q_heads            attention query heads (padded to TP)         -> "model"
  kv_heads           attention kv heads                           -> "model" iff divisible
  head_dim           per-head dim                                 -> never sharded
  ff                 MLP hidden                                   -> "model"
  vocab              (padded) vocabulary                          -> "model"
  experts            MoE experts                                  -> "model"
  ssm_heads          Mamba2 value heads (padded)                  -> "model"
  ssm_state / conv   SSD state dim / conv kernel                  -> never sharded
  rnn                RG-LRU recurrent width                       -> "model"
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# logical axis -> mesh axis resolution
# ---------------------------------------------------------------------------

DEFAULT_RULES: dict[str, Optional[str]] = {
    "layer": None,
    "group": None,
    "embed": None,
    "head_dim": None,
    "q_heads": "model",
    "kv_heads": "model",     # dropped to None by configs when not divisible
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,   # production rules move experts->data, expert_ff->model
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "rnn": "model",
}


def logical_to_pspec(axes: Sequence[Optional[str]],
                     rules: Mapping[str, Optional[str]]) -> P:
    return P(*[None if a is None else rules.get(a, None) for a in axes])


def spec_tree_to_pspecs(spec_tree, rules):
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def shardings_for(spec_tree, rules, mesh: Mesh):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        spec_tree_to_pspecs(spec_tree, rules),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# param declaration helpers
# ---------------------------------------------------------------------------

class ParamTree:
    """Accumulates (params, logical-spec) pairs with a shared RNG stream."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.specs: dict[str, Any] = {}

    def _next(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, name: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], *, fan_in: Optional[int] = None,
              scale: float = 1.0) -> None:
        """Truncated-normal init with 1/sqrt(fan_in) scaling."""
        shape = tuple(shape)
        if fan_in is None:
            fan_in = shape[0] if shape else 1
        std = scale / math.sqrt(max(fan_in, 1))
        self.params[name] = (
            jax.random.truncated_normal(self._next(), -2.0, 2.0, shape,
                                        jnp.float32) * std).astype(self.dtype)
        self.specs[name] = tuple(axes)

    def const(self, name: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], value: float = 0.0) -> None:
        self.params[name] = jnp.full(tuple(shape), value, dtype=self.dtype)
        self.specs[name] = tuple(axes)

    def custom(self, name: str, value: jax.Array,
               axes: Sequence[Optional[str]]) -> None:
        self.params[name] = value.astype(self.dtype)
        self.specs[name] = tuple(axes)

    def sub(self, name: str, other: "ParamTree") -> None:
        self.params[name] = other.params
        self.specs[name] = other.specs

    def child(self) -> "ParamTree":
        return ParamTree(self._next(), self.dtype)


def stack_layers(trees: Sequence[tuple[dict, dict]]) -> tuple[dict, dict]:
    """Stack per-layer (params, specs) into scan-ready stacked params with a
    leading 'layer' logical axis."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                          *[t[0] for t in trees])
    specs = jax.tree.map(
        lambda axes: ("layer", *axes), trees[0][1],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
    return params, specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm; ``plus_one`` uses the gemma convention (scale = 1 + w)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (x * w).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (standard "half rotation", interleaved, and partial/2d variants)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, rot_dim: int, *,
                 base: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables of shape (*positions.shape, rot_dim // 2), f32."""
    inv = 1.0 / (base ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                          / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, *,
               mode: str = "half") -> jax.Array:
    """Rotate the leading ``2 * cos.shape[-1]`` dims of the head axis.

    x: (..., S, H, D) with cos/sin (..., S, R/2) broadcast over H.
    mode 'half'        : (x1, x2) = split-in-half pairing (llama/neox)
    mode 'interleaved' : (x[0::2], x[1::2]) pairing (GPT-J / chatglm 2d rope,
                         which additionally rotates only D/2 of the head dim —
                         achieved by passing rot_dim = D // 2).
    """
    r2 = cos.shape[-1]
    rot, rest = x[..., : 2 * r2], x[..., 2 * r2:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    if mode == "half":
        x1, x2 = rot[..., :r2], rot[..., r2:]
        o1 = x1 * c - x2 * s
        o2 = x2 * c + x1 * s
        out = jnp.concatenate([o1, o2], axis=-1)
    elif mode == "interleaved":
        x1, x2 = rot[..., 0::2], rot[..., 1::2]
        o1 = x1 * c - x2 * s
        o2 = x2 * c + x1 * s
        out = jnp.stack([o1, o2], axis=-1).reshape(rot.shape)
    else:
        raise ValueError(f"unknown rope mode {mode!r}")
    return jnp.concatenate([out.astype(x.dtype), rest], axis=-1) \
        if rest.shape[-1] else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op outside jit-with-mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
