"""Architecture configuration: one frozen dataclass drives model init,
forward, serving, sharding, and the dry-run for every assigned arch.

Layer kinds (``pattern``, cycled over the depth):
  "A" global causal attention      "L" local (sliding-window) attention
  "M" Mamba2 SSD                   "R" RG-LRU recurrent block
Encoder-decoder archs set ``enc_layers > 0`` (encoder is bidirectional
"A" layers); the decoder follows ``pattern``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp

from repro.core.layout import Layout

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention
    rope_base: float = 10000.0
    rope_base_local: Optional[float] = None  # gemma3: local layers differ
    rope_mode: str = "half"          # half | interleaved
    rope_fraction: float = 1.0       # chatglm3: 0.5 (2d rope)
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    window: Optional[int] = None     # sliding window for "L" layers
    pattern: tuple[str, ...] = ("A",)

    # norms / mlp
    norm_kind: str = "rms"           # rms | layernorm (layernorm adds biases)
    norm_eps: float = 1e-6
    norm_plus_one: bool = False      # gemma family
    sandwich_norm: bool = False      # gemma3 post-norms
    mlp_kind: str = "swiglu"         # swiglu | geglu | mlp
    act: str = "silu"

    # embeddings / head
    tie_embeddings: bool = False
    scale_embed: bool = False        # gemma: embed * sqrt(d)
    logit_softcap: Optional[float] = None

    # moe
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    d_conv: int = 4

    # rg-lru
    lru_width: int = 0
    rnn_blocks: int = 16

    # enc-dec
    enc_layers: int = 0

    # modality frontend stub (vlm/audio): embeddings of this dim arrive
    # precomputed from input_specs; 0 = token-only
    frontend_dim: int = 0
    frontend_tokens: int = 0         # positions occupied by frontend embeds

    # numerics / perf knobs (hillclimb surface)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_impl: str = "chunked"       # dense | chunked | tri
    q_chunk: int = 512
    k_chunk: int = 512
    ssd_chunk: int = 128
    kv_layout: Layout = Layout.AOS
    kv_order: str = "bsh"            # cache space order: bsh | bhs (C1 knob)
    remat: str = "full"              # full | none
    microbatches: int = 1
    shard_activations: bool = True   # residual d_model over TP between layers
    train_sharding: str = "tp"       # tp (Megatron TP+SP) | fsdp (ZeRO-3:
                                     # params sharded over the flat mesh,
                                     # batch over all axes, per-layer gather)
    optimizer: str = "adamw"         # adamw | adafactor
    zero1: bool = True               # shard optimizer moments over DP

    # long-context applicability (subquadratic path exists)
    supports_long_context: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def param_jdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def compute_jdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    # -- TP padding ------------------------------------------------------
    def padded_heads(self, tp: int) -> int:
        """Query heads padded so head-TP shards cleanly.

        MHA (kv == q): pad both to a multiple of tp.  GQA: pad the group
        size G so Kv * G' is a multiple of tp, keeping the contiguous
        (kv-group-major) head->kv mapping — pad heads sit at the tail of
        each group with zero wq/wo, so numerics are exact."""
        import math as _m
        if self.n_kv_heads == self.n_heads:
            return -(-self.n_heads // tp) * tp
        G = self.n_heads // self.n_kv_heads
        m = tp // _m.gcd(self.n_kv_heads, tp)
        Gp = -(-G // m) * m
        return self.n_kv_heads * Gp

    def padded_kv_heads(self, tp: int) -> int:
        if self.n_kv_heads == self.n_heads:
            return self.padded_heads(tp)
        return self.n_kv_heads

    def kv_heads_sharded(self, tp: int) -> bool:
        return self.padded_kv_heads(tp) % tp == 0

    def padded_vocab(self, tp: int) -> int:
        return -(-self.vocab_size // tp) * tp

    def ssm_heads(self) -> int:
        d_inner = self.ssm_expand * self.d_model
        return d_inner // self.ssm_head_dim

    def padded_ssm_heads(self, tp: int) -> int:
        return -(-self.ssm_heads() // tp) * tp

    # -- layer grouping for scan ------------------------------------------
    def layer_groups(self) -> tuple[int, tuple[str, ...], tuple[str, ...]]:
        """(n_scanned_groups, group_pattern, tail_pattern)."""
        g = len(self.pattern)
        return (self.n_layers // g, self.pattern,
                self.pattern[: self.n_layers % g])

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeCfg("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCfg("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCfg("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCfg("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> tuple[ShapeCfg, ...]:
    """The assigned shape cells that apply to this arch (long_500k only for
    sub-quadratic archs, per the brief; all assigned archs have a decoder,
    so decode shapes always apply)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)
