"""State-space / linear-recurrence blocks: Mamba2 (SSD) and RG-LRU
(RecurrentGemma), plus the sequence-parallel halo/carry utilities that map
the paper's C3 (stencil padding) onto LM sequence sharding.

Mamba2 follows arXiv:2405.21060 with n_groups=1: separate (TP-shardable)
projections for z/x/B/C/dt instead of the fused in_proj, causal depthwise
conv over the x/B/C streams, SSD computed in the chunked dual form
(``repro.kernels.ssd`` holds the Pallas intra-chunk kernel; the model path
uses the pure-jnp chunked form so dry-run FLOPs are roofline-visible), and
a per-head gated RMSNorm (deviation from the fused-group norm of the
reference implementation, noted in DESIGN.md — per-head keeps the norm
local under head-sharded TP).

RG-LRU follows the Griffin paper (arXiv:2402.19427): block-diagonal input
and recurrence gates, a = exp(-c * softplus(Lambda) * r_t), recurrence
h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t), computed with
``lax.associative_scan`` (log-depth — the TPU-native choice; a sequential
scan would serialize 4k steps).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .common import ParamTree
from repro.kernels.ssd.ref import ssd_chunked, ssd_decode_step

RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# causal depthwise conv (the paper's 1-d stencil, at LM scale)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, *, prefix: Optional[jax.Array] = None):
    """x (B, S, C), w (C, K) depthwise causal conv.  ``prefix`` (B, K-1, C)
    supplies the left halo (decode state / sequence-parallel halo from the
    previous shard — repro.core.halo provides it under shard_map); zeros
    otherwise."""
    B, S, C = x.shape
    K = w.shape[-1]
    if prefix is None:
        prefix = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for k in range(K):
        out = out + xp[:, k : k + S].astype(jnp.float32) \
            * w[:, k].astype(jnp.float32)
    return out.astype(x.dtype)


def conv_state_update(state: jax.Array, xt: jax.Array) -> jax.Array:
    """Roll one token into the (B, K-1, C) conv state."""
    return jnp.concatenate([state[:, 1:], xt[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba2(pt: ParamTree, *, d_model: int, d_state: int, n_heads: int,
                head_dim: int, d_conv: int = 4, name: str = "mamba",
                pad_heads: int = 0) -> None:
    """n_heads includes TP padding (``pad_heads`` of them are zero-init so
    padded head outputs vanish exactly)."""
    H, P, N = n_heads, head_dim, d_state
    sub = pt.child()
    sub.dense("wz", (d_model, H, P), ("embed", "ssm_heads", None),
              fan_in=d_model)
    sub.dense("wx", (d_model, H, P), ("embed", "ssm_heads", None),
              fan_in=d_model)
    sub.dense("wB", (d_model, N), ("embed", "ssm_state"), fan_in=d_model)
    sub.dense("wC", (d_model, N), ("embed", "ssm_state"), fan_in=d_model)
    sub.dense("wdt", (d_model, H), ("embed", "ssm_heads"), fan_in=d_model)
    # dt bias ~ softplus^-1 of dt in [1e-3, 1e-1]
    sub.custom("dt_bias",
               jnp.log(jnp.expm1(jnp.logspace(-3, -1, H))), ("ssm_heads",))
    sub.custom("A_log", jnp.log(jnp.linspace(1.0, 16.0, H)), ("ssm_heads",))
    sub.const("D", (H,), ("ssm_heads",), 1.0)
    sub.dense("conv_x", (H * P, d_conv), (None, "conv"), fan_in=d_conv)
    sub.dense("conv_B", (N, d_conv), ("ssm_state", "conv"), fan_in=d_conv)
    sub.dense("conv_C", (N, d_conv), ("ssm_state", "conv"), fan_in=d_conv)
    sub.const("norm", (H, P), ("ssm_heads", None), 1.0)
    sub.dense("wo", (H, P, d_model), ("ssm_heads", None, "embed"),
              fan_in=H * P)
    if pad_heads:
        for nm in ("wz", "wx", "wdt", "wo"):
            w = sub.params[nm]
            ax = 1 if nm != "wo" else 0
            idx = [slice(None)] * w.ndim
            idx[ax] = slice(H - pad_heads, None)
            sub.params[nm] = w.at[tuple(idx)].set(0.0)
    pt.sub(name, sub)


def _gated_head_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                     eps: float = 1e-6) -> jax.Array:
    """Per-head gated RMSNorm: norm(y * silu(z)) over the head_dim axis."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_forward(p, x: jax.Array, *, chunk: int = 128,
                   init_state=None, conv_prefix=None):
    """x (B, S, d) -> (y (B, S, d), (ssd_state, conv_state)).

    conv_prefix, when given, is the (B, K-1, HP + 2N) halo for the three
    convolved streams (decode / sequence-parallel)."""
    B, S, d = x.shape
    H, P = p["wz"].shape[1], p["wz"].shape[2]
    N = p["wB"].shape[1]
    K = p["conv_x"].shape[-1]

    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"].astype(x.dtype))
    xh = jnp.einsum("bsd,dhp->bshp", x, p["wx"].astype(x.dtype))
    Bm = x @ p["wB"].astype(x.dtype)
    C = x @ p["wC"].astype(x.dtype)
    dt = x @ p["wdt"].astype(x.dtype)

    streams = jnp.concatenate(
        [xh.reshape(B, S, H * P), Bm, C], axis=-1)
    wconv = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=0)
    conv_out = jax.nn.silu(causal_conv1d(streams, wconv, prefix=conv_prefix))
    new_conv_state = streams[:, -(K - 1):] if conv_prefix is None else \
        jnp.concatenate([conv_prefix, streams], axis=1)[:, -(K - 1):]
    xh = conv_out[..., : H * P].reshape(B, S, H, P)
    Bm = conv_out[..., H * P : H * P + N]
    C = conv_out[..., H * P + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # pad seq to a chunk multiple; padded steps use dt = 0 (identity decay,
    # zero state contribution) so the final state is exact
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, pad if i == 1 else 0)
                                     for i in range(a.ndim)])
        xh, Bm, C, dt = zpad(xh), zpad(Bm), zpad(C), zpad(dt)
    y, state = ssd_chunked(xh, dt, A, Bm, C, D=p["D"].astype(jnp.float32),
                           init_state=init_state, chunk=chunk)
    if pad:
        y = y[:, :S]
    y = _gated_head_norm(y, z, p["norm"])
    out = jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(y.dtype))
    return out, (state, new_conv_state)


def mamba2_decode(p, xt: jax.Array, state):
    """One-token step. xt (B, d); state = (ssd_state (B,H,P,N),
    conv_state (B, K-1, HP+2N))."""
    ssd_state, conv_state = state
    B, d = xt.shape
    H, P = p["wz"].shape[1], p["wz"].shape[2]
    N = p["wB"].shape[1]

    z = jnp.einsum("bd,dhp->bhp", xt, p["wz"].astype(xt.dtype))
    xh = jnp.einsum("bd,dhp->bhp", xt, p["wx"].astype(xt.dtype)).reshape(B, H * P)
    Bm = xt @ p["wB"].astype(xt.dtype)
    C = xt @ p["wC"].astype(xt.dtype)
    dt = xt @ p["wdt"].astype(xt.dtype)

    stream_t = jnp.concatenate([xh, Bm, C], axis=-1)
    full = jnp.concatenate([conv_state, stream_t[:, None]], axis=1)  # (B,K,C)
    wconv = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=0)
    conv_t = jax.nn.silu(jnp.einsum("bkc,ck->bc", full.astype(jnp.float32),
                                    wconv.astype(jnp.float32)))
    new_conv_state = full[:, 1:]
    xh = conv_t[:, : H * P].reshape(B, H, P).astype(xt.dtype)
    Bm = conv_t[:, H * P : H * P + N].astype(xt.dtype)
    C = conv_t[:, H * P + N :].astype(xt.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    new_ssd, yt = ssd_decode_step(ssd_state, xh, dt, A, Bm, C,
                                  D=p["D"].astype(jnp.float32))
    yt = _gated_head_norm(yt, z, p["norm"])
    out = jnp.einsum("bhp,hpd->bd", yt, p["wo"].astype(yt.dtype))
    return out, (new_ssd, new_conv_state)


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def init_rglru(pt: ParamTree, *, d_model: int, lru_width: int, n_blocks: int,
               d_conv: int = 4, name: str = "rglru") -> None:
    R, Hb = lru_width, n_blocks
    W = R // Hb
    sub = pt.child()
    sub.dense("wx", (d_model, R), ("embed", "rnn"), fan_in=d_model)
    sub.dense("wy", (d_model, R), ("embed", "rnn"), fan_in=d_model)
    sub.dense("conv", (R, d_conv), ("rnn", "conv"), fan_in=d_conv)
    sub.dense("gate_a", (Hb, W, W), ("rnn", None, None), fan_in=W)
    sub.const("gate_a_b", (R,), ("rnn",), 0.0)
    sub.dense("gate_x", (Hb, W, W), ("rnn", None, None), fan_in=W)
    sub.const("gate_x_b", (R,), ("rnn",), 0.0)
    # Lambda init so a = exp(-c softplus(L)) is in ~[0.9, 0.999]
    a0 = jnp.linspace(0.9, 0.999, R)
    lam = jnp.log(jnp.expm1(-jnp.log(a0) / RG_LRU_C))
    sub.custom("lam", lam, ("rnn",))
    sub.dense("wo", (R, d_model), ("rnn", "embed"), fan_in=R)
    pt.sub(name, sub)


def _block_diag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (..., R) through block-diagonal weight (Hb, W, W) + bias (R,)."""
    Hb, W, _ = w.shape
    xs = x.reshape(*x.shape[:-1], Hb, W)
    out = jnp.einsum("...hw,hwv->...hv", xs, w.astype(x.dtype))
    return out.reshape(*x.shape[:-1], Hb * W) + b.astype(x.dtype)


def _rglru_gates(p, xc):
    """log_a (f32) and gated input for the recurrence; xc (B,S,R)."""
    r = jax.nn.sigmoid(_block_diag(xc, p["gate_a"], p["gate_a_b"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xc, p["gate_x"], p["gate_x_b"])
                       .astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(jnp.float32))
    return a, gated


def rglru_forward(p, x: jax.Array, *, init_state=None, conv_prefix=None):
    """x (B, S, d) -> (y (B, S, d), (h_state (B,R), conv_state))."""
    B, S, d = x.shape
    R = p["wx"].shape[1]
    K = p["conv"].shape[-1]

    xb = x @ p["wx"].astype(x.dtype)
    yb = jax.nn.gelu(x @ p["wy"].astype(x.dtype))
    xc = causal_conv1d(xb, p["conv"], prefix=conv_prefix)
    new_conv_state = xb[:, -(K - 1):] if conv_prefix is None else \
        jnp.concatenate([conv_prefix, xb], axis=1)[:, -(K - 1):]

    a, gated = _rglru_gates(p, xc)
    if init_state is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.ones((B, 1, R), a.dtype), a], axis=1)
        gated = jnp.concatenate([init_state.astype(jnp.float32)[:, None],
                                 gated], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    if init_state is not None:
        h = h[:, 1:]
    h_last = h[:, -1]
    out = (h.astype(x.dtype) * yb) @ p["wo"].astype(x.dtype)
    return out, (h_last, new_conv_state)


def rglru_decode(p, xt: jax.Array, state):
    """One-token step; state = (h (B,R) f32, conv_state (B,K-1,R))."""
    h, conv_state = state
    B, d = xt.shape
    xb = xt @ p["wx"].astype(xt.dtype)
    yb = jax.nn.gelu(xt @ p["wy"].astype(xt.dtype))
    full = jnp.concatenate([conv_state, xb[:, None]], axis=1)
    xc = jnp.einsum("bkr,rk->br", full.astype(jnp.float32),
                    p["conv"].astype(jnp.float32)).astype(xt.dtype)
    a, gated = _rglru_gates(p, xc)
    h_new = a * h.astype(jnp.float32) + gated
    out = (h_new.astype(xt.dtype) * yb) @ p["wo"].astype(xt.dtype)
    return out, (h_new, full[:, 1:])


# ---------------------------------------------------------------------------
# sequence-parallel (context-parallel) utilities — paper C3 at LM scale
# ---------------------------------------------------------------------------

def seqpar_conv_halo(x_local: jax.Array, *, width: int, axis_name: str):
    """Left halo of ``width`` tokens from the previous sequence shard via
    ppermute — exactly repro.core.halo's one-sided exchange.  First shard
    gets zeros (causal boundary).  Must run inside shard_map."""
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    send = x_local[:, -width:]
    halo = lax.ppermute(send, axis_name, [(i, i + 1) for i in range(n - 1)])
    return jnp.where(idx == 0, jnp.zeros_like(halo), halo)


def seqpar_scan_carry(a_total: jax.Array, h_local: jax.Array, *,
                      axis_name: str):
    """Combine per-shard linear-recurrence results across sequence shards.

    Each shard computed its local recurrence from a zero state, yielding
    ``h_local`` (B, R) (its last state) and ``a_total`` (B, R) (the product
    of its decay factors).  The true incoming state of shard i is the
    prefix-combined state of shards < i — an exclusive associative scan
    over the mesh axis, done here with an all-gather (shard count is small)."""
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    a_all = lax.all_gather(a_total, axis_name)   # (n, B, R)
    h_all = lax.all_gather(h_local, axis_name)   # (n, B, R)

    def step(carry, xs):
        a_i, h_i = xs
        return carry * a_i + h_i, carry

    _, incoming = lax.scan(step, jnp.zeros_like(h_local), (a_all, h_all))
    return incoming[idx]  # state entering this shard
