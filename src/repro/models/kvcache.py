"""KV cache with polymorphic layout — the paper's C1 applied to serving.

The cache is a RecordArray with fields (k, v) of size head_dim over the
space (batch, seq, kv_heads):

* AoS  -> one array (B, S, Hkv, 2*hd): k/v interleaved per (position, head);
          reading k is a minor-dim slice (zero transpose), appending one
          token writes one contiguous slab.
* SoA  -> one array (2*hd, B, S, Hkv): each of the 2*hd component planes is
          contiguous over (B, S, Hkv); reads transpose the component axis
          to the minor position.
* AoSoA -> the last space dim is tiled by ``aosoa_tile``:
          "bsh" tiles Hkv (sequence stays a plain storage axis, so token
          writes are ordinary dynamic slices); "bhs" tiles the sequence
          itself, and a token write addresses (pos // tile, pos % tile)
          across the two storage axes — the dynamic-slice write path that
          used to be rejected with a ValueError.

On GPU the paper finds SoA wins for vector-field kernels (coalescing).
For TPU *decode reads* the AoS record keeps head_dim minor-most (exactly
one 128-lane tile for hd=128) while SoA leaves the small Hkv axis minor —
so the winner flips with the workload, which is precisely the paper's
argument for making layout a one-line polymorphic knob rather than a
rewrite.  benchmarks/roofline + EXPERIMENTS §Perf quantify both.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.layout import (Layout, RecordArray, RecordSpec, Vector,
                               relayout_data)

__all__ = ["KVLayout", "kv_spec", "kv_make", "kv_read", "kv_write_prefill",
           "kv_write_token", "kv_pspec"]

KVLayout = Layout  # re-export under the serving name


def kv_spec(head_dim: int) -> RecordSpec:
    return RecordSpec.create(Vector("k", head_dim), Vector("v", head_dim))


def _space(batch: int, seq: int, kv_heads: int, order: str):
    return (batch, seq, kv_heads) if order == "bsh" else (batch, kv_heads, seq)


def kv_make(batch: int, seq: int, kv_heads: int, head_dim: int,
            dtype=jnp.bfloat16, layout: Layout = Layout.AOS,
            order: str = "bsh") -> jax.Array:
    """Cache storage.  ``order`` is the SPACE axis order — the second
    polymorphic-layout knob (paper C1): "bsh" keeps sequence adjacent to
    batch; "bhs" puts sequence minor-most-but-one so the decode score dot
    consumes k as (B, H, S, hd) with NO per-step transpose (measured:
    -47%% decode HBM traffic on qwen3 decode_32k; EXPERIMENTS §Perf)."""
    shape = RecordArray.storage_shape(kv_spec(head_dim),
                                      _space(batch, seq, kv_heads, order),
                                      layout)
    return jnp.zeros(shape, dtype)


def kv_read(storage: jax.Array, head_dim: int,
            layout: Layout = Layout.AOS,
            order: str = "bsh") -> tuple[jax.Array, jax.Array]:
    """-> (k, v) each (B, S, Hkv, hd) for "bsh" / (B, Hkv, S, hd) for
    "bhs" (native, no transpose)."""
    rec = RecordArray(storage, kv_spec(head_dim), layout)
    return rec.field("k"), rec.field("v")


def kv_write_prefill(storage: jax.Array, k: jax.Array, v: jax.Array,
                     layout: Layout = Layout.AOS,
                     order: str = "bsh") -> jax.Array:
    """Write the first S_in positions of the cache from prefill k/v
    (B, S_in, Hkv, hd) — one transpose at prefill for "bhs".  For AoSoA
    the bulk write stages through the AoS view (a pure transpose, traced
    into the prefill executable) because the update region need not be
    tile-aligned."""
    hd = k.shape[-1]
    kv = jnp.concatenate([k, v], axis=-1).astype(storage.dtype)
    if order == "bhs":
        kv = jnp.swapaxes(kv, 1, 2)             # (B, Hkv, S_in, 2hd)
    if layout is Layout.AOSOA:
        spec = kv_spec(hd)
        aos = relayout_data(storage, spec, Layout.AOSOA, Layout.AOS)
        aos = lax.dynamic_update_slice(aos, kv, (0, 0, 0, 0))
        return relayout_data(aos, spec, Layout.AOS, Layout.AOSOA)
    if layout is Layout.AOS:
        return lax.dynamic_update_slice(storage, kv, (0, 0, 0, 0))
    return lax.dynamic_update_slice(
        storage, jnp.moveaxis(kv, -1, 0), (0, 0, 0, 0))


def _aosoa_tilefold(kv: jax.Array, tile: int) -> jax.Array:
    """(B, Hkv, C) token slab -> (B, Hkv//tile, C, tile) AoSoA slab."""
    B, H, C = kv.shape
    return kv.reshape(B, H // tile, tile, C).swapaxes(-1, -2)


def kv_write_token(storage: jax.Array, k_t: jax.Array, v_t: jax.Array,
                   pos: jax.Array, layout: Layout = Layout.AOS,
                   order: str = "bsh") -> jax.Array:
    """Write one token's k/v (B, Hkv, hd) at sequence slot ``pos``.

    ``pos`` is either a scalar (whole batch at one position — training-eval
    and uniform decode) or a vector (B,) of per-slot positions (continuous
    batching: every batch slot sits at its own depth).  Scalar writes lower
    to ``dynamic_update_slice``; vector writes to an XLA scatter."""
    kv = jnp.concatenate([k_t, v_t], axis=-1).astype(storage.dtype)
    pos = jnp.asarray(pos, jnp.int32)
    B, H, C = kv.shape
    if pos.ndim == 0:
        if order == "bsh":
            if layout is Layout.AOS:
                upd = kv[:, None]                     # (B, 1, Hkv, 2hd)
                return lax.dynamic_update_slice(storage, upd, (0, pos, 0, 0))
            if layout is Layout.SOA:
                upd = jnp.moveaxis(kv, -1, 0)[:, :, None]  # (2hd, B, 1, Hkv)
                return lax.dynamic_update_slice(storage, upd, (0, 0, pos, 0))
            upd = _aosoa_tilefold(kv, storage.shape[-1])[:, None]
            return lax.dynamic_update_slice(storage, upd, (0, pos, 0, 0, 0))
        if layout is Layout.AOS:
            upd = kv[:, :, None]                      # (B, Hkv, 1, 2hd)
            return lax.dynamic_update_slice(storage, upd, (0, 0, pos, 0))
        if layout is Layout.SOA:
            upd = jnp.moveaxis(kv, -1, 0)[:, :, :, None]  # (2hd, B, Hkv, 1)
            return lax.dynamic_update_slice(storage, upd, (0, 0, 0, pos))
        # AoSoA "bhs": sequence is the tiled dim -> address the slot as
        # (pos // tile, pos % tile) across the two storage axes.
        tile = storage.shape[-1]
        upd = kv[:, :, None, :, None]                 # (B, Hkv, 1, 2hd, 1)
        return lax.dynamic_update_slice(
            storage, upd, (0, 0, pos // tile, 0, pos % tile))

    # vector pos: one scatter per field-free storage form
    b = jnp.arange(B, dtype=jnp.int32)
    h = jnp.arange(H, dtype=jnp.int32)
    if order == "bsh":
        if layout is Layout.AOS:                      # (B, S, Hkv, 2hd)
            return storage.at[b, pos].set(kv)
        if layout is Layout.SOA:                      # (2hd, B, S, Hkv)
            return storage.at[:, b, pos].set(jnp.moveaxis(kv, -1, 0))
        upd = _aosoa_tilefold(kv, storage.shape[-1])  # (B, n, 2hd, t)
        return storage.at[b, pos].set(upd)            # (B, S, n, 2hd, t)
    if layout is Layout.AOS:                          # (B, Hkv, S, 2hd)
        return storage.at[b[:, None], h[None, :], pos[:, None]].set(kv)
    if layout is Layout.SOA:                          # (2hd, B, Hkv, S)
        return storage.at[:, b[:, None], h[None, :],
                          pos[:, None]].set(jnp.moveaxis(kv, -1, 0))
    tile = storage.shape[-1]                          # (B, Hkv, S//t, 2hd, t)
    return storage.at[b[:, None], h[None, :], (pos // tile)[:, None], :,
                      (pos % tile)[:, None]].set(kv)


def kv_pspec(layout: Layout, *, batch_axes, seq_axes,
             order: str = "bsh") -> P:
    """PartitionSpec for the cache storage given the serving sharding
    scheme (batch over DP axes, sequence flash-decode-sharded)."""
    ba = tuple(batch_axes) if batch_axes else None
    sa = tuple(seq_axes) if seq_axes else None
    space = (ba, sa, None) if order == "bsh" else (ba, None, sa)
    if layout is Layout.AOS:
        return P(*space, None)
    if layout is Layout.SOA:
        return P(None, *space)
    # AoSoA: the tiled (last-space) dim splits into (major, comp, lane);
    # any sharding of it lands on the tile-major axis (whole tiles).
    return P(*space[:-1], space[-1], None, None)
