"""Layer blocks: attention (global/local/cross), MLP / MoE FFN, and the
uniform layer wrapper that assembles mixer + FFN with pre/post norms for
every layer kind ("A" global attn, "L" local attn, "M" Mamba2, "R" RG-LRU).

Every block has three entry points:
  init_*            parameter + logical-spec construction (TP-padded)
  *_forward         full-sequence (train / prefill), optionally emitting
                    the serving cache
  *_decode          one-token step against the cache

The sharding of every weight is declared once via logical axes
(models/common.py) — the polymorphic-layout philosophy of the paper: the
layout/partitioning decision is data, not code.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field as dfield
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.layout import Layout
from . import kvcache as kvc
from .attention import attention, decode_attention, make_sharded_decode_attention
from .common import DEFAULT_RULES, ParamTree, layer_norm, rms_norm, rope_cos_sin, apply_rope
from .config import ModelConfig
from .moe import init_moe, moe_block
from .ssm import (init_mamba2, init_rglru, mamba2_decode, mamba2_forward,
                  rglru_decode, rglru_forward)

BIG_POS = jnp.int32(1 << 30)


# ---------------------------------------------------------------------------
# sharding context
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardCtx:
    """Everything the forward pass needs to know about the mesh."""

    mesh: Optional[Mesh] = None
    rules: Mapping[str, Optional[str]] = dfield(
        default_factory=lambda: dict(DEFAULT_RULES))
    batch_axes: tuple[str, ...] = ()        # activation batch sharding
    decode_seq_axes: tuple[str, ...] = ()   # cache seq sharding (flash-decode)
    residual_tp: bool = False               # shard residual d_model over TP
                                            # (Megatron-style sequence par.:
                                            # remat-saved carries 16x smaller)
    moe_a2a: Optional[Any] = None           # explicit-EP MoE fn (make_moe_a2a)

    @property
    def tp(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get("model", 1)

    @property
    def ba(self):
        return tuple(self.batch_axes) if self.batch_axes else None

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def norm_apply(p, x, cfg: ModelConfig, prefix: str):
    if f"{prefix}_b" in p:
        return layer_norm(x, p[prefix], p[f"{prefix}_b"], eps=cfg.norm_eps)
    return rms_norm(x, p[prefix], eps=cfg.norm_eps,
                    plus_one=cfg.norm_plus_one)


def init_norm(pt: ParamTree, cfg: ModelConfig, name: str, dim: int) -> None:
    init = 0.0 if cfg.norm_plus_one else 1.0
    pt.const(name, (dim,), ("embed",), init)
    if cfg.norm_kind == "layernorm":
        pt.const(f"{name}_b", (dim,), ("embed",), 0.0)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def init_attention(pt: ParamTree, cfg: ModelConfig, tp: int, *,
                   cross: bool = False, name: str = "attn") -> None:
    d, hd = cfg.d_model, cfg.head_dim
    Hp = cfg.padded_heads(tp)
    Kv = cfg.padded_kv_heads(tp)
    sub = pt.child()
    sub.dense("wq", (d, Hp, hd), ("embed", "q_heads", "head_dim"), fan_in=d)
    sub.dense("wk", (d, Kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d)
    sub.dense("wv", (d, Kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d)
    sub.dense("wo", (Hp, hd, d), ("q_heads", "head_dim", "embed"),
              fan_in=Hp * hd)
    if Hp != cfg.n_heads:  # zero the padded heads: exact numerics
        Gp = Hp // Kv
        G = cfg.n_heads // cfg.n_kv_heads if cfg.n_kv_heads != cfg.n_heads \
            else Hp  # MHA: tail padding within the single 'group'
        if cfg.n_kv_heads == cfg.n_heads:
            pad = jnp.arange(Hp) >= cfg.n_heads
        else:  # pad heads sit at the tail of each kv group
            pad = (jnp.arange(Hp) % Gp) >= G
        sub.params["wq"] = jnp.where(pad[None, :, None], 0.0,
                                     sub.params["wq"])
        sub.params["wo"] = jnp.where(pad[:, None, None], 0.0,
                                     sub.params["wo"])
    if Kv != cfg.n_kv_heads:  # MHA padded kv heads: zero k/v projections
        padkv = jnp.arange(Kv) >= cfg.n_kv_heads
        sub.params["wk"] = jnp.where(padkv[None, :, None], 0.0,
                                     sub.params["wk"])
        sub.params["wv"] = jnp.where(padkv[None, :, None], 0.0,
                                     sub.params["wv"])
    if cfg.qkv_bias and not cross:
        sub.const("bq", (Hp, hd), ("q_heads", "head_dim"), 0.0)
        sub.const("bk", (Kv, hd), ("kv_heads", "head_dim"), 0.0)
        sub.const("bv", (Kv, hd), ("kv_heads", "head_dim"), 0.0)
    if cfg.qk_norm and not cross:
        sub.const("q_norm", (hd,), ("head_dim",), 1.0)
        sub.const("k_norm", (hd,), ("head_dim",), 1.0)
    pt.sub(name, sub)


def _project_qkv(p, x, cfg: ModelConfig, *, rope: Optional[tuple] = None):
    """x (B, S, d) -> q (B,S,Hp,hd), k/v (B,S,Kv,hd)."""
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin, mode=cfg.rope_mode)
        k = apply_rope(k, cos, sin, mode=cfg.rope_mode)
    return q, k, v


def _rope_tables(cfg: ModelConfig, positions: jax.Array):
    rot = int(cfg.head_dim * cfg.rope_fraction)
    return rope_cos_sin(positions, rot, base=cfg.rope_base)


def attention_forward(p, h, cfg: ModelConfig, ctx: ShardCtx, *,
                      causal: bool = True, window: Optional[int] = None,
                      positions: Optional[jax.Array] = None,
                      enc_out: Optional[jax.Array] = None,
                      want_cache: bool = False):
    """Full-sequence attention sub-block (no residual / norm — the layer
    wrapper owns those).  ``enc_out`` switches to cross-attention."""
    B, S, d = h.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if enc_out is None:
        rope = _rope_tables(cfg, positions)
        q, k, v = _project_qkv(p, h, cfg, rope=rope)
        kpos = positions
    else:
        cdt = h.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(cdt))
        kpos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        causal, window = False, None
    q = ctx.constrain(q, P(ctx.ba, None,
                           ctx.rules.get("q_heads"), None))
    out = attention(q, k, v, qpos=positions, kpos=kpos, causal=causal,
                    window=window, impl=cfg.attn_impl,
                    q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if want_cache:
        return o, (k, v)
    return o


def make_attn_cache(cfg: ModelConfig, batch: int, max_seq: int,
                    window: Optional[int], dtype, tp: int = 1) -> jax.Array:
    S = min(window, max_seq) if window else max_seq
    return kvc.kv_make(batch, S, cfg.padded_kv_heads(tp), cfg.head_dim,
                       dtype, cfg.kv_layout, cfg.kv_order)


def fill_attn_cache(storage, k, v, cfg: ModelConfig,
                    window: Optional[int]) -> jax.Array:
    """Write prefill k/v (B, S, Kv, hd) into a fresh cache."""
    S = k.shape[1]
    if window:
        W = _cache_seq_len(storage, cfg)
        if S >= W:
            slot_pos = S - W + ((jnp.arange(W) - S) % W)
            k = k[:, slot_pos]
            v = v[:, slot_pos]
        else:
            pad = W - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return kvc.kv_write_prefill(storage, k, v, cfg.kv_layout, cfg.kv_order)


def _cache_seq_len(storage, cfg: ModelConfig) -> int:
    if cfg.kv_layout is Layout.AOSOA:
        if cfg.kv_order == "bsh":      # (B, S, Hkv//t, C, t)
            return storage.shape[1]
        return storage.shape[2] * storage.shape[4]  # (B, Hkv, S//t, C, t)
    i = 1 if cfg.kv_order == "bsh" else 2
    if cfg.kv_layout is not Layout.AOS:
        i += 1
    return storage.shape[i]


def _ring_kpos(pos: jax.Array, W: int) -> jax.Array:
    """Global position held by each ring slot after writing ``pos``;
    unwritten slots get BIG_POS (masked by cache_len).  ``pos`` scalar ->
    (W,); per-slot vector (B,) -> (B, W)."""
    i = jnp.arange(W, dtype=jnp.int32)
    p = pos[..., None] - ((pos[..., None] - i) % W)
    return jnp.where(p >= 0, p, BIG_POS)


def attention_decode(p, h_t, cache, pos, cfg: ModelConfig, ctx: ShardCtx, *,
                     window: Optional[int] = None,
                     cross_len: Optional[int] = None):
    """One-token attention. h_t (B, d); cache = kv storage; pos = position
    of the incoming token: a scalar (uniform batch) or a (B,) vector of
    per-slot positions (continuous batching).  cross_len: cache is a frozen
    encoder cache of that length (no write, no rope, no mask beyond
    length)."""
    B, d = h_t.shape
    cdt = h_t.dtype
    q = jnp.einsum("bd,dhk->bhk", h_t, p["wq"].astype(cdt))
    if cross_len is None:
        pos = jnp.asarray(pos, jnp.int32)
        ragged = pos.ndim == 1
        k_t = jnp.einsum("bd,dhk->bhk", h_t, p["wk"].astype(cdt))
        v_t = jnp.einsum("bd,dhk->bhk", h_t, p["wv"].astype(cdt))
        if "bq" in p:
            q = q + p["bq"].astype(cdt)
            k_t = k_t + p["bk"].astype(cdt)
            v_t = v_t + p["bv"].astype(cdt)
        if "q_norm" in p:
            q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
            k_t = rms_norm(k_t, p["k_norm"], eps=cfg.norm_eps)
        if ragged:  # per-slot rope rows, broadcast over heads only
            cos, sin = _rope_tables(cfg, pos)
            cos, sin = cos[:, None], sin[:, None]
        else:
            cos, sin = _rope_tables(cfg, pos[None])
            cos, sin = cos[None], sin[None]
        q = apply_rope(q[:, None], cos, sin, mode=cfg.rope_mode)[:, 0]
        k_t = apply_rope(k_t[:, None], cos, sin, mode=cfg.rope_mode)[:, 0]
        if window:
            W = _cache_seq_len(cache, cfg)
            slot = (pos % W).astype(jnp.int32)
            cache = kvc.kv_write_token(cache, k_t, v_t, slot, cfg.kv_layout,
                                       cfg.kv_order)
            kp = _ring_kpos(pos, W)
            kpos = kp if ragged else jnp.broadcast_to(kp[None], (B, W))
        else:
            cache = kvc.kv_write_token(cache, k_t, v_t, pos,
                                       cfg.kv_layout, cfg.kv_order)
            kpos = None
        cache_len = jnp.broadcast_to(pos + 1, (B,)).astype(jnp.int32)
    else:
        cache_len = jnp.broadcast_to(cross_len, (B,)).astype(jnp.int32)
        kpos = None

    k, v = kvc.kv_read(cache, cfg.head_dim, cfg.kv_layout, cfg.kv_order)
    fmt = "bshd" if cfg.kv_order == "bsh" else "bhsd"
    use_dist = (ctx.mesh is not None and ctx.decode_seq_axes
                and window is None)
    if use_dist:
        fn = make_sharded_decode_attention(
            ctx.mesh, batch_axes=ctx.batch_axes,
            seq_axes=ctx.decode_seq_axes,
            heads_tp=ctx.tp > 1, kv_format=fmt)
        out = fn(q, k, v, cache_len, window)
    else:
        from .attention import repeat_kv
        h_ax = 2 if fmt == "bshd" else 1
        k = jnp.repeat(k, q.shape[1] // k.shape[h_ax], axis=h_ax) \
            if k.shape[h_ax] != q.shape[1] else k
        v = jnp.repeat(v, q.shape[1] // v.shape[h_ax], axis=h_ax) \
            if v.shape[h_ax] != q.shape[1] else v
        out = decode_attention(q, k, v, cache_len,
                               kpos=kpos, window=window, kv_format=fmt)
    o = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(out.dtype))
    return o, cache


# ---------------------------------------------------------------------------
# FFN: dense MLP and MoE
# ---------------------------------------------------------------------------

def init_ffn(pt: ParamTree, cfg: ModelConfig, name: str = "ffn") -> None:
    d, f = cfg.d_model, cfg.d_ff
    sub = pt.child()
    if cfg.n_experts:
        init_moe(sub, d_model=d, d_ff=f, n_experts=cfg.n_experts, name="moe")
        if cfg.dense_residual:
            sub.dense("wi_dense", (d, 2, f), ("embed", None, "ff"), fan_in=d)
            sub.dense("wo_dense", (f, d), ("ff", "embed"), fan_in=f)
    elif cfg.mlp_kind in ("swiglu", "geglu"):
        sub.dense("wi", (d, 2, f), ("embed", None, "ff"), fan_in=d)
        sub.dense("wo", (f, d), ("ff", "embed"), fan_in=f)
    else:
        sub.dense("wi", (d, f), ("embed", "ff"), fan_in=d)
        sub.const("bi", (f,), ("ff",), 0.0)
        sub.dense("wo", (f, d), ("ff", "embed"), fan_in=f)
        sub.const("bo", (d,), ("embed",), 0.0)
    pt.sub(name, sub)


def _act(cfg: ModelConfig, x):
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def _glu_act(cfg: ModelConfig, h):
    gate = h[..., 0, :]
    up = h[..., 1, :]
    g = jax.nn.gelu(gate) if cfg.mlp_kind == "geglu" else jax.nn.silu(gate)
    return g * up


def ffn_forward(p, x, cfg: ModelConfig, ctx: ShardCtx, *,
                dropless: bool = False):
    """x (..., d) -> (out (..., d), aux_loss scalar)."""
    cdt = x.dtype
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        lead = x.shape[:-1]
        x2d = x.reshape(-1, cfg.d_model)
        if ctx.moe_a2a is not None and not dropless:
            out, aux = ctx.moe_a2a(p["moe"], x2d)
        else:
            out, aux = moe_block(p["moe"], x2d, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 dropless=dropless)
        out = out.reshape(*lead, cfg.d_model)
        if cfg.dense_residual:
            h = jnp.einsum("...d,dtf->...tf", x, p["wi_dense"].astype(cdt))
            out = out + _glu_act(cfg, h) @ p["wo_dense"].astype(cdt)
        return out, aux
    if cfg.mlp_kind in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dtf->...tf", x, p["wi"].astype(cdt))
        return _glu_act(cfg, h) @ p["wo"].astype(cdt), aux
    h = _act(cfg, x @ p["wi"].astype(cdt) + p["bi"].astype(cdt))
    return h @ p["wo"].astype(cdt) + p["bo"].astype(cdt), aux


# ---------------------------------------------------------------------------
# uniform layer wrapper
# ---------------------------------------------------------------------------

def init_layer(pt: ParamTree, cfg: ModelConfig, kind: str, tp: int, *,
               cross: bool = False, name: str = "layer") -> None:
    """One decoder layer of the given kind (+optional cross-attention)."""
    sub = pt.child()
    init_norm(sub, cfg, "ln_mix", cfg.d_model)
    if kind in ("A", "L"):
        init_attention(sub, cfg, tp, name="attn")
    elif kind == "M":
        init_mamba2(sub, d_model=cfg.d_model, d_state=cfg.ssm_state,
                    n_heads=cfg.padded_ssm_heads(tp),
                    head_dim=cfg.ssm_head_dim, d_conv=cfg.d_conv,
                    name="mamba",
                    pad_heads=cfg.padded_ssm_heads(tp) - cfg.ssm_heads())
    elif kind == "R":
        init_rglru(sub, d_model=cfg.d_model, lru_width=cfg.lru_width,
                   n_blocks=cfg.rnn_blocks, d_conv=cfg.d_conv, name="rglru")
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.sandwich_norm:
        init_norm(sub, cfg, "ln_mix_post", cfg.d_model)
    if cross:
        init_norm(sub, cfg, "ln_cross", cfg.d_model)
        init_attention(sub, cfg, tp, cross=True, name="cross")
    if cfg.d_ff:
        init_norm(sub, cfg, "ln_ffn", cfg.d_model)
        init_ffn(sub, cfg, name="ffn")
        if cfg.sandwich_norm:
            init_norm(sub, cfg, "ln_ffn_post", cfg.d_model)
    pt.sub(name, sub)


def layer_forward(p, h, kind: str, cfg: ModelConfig, ctx: ShardCtx, *,
                  causal: bool = True, positions=None, enc_out=None,
                  want_cache: bool = False):
    """Full-seq layer. Returns (h, aux_loss, cache_entry|None)."""
    window = cfg.window if kind == "L" else None
    if kind == "L" and cfg.rope_base_local is not None:
        cfg = cfg.with_(rope_base=cfg.rope_base_local)
    x = norm_apply(p, h, cfg, "ln_mix")
    cache = None
    if kind in ("A", "L"):
        out = attention_forward(
            p["attn"], x, cfg, ctx, causal=causal, window=window,
            positions=positions, want_cache=want_cache)
        if want_cache:
            out, cache = out
    elif kind == "M":
        out, state = mamba2_forward(p["mamba"], x, chunk=cfg.ssd_chunk)
        cache = state if want_cache else None
    else:  # "R"
        out, state = rglru_forward(p["rglru"], x)
        cache = state if want_cache else None
    if cfg.sandwich_norm:
        out = norm_apply(p, out, cfg, "ln_mix_post")
    h = h + out
    if enc_out is not None and "cross" in p:
        xc = norm_apply(p, h, cfg, "ln_cross")
        h = h + attention_forward(p["cross"], xc, cfg, ctx, enc_out=enc_out)
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff:
        xf = norm_apply(p, h, cfg, "ln_ffn")
        out, aux = ffn_forward(p["ffn"], xf, cfg, ctx)
        if cfg.sandwich_norm:
            out = norm_apply(p, out, cfg, "ln_ffn_post")
        h = h + out
    h = ctx.constrain(h, P(ctx.ba, None,
                           "model" if ctx.residual_tp else None))
    return h, aux, cache


def layer_decode(p, h_t, kind: str, cfg: ModelConfig, ctx: ShardCtx, *,
                 cache, pos, enc_cache=None, enc_len: Optional[int] = None):
    """One-token layer step. Returns (h_t, new_cache)."""
    window = cfg.window if kind == "L" else None
    if kind == "L" and cfg.rope_base_local is not None:
        cfg = cfg.with_(rope_base=cfg.rope_base_local)
    x = norm_apply(p, h_t, cfg, "ln_mix")
    if kind in ("A", "L"):
        out, cache = attention_decode(p["attn"], x, cache, pos, cfg, ctx,
                                      window=window)
    elif kind == "M":
        out, cache = mamba2_decode(p["mamba"], x, cache)
    else:
        out, cache = rglru_decode(p["rglru"], x, cache)
    if cfg.sandwich_norm:
        out = norm_apply(p, out, cfg, "ln_mix_post")
    h_t = h_t + out
    if enc_cache is not None and "cross" in p:
        xc = norm_apply(p, h_t, cfg, "ln_cross")
        out, _ = attention_decode(p["cross"], xc, enc_cache, pos, cfg, ctx,
                                  cross_len=enc_len)
        h_t = h_t + out
    if cfg.d_ff:
        xf = norm_apply(p, h_t, cfg, "ln_ffn")
        out, _ = ffn_forward(p["ffn"], xf, cfg, ctx, dropless=True)
        if cfg.sandwich_norm:
            out = norm_apply(p, out, cfg, "ln_ffn_post")
        h_t = h_t + out
    return h_t, cache


def make_layer_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
                     dtype, tp: int = 1):
    """Fresh (empty) cache entry for one layer."""
    if kind == "A":
        return make_attn_cache(cfg, batch, max_seq, None, dtype, tp)
    if kind == "L":
        return make_attn_cache(cfg, batch, max_seq, cfg.window, dtype, tp)
    if kind == "M":
        H = cfg.padded_ssm_heads(tp)
        P_, N, K = cfg.ssm_head_dim, cfg.ssm_state, cfg.d_conv
        return (jnp.zeros((batch, H, P_, N), jnp.float32),
                jnp.zeros((batch, K - 1, H * P_ + 2 * N), dtype))
    if kind == "R":
        R, K = cfg.lru_width, cfg.d_conv
        return (jnp.zeros((batch, R), jnp.float32),
                jnp.zeros((batch, K - 1, R), dtype))
    raise ValueError(kind)
