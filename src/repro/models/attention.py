"""Attention for the LM stack: chunked (flash-style) training/prefill
attention in pure jnp (the roofline-visible implementation; the Pallas TPU
kernel in ``repro.kernels.attention`` is the hot-spot twin, validated
against the same math) and distributed decode attention over a
sequence-sharded KV cache.

Three implementations, selectable per config (hillclimb knob):

* ``dense``   — materialize (S, S) scores with mask. Smoke-test only.
* ``chunked`` — scan over (q-chunk x k-chunk) grid with online softmax;
                memory-bounded, computes ALL chunk pairs (masked). This is
                the paper-faithful baseline: the mask is the paper's
                "iterator validity check" — computed lanes that a bounds
                check discards.
* ``tri``     — scan over the *static lower-triangular list* of chunk pairs
                (plus window band for sliding-window layers): skipped pairs
                never appear in the HLO, cutting attention FLOPs ~2x for
                causal (the beyond-paper optimization; see EXPERIMENTS §Perf).

Decode: ``decode_attention`` combines per-shard partial attention with a
log-sum-exp reduction (flash-decoding) across the mesh axes that shard the
cache's sequence dim — the LM-scale analogue of the paper's partitioned
reduction (Fig. 4: each partition reduces as soon as its data is ready).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _mask_bias(qpos, kpos, *, causal: bool, window: Optional[int]) -> jax.Array:
    """(..., Lq, Lk) additive bias from causal/sliding-window visibility."""
    d = qpos[..., :, None] - kpos[..., None, :]
    ok = (d >= 0) if causal else jnp.ones_like(d, dtype=bool)
    if window is not None:
        ok = ok & (d < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def repeat_kv(k, n_heads: int):
    """Replicate kv heads to the full (padded) q-head count.

    Materializing the GQA replication keeps the head axis shardable as ONE
    contiguous TP dim: a (Hkv, G) reshape-split would leave the partitioner
    unable to shard either factor when Hkv < tp, falling back to
    all-gathered attention (measured: +490 GiB/step of all-reduce on
    qwen3 train_4k).  Per-device the replication is G x a small slice; the
    Pallas TPU kernel performs GQA without replication (kernels/attention).
    """
    Hkv = k.shape[2]
    if Hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // Hkv, axis=2)


def _gqa_scores(q, k, scale):
    """q (B,Lq,H,D), k (B,Lk,Hkv,D) -> scores (B,Hkv,G,Lq,Lk), f32."""
    B, Lq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Lq, Hkv, G, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _gqa_out(p, v):
    """p (B,Hkv,G,Lq,Lk) f32, v (B,Lk,Hkv,D) -> (B,Lq,H,D) f32."""
    B, Hkv, G, Lq, _ = p.shape
    D = v.shape[-1]
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Lq, Hkv * G, D)


def dense_attention(q, k, v, *, qpos, kpos, causal=True, window=None,
                    scale=None):
    """Reference (smoke/test) attention: full (Lq, Lk) scores.

    qpos (Lq,) and kpos (Lk,) are global token positions (1-d, shared
    across the batch)."""
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    s = _gqa_scores(q, k, scale)  # (B,Hkv,G,Lq,Lk)
    s = s + _mask_bias(qpos, kpos, causal=causal, window=window)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v).astype(q.dtype)


def _chunk_pairs(nq: int, nk: int, *, causal: bool,
                 window_chunks: Optional[int]) -> list[tuple[int, int]]:
    """Static (qi, ki) chunk-pair list actually needed under the mask."""
    pairs = []
    for qi in range(nq):
        for ki in range(nk):
            if causal and ki > qi + (nk - nq):
                continue
            if window_chunks is not None and (qi + (nk - nq)) - ki >= window_chunks:
                continue
            pairs.append((qi, ki))
    return pairs


def chunked_attention(q, k, v, *, qpos, kpos, causal=True, window=None,
                      q_chunk=512, k_chunk=512, impl="chunked", scale=None):
    """Flash-style attention (online softmax), scan over chunk pairs.

    q (B,Lq,H,D); k,v (B,Lk,Hkv,D); qpos (Lq,), kpos (Lk,) int32 positions.
    impl='chunked' scans the full nq*nk grid; impl='tri' scans only the
    statically-needed pairs (causal triangle / window band).
    """
    B, Lq, H, D = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    def fit(L, c):  # largest divisor of L that is <= c
        c = min(c, L)
        while L % c:
            c -= 1
        return c

    q_chunk = fit(Lq, q_chunk)
    k_chunk = fit(Lk, k_chunk)
    nq, nk = Lq // q_chunk, Lk // k_chunk
    G = H // Hkv

    wc = None
    if window is not None:
        wc = (window + k_chunk - 1) // k_chunk + 1
    if impl == "tri":
        pairs = _chunk_pairs(nq, nk, causal=causal, window_chunks=wc)
    else:
        pairs = [(qi, ki) for qi in range(nq) for ki in range(nk)]
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)  # (P, 2)

    qf = q.astype(jnp.float32).reshape(B, nq, q_chunk, Hkv, G, D)
    kf = k.astype(jnp.float32).reshape(B, nk, k_chunk, Hkv, D)
    vf = v.astype(jnp.float32).reshape(B, nk, k_chunk, Hkv, D)
    qpos_c = qpos.reshape(nq, q_chunk)
    kpos_c = kpos.reshape(nk, k_chunk)

    acc0 = jnp.zeros((B, nq, q_chunk, Hkv, G, D), jnp.float32)
    m0 = jnp.full((B, nq, q_chunk, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, q_chunk, Hkv, G), jnp.float32)

    def body(carry, pair):
        acc, m, l = carry
        qi, ki = pair[0], pair[1]
        qc = lax.dynamic_index_in_dim(qf, qi, 1, keepdims=False)
        kc = lax.dynamic_index_in_dim(kf, ki, 1, keepdims=False)
        vc = lax.dynamic_index_in_dim(vf, ki, 1, keepdims=False)
        qp = lax.dynamic_index_in_dim(qpos_c, qi, 0, keepdims=False)
        kp = lax.dynamic_index_in_dim(kpos_c, ki, 0, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc) * scale
        s = s + _mask_bias(qp, kp, causal=causal, window=window)
        m_blk = jnp.max(s, axis=-1)                     # (B,Hkv,G,Lqc)
        m_blk = jnp.moveaxis(m_blk, -1, 1)              # (B,Lqc,Hkv,G)
        m_old = lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        a_old = lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, m_blk)
        p = jnp.exp(s - jnp.moveaxis(m_new, 1, -1)[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.moveaxis(jnp.sum(p, -1), -1, 1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vc)
        a_new = a_old * corr[..., None] + o
        acc = lax.dynamic_update_index_in_dim(acc, a_new, qi, 1)
        m = lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (acc, m, l), None

    (acc, m, l), _ = lax.scan(body, (acc0, m0, l0), pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Lq, H, D).astype(q.dtype)


def attention(q, k, v, *, qpos, kpos, causal=True, window=None,
              impl="chunked", q_chunk=512, k_chunk=512, scale=None,
              replicate_kv: bool = True):
    if replicate_kv:
        k = repeat_kv(k, q.shape[2])
        v = repeat_kv(v, q.shape[2])
    if impl == "dense":
        return dense_attention(q, k, v, qpos=qpos, kpos=kpos, causal=causal,
                               window=window, scale=scale)
    return chunked_attention(q, k, v, qpos=qpos, kpos=kpos, causal=causal,
                             window=window, q_chunk=q_chunk, k_chunk=k_chunk,
                             impl=impl, scale=scale)


# ---------------------------------------------------------------------------
# decode attention (one query token against a long cache)
# ---------------------------------------------------------------------------

def _decode_local(q, k, v, kmask, scale, kv_format="bshd"):
    """Partial attention of q (B,H,D) against local k/v ((B,Sl,Hkv,D) for
    "bshd" / (B,Hkv,Sl,D) for "bhsd" — the latter needs no transpose for
    the score dot, the C1 cache-order win).

    Returns (num (B,H,D), den (B,H), m (B,H)) for LSE combining."""
    B, H, D = q.shape
    Hkv = k.shape[2] if kv_format == "bshd" else k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    klbl = "bshd" if kv_format == "bshd" else "bhsd"
    s = jnp.einsum(f"bhgd,{klbl}->bhgs", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(kmask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    den = jnp.sum(p, axis=-1)
    num = jnp.einsum(f"bhgs,{klbl}->bhgd", p, v.astype(jnp.float32))
    return (num.reshape(B, H, D), den.reshape(B, H), m.reshape(B, H))


def decode_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     combine_axes: Sequence[str] = (), kpos=None,
                     window: Optional[int] = None, kv_format: str = "bshd"):
    """Flash-decoding step. q (B,H,D); caches (B,S,Hkv,D) ["bshd"] or
    (B,Hkv,S,D) ["bhsd"]; cache_len (B,) valid prefix length.  When the
    cache's S dim is sharded (the caller runs this inside shard_map),
    ``combine_axes`` are the mesh axes to LSE-combine over and ``kpos``
    (B, S_local) gives each local slot's global position.
    """
    if kv_format == "bshd":
        B, S, Hkv, D = k_cache.shape
    else:
        B, Hkv, S, D = k_cache.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if kpos is None:
        kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kmask = kpos < cache_len[:, None]
    if window is not None:
        kmask = kmask & (kpos >= (cache_len[:, None] - window))
    num, den, m = _decode_local(q, k_cache, v_cache, kmask, scale,
                                kv_format)
    for ax in combine_axes:
        m_all = lax.pmax(m, ax)
        corr = jnp.exp(m - m_all)
        num = lax.psum(num * corr[..., None], ax)
        den = lax.psum(den * corr, ax)
        m = m_all
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)


def make_sharded_decode_attention(mesh: Mesh, *, batch_axes, seq_axes,
                                  heads_tp: bool, kv_format: str = "bshd"):
    """Wrap :func:`decode_attention` in shard_map for a cache whose sequence
    dim is sharded over ``seq_axes`` (flash-decoding across chips).

    q enters sharded over heads (TP) when ``heads_tp``; it is all-gathered
    (tiny) inside so every seq-shard scores all heads, and the output is
    returned head-sharded again, so the surrounding o-proj TP contraction
    proceeds without resharding.
    """
    ba = tuple(batch_axes) if batch_axes else None
    sa = tuple(seq_axes)
    q_spec = P(ba, "model" if heads_tp else None, None)
    kv_spec = P(ba, sa, None, None) if kv_format == "bshd" \
        else P(ba, None, sa, None)
    len_spec = P(ba)

    def fn(q, k_cache, v_cache, cache_len, window=None):
        S = k_cache.shape[1] if kv_format == "bshd" else k_cache.shape[2]
        nshards = math.prod(mesh.shape[a] for a in sa)
        S_local = S // nshards

        def local(q_l, k_l, v_l, len_l):
            if heads_tp:
                q_full = lax.all_gather(q_l, "model", axis=1, tiled=True)
            else:
                q_full = q_l
            # global slot position of each local cache slot
            idx = 0
            for a in sa:
                idx = idx * mesh.shape[a] + lax.axis_index(a)
            pos0 = idx * S_local
            kpos = (pos0 + jnp.arange(S_local, dtype=jnp.int32))[None]
            kpos = jnp.broadcast_to(kpos, (q_l.shape[0], S_local))
            out = decode_attention(q_full, k_l, v_l, len_l,
                                   combine_axes=sa, kpos=kpos, window=window,
                                   kv_format=kv_format)
            if heads_tp:
                h_l = q_l.shape[1]
                out = lax.dynamic_slice_in_dim(
                    out, lax.axis_index("model") * h_l, h_l, axis=1)
            return out

        return jax.shard_map(
            local, mesh=mesh,
            in_specs=(q_spec, kv_spec, kv_spec, len_spec),
            out_specs=q_spec, check_vma=False,
        )(q, k_cache, v_cache, cache_len)

    return fn
