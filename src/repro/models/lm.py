"""LM assembly: init, train forward (loss), prefill, and decode for every
assigned architecture, built from the uniform layer blocks.

Layers are stacked into scan groups (``cfg.pattern`` repeats; e.g. gemma3
scans 8 groups of [L,L,L,L,L,A], recurrentgemma scans 12 of [R,R,A] plus a
[R,R] tail) so the HLO stays small enough to compile 40 dry-run cells x 2
meshes on one CPU core, and so remat policy applies per group.

Sharding: all weight placement comes from logical axes (models/common);
activations are constrained to batch-over-DP at layer boundaries; the
vocab-sharded logits/CE never materialize an unsharded (B, S, V) array.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .blocks import (ShardCtx, init_layer, init_norm, layer_decode,
                     layer_forward, make_layer_cache, norm_apply)
from .common import ParamTree, count_params, stack_layers
from .config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_group_stack(pt: ParamTree, cfg: ModelConfig, pattern, n_groups: int,
                      tp: int, *, cross: bool, name: str) -> None:
    trees = []
    for _ in range(n_groups):
        g = pt.child()
        for i, kind in enumerate(pattern):
            init_layer(g, cfg, kind, tp, cross=cross, name=f"p{i}")
        trees.append((g.params, g.specs))
    params, specs = stack_layers(trees)
    pt.params[name] = params
    pt.specs[name] = specs


def init_lm(cfg: ModelConfig, key: jax.Array, tp: int = 1):
    """-> (params, logical-spec tree)."""
    pt = ParamTree(key, dtype=cfg.param_jdtype)
    Vp = cfg.padded_vocab(tp)
    d = cfg.d_model
    pt.dense("embed", (Vp, d), ("vocab", "embed"), fan_in=d)
    if cfg.frontend_dim:
        pt.dense("frontend_proj", (cfg.frontend_dim, d), (None, "embed"),
                 fan_in=cfg.frontend_dim)
    if cfg.is_encdec:
        _init_group_stack(pt, cfg, ("A",), cfg.enc_layers, tp,
                          cross=False, name="encoder")
        enc_norm = pt.child()
        init_norm(enc_norm, cfg, "ln", d)
        pt.sub("enc_final", enc_norm)
    n_groups, pattern, tail = cfg.layer_groups()
    _init_group_stack(pt, cfg, pattern, n_groups, tp,
                      cross=cfg.is_encdec, name="groups")
    for i, kind in enumerate(tail):
        t = pt.child()
        init_layer(t, cfg, kind, tp, cross=cfg.is_encdec, name="layer")
        pt.sub(f"tail{i}", t)
    fin = pt.child()
    init_norm(fin, cfg, "ln", d)
    pt.sub("final", fin)
    if not cfg.tie_embeddings:
        pt.dense("head", (Vp, d), ("vocab", "embed"), fan_in=d)
    return pt.params, pt.specs


def param_count(cfg: ModelConfig, tp: int = 1) -> int:
    shapes = jax.eval_shape(
        lambda k: init_lm(cfg, k, tp)[0], jax.random.PRNGKey(0))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig, ctx: ShardCtx):
    h = params["embed"].astype(cfg.compute_jdtype)[tokens]
    if cfg.scale_embed:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def lm_logits(params, h, cfg: ModelConfig, ctx: ShardCtx):
    w = params.get("head", params["embed"]).astype(h.dtype)
    logits = jnp.einsum("...d,vd->...v", h, w)
    logits = ctx.constrain(logits, P(ctx.ba, *([None] * (logits.ndim - 2)),
                                     ctx.rules.get("vocab")))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    Vp = w.shape[0]
    if Vp != cfg.vocab_size:
        mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, NEG_INF)
    return logits


def ce_loss(logits: jax.Array, labels: jax.Array):
    """Mean CE over positions with label >= 0."""
    valid = (labels >= 0)
    lab = jnp.maximum(labels, 0)
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    per_tok = (lse - ll) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(per_tok) / n


# ---------------------------------------------------------------------------
# encoder (enc-dec archs)
# ---------------------------------------------------------------------------

def encode(params, frames, cfg: ModelConfig, ctx: ShardCtx):
    """frames (B, S_enc, frontend_dim) from the modality stub -> enc_out."""
    h = frames.astype(cfg.compute_jdtype) @ \
        params["frontend_proj"].astype(cfg.compute_jdtype)
    h = ctx.constrain(h, P(ctx.ba, None, None))

    def gfn(carry, gp):
        h = carry
        h, _, _ = layer_forward(gp["p0"], h, "A", cfg, ctx, causal=False)
        return h, None

    body = jax.checkpoint(gfn) if cfg.remat == "full" else gfn
    h, _ = lax.scan(body, h, params["encoder"])
    return norm_apply(params["enc_final"], h, cfg, "ln")


# ---------------------------------------------------------------------------
# full-sequence decoder pass (train / prefill)
# ---------------------------------------------------------------------------

def decoder_pass(params, h, cfg: ModelConfig, ctx: ShardCtx, *,
                 positions=None, enc_out=None, want_cache=False):
    """-> (h, aux_loss, caches|None); caches = {"groups": stacked, "tail": [...]}"""
    n_groups, pattern, tail = cfg.layer_groups()

    def gfn(carry, gp):
        h = carry
        aux_t = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(pattern):
            h, aux, c = layer_forward(gp[f"p{i}"], h, kind, cfg, ctx,
                                      causal=True, positions=positions,
                                      enc_out=enc_out,
                                      want_cache=want_cache)
            aux_t = aux_t + aux
            if want_cache:
                caches[f"p{i}"] = c
        return h, (aux_t, caches if want_cache else None)

    body = jax.checkpoint(gfn) if cfg.remat == "full" else gfn
    h, (auxs, group_caches) = lax.scan(body, h, params["groups"])
    aux_total = jnp.sum(auxs)
    tail_caches = []
    for i, kind in enumerate(tail):
        h, aux, c = layer_forward(params[f"tail{i}"]["layer"], h, kind, cfg,
                                  ctx, causal=True, positions=positions,
                                  enc_out=enc_out, want_cache=want_cache)
        aux_total = aux_total + aux
        tail_caches.append(c)
    h = norm_apply(params["final"], h, cfg, "ln")
    caches = None
    if want_cache:
        caches = {"groups": group_caches, "tail": tail_caches}
    return h, aux_total, caches


def assemble_input(params, batch, cfg: ModelConfig, ctx: ShardCtx):
    """Token (+frontend) embeddings -> (h, positions, enc_out)."""
    tokens = batch["tokens"]
    h = embed_tokens(params, tokens, cfg, ctx)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, batch["frames"], cfg, ctx)
    elif cfg.frontend_dim and "patches" in batch:
        pe = batch["patches"].astype(h.dtype) @ \
            params["frontend_proj"].astype(h.dtype)
        h = jnp.concatenate([pe, h], axis=1)
    h = ctx.constrain(h, P(ctx.ba, None, None))
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    return h, positions, enc_out


def forward_loss(params, batch, cfg: ModelConfig, ctx: ShardCtx, *,
                 aux_weight: float = 0.01):
    """Training objective: CE + aux (MoE load-balance) loss."""
    h, positions, enc_out = assemble_input(params, batch, cfg, ctx)
    h, aux, _ = decoder_pass(params, h, cfg, ctx, positions=positions,
                             enc_out=enc_out)
    logits = lm_logits(params, h, cfg, ctx)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm: frontend positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    loss = ce_loss(logits, labels)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(params, cfg: ModelConfig, batch: int, max_seq: int,
                ctx: ShardCtx, *, enc_len: int = 0):
    """Empty cache pytree matching decoder_pass(want_cache) structure,
    converted for decode (attention caches sized to max_seq / window)."""
    n_groups, pattern, tail = cfg.layer_groups()
    tp = ctx.tp
    dt = cfg.compute_jdtype

    def one(kind):
        c = make_layer_cache(kind, cfg, batch, max_seq, dt, tp)
        if cfg.is_encdec and kind in ("A", "L"):
            cross = make_layer_cache("A", cfg, batch, max(enc_len, 1), dt, tp)
            return {"self": c, "cross": cross}
        return c

    groups = {f"p{i}": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)).copy(), one(k))
        for i, k in enumerate(pattern)}
    return {"groups": groups,
            "tail": [one(k) for k in tail],
            "pos": jnp.zeros((), jnp.int32)}


def _prefill_to_decode_cache(raw, kind, cfg: ModelConfig, batch, max_seq,
                             dtype, tp):
    """Convert a layer_forward cache emission into decode-ready storage."""
    from .blocks import fill_attn_cache, make_attn_cache
    if kind in ("A", "L"):
        k, v = raw
        window = cfg.window if kind == "L" else None
        store = make_attn_cache(cfg, batch, max_seq, window, dtype, tp)
        return fill_attn_cache(store, k, v, cfg, window)
    return raw  # ssm/rglru states are already decode-ready


def prefill(params, batch, cfg: ModelConfig, ctx: ShardCtx, *,
            max_seq: Optional[int] = None):
    """Process the prompt; -> (last-token logits (B, Vp), caches)."""
    h, positions, enc_out = assemble_input(params, batch, cfg, ctx)
    B, S = h.shape[0], h.shape[1]
    max_seq = max_seq or S
    h, _, raw = decoder_pass(params, h, cfg, ctx, positions=positions,
                             enc_out=enc_out, want_cache=True)
    n_groups, pattern, tail = cfg.layer_groups()
    dt = cfg.compute_jdtype

    def conv_group(i, kind):
        entry = jax.tree.map(
            lambda *_: None, None)  # placeholder, replaced below
        raw_i = raw["groups"][f"p{i}"]
        conv = jax.vmap(
            lambda r: _prefill_to_decode_cache(r, kind, cfg, B, max_seq,
                                               dt, ctx.tp))(raw_i)
        if cfg.is_encdec and kind in ("A", "L"):
            # cross-attention cache: encoder k/v per group layer
            def cross_of(gp):
                p = gp[f"p{i}"]["cross"]
                k = jnp.einsum("bsd,dhk->bshk", enc_out,
                               p["wk"].astype(dt))
                v = jnp.einsum("bsd,dhk->bshk", enc_out,
                               p["wv"].astype(dt))
                store = _prefill_to_decode_cache((k, v), "A", cfg, B,
                                                 enc_out.shape[1], dt, ctx.tp)
                return store
            cross = jax.lax.map(cross_of, params["groups"])
            return {"self": conv, "cross": cross}
        return conv

    groups = {f"p{i}": conv_group(i, k) for i, k in enumerate(pattern)}
    tails = []
    for i, kind in enumerate(tail):
        c = _prefill_to_decode_cache(raw["tail"][i], kind, cfg, B, max_seq,
                                     dt, ctx.tp)
        if cfg.is_encdec and kind in ("A", "L"):
            p = params[f"tail{i}"]["layer"]["cross"]
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
            c = {"self": c,
                 "cross": _prefill_to_decode_cache((k, v), "A", cfg, B,
                                                   enc_out.shape[1], dt,
                                                   ctx.tp)}
        tails.append(c)
    caches = {"groups": groups, "tail": tails,
              "pos": jnp.asarray(S, jnp.int32)}
    logits = lm_logits(params, h[:, -1], cfg, ctx)
    return logits, caches


def decode_step(params, caches, tokens_t, cfg: ModelConfig, ctx: ShardCtx, *,
                enc_len: Optional[int] = None):
    """One token for the whole batch. tokens_t (B,) -> (logits, caches)."""
    pos = caches["pos"]
    h_t = embed_tokens(params, tokens_t, cfg, ctx)
    h_t = ctx.constrain(h_t, P(ctx.ba, None))
    n_groups, pattern, tail = cfg.layer_groups()

    def gfn(carry, xs):
        h_t = carry
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(pattern):
            c = gc[f"p{i}"]
            if isinstance(c, dict):  # encdec
                h_t, cs = layer_decode(gp[f"p{i}"], h_t, kind, cfg, ctx,
                                       cache=c["self"], pos=pos,
                                       enc_cache=c["cross"], enc_len=enc_len)
                new_c[f"p{i}"] = {"self": cs, "cross": c["cross"]}
            else:
                h_t, cs = layer_decode(gp[f"p{i}"], h_t, kind, cfg, ctx,
                                       cache=c, pos=pos)
                new_c[f"p{i}"] = cs
        return h_t, new_c

    h_t, new_groups = lax.scan(gfn, h_t, (params["groups"], caches["groups"]))
    new_tail = []
    for i, kind in enumerate(tail):
        c = caches["tail"][i]
        if isinstance(c, dict):
            h_t, cs = layer_decode(params[f"tail{i}"]["layer"], h_t, kind,
                                   cfg, ctx, cache=c["self"], pos=pos,
                                   enc_cache=c["cross"], enc_len=enc_len)
            new_tail.append({"self": cs, "cross": c["cross"]})
        else:
            h_t, cs = layer_decode(params[f"tail{i}"]["layer"], h_t, kind,
                                   cfg, ctx, cache=c, pos=pos)
            new_tail.append(cs)
    h_t = norm_apply(params["final"], h_t, cfg, "ln")
    logits = lm_logits(params, h_t, cfg, ctx)
    return logits, {"groups": new_groups, "tail": new_tail, "pos": pos + 1}
