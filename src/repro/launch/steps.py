"""Step builders: sharded train / prefill / decode step functions and the
ShapeDtypeStruct input/state specs the multi-pod dry-run lowers against.

Sharding scheme (DESIGN.md §5):

  train/prefill   batch over DP axes ("pod","data"); heads / d_ff / vocab /
                  expert-TP over "model"; experts over "data" (explicit-a2a
                  EP); residual d_model over "model" between layers
                  (Megatron SP) so remat-saved carries are TP-sharded;
                  optimizer moments additionally over DP (ZeRO-1).
  decode          batch over DP; KV-cache *sequence* over "model"
                  (flash-decoding LSE combine); ring caches replicated.
  long_500k (B=1) cache sequence over ALL axes; batch unsharded.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import kvcache as kvc
from repro.models.blocks import ShardCtx
from repro.models.common import DEFAULT_RULES, spec_tree_to_pspecs
from repro.models.config import ModelConfig, ShapeCfg
from repro.models.lm import (decode_step, forward_loss, init_caches, init_lm,
                             prefill)
from repro.models.moe import make_moe_a2a
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer
from .mesh import dp_axes, tp_size

ENC_LEN_SERVE = 4096  # frozen encoder length for enc-dec decode cells


# ---------------------------------------------------------------------------
# rules / ctx
# ---------------------------------------------------------------------------

def fsdp_pspec(shape: tuple, mesh: Mesh) -> P:
    """ZeRO-3 placement: shard the first dim divisible by the flat mesh
    (data x model), falling back to model-only / data-only / replicated.
    XLA inserts the per-layer weight all-gather inside the layer scan."""
    axes_options = [tuple(a for a in ("data", "model")
                          if mesh.shape.get(a, 1) > 1),
                    ("model",), ("data",)]
    for axes in axes_options:
        if not axes or any(a not in mesh.shape for a in axes):
            continue
        n = math.prod(mesh.shape[a] for a in axes)
        if n <= 1:
            continue
        for i, s in enumerate(shape):
            if s % n == 0 and s >= n:
                entries: list = [None] * len(shape)
                entries[i] = axes if len(axes) > 1 else axes[0]
                return P(*entries)
    return P()


def make_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    rules = dict(DEFAULT_RULES)
    tp = tp_size(mesh)
    if tp <= 1 or cfg.train_sharding == "fsdp":
        return {k: None for k in rules}
    if cfg.n_kv_heads % tp:
        rules["kv_heads"] = None
    if cfg.d_ff and cfg.d_ff % tp:
        rules["ff"] = None
    if cfg.lru_width and cfg.lru_width % tp:
        rules["rnn"] = None
    if cfg.n_experts:
        data = mesh.shape.get("data", 1)
        if data > 1 and cfg.n_experts % data == 0:
            rules["experts"] = "data"
            rules["expert_ff"] = "model" if cfg.d_ff % tp == 0 else None
        elif cfg.n_experts % tp == 0:
            rules["experts"] = "model"
            rules["expert_ff"] = None
        else:
            rules["experts"] = None
            rules["expert_ff"] = "model" if cfg.d_ff % tp == 0 else None
    return rules


def make_ctx(cfg: ModelConfig, mesh: Optional[Mesh],
             shape: Optional[ShapeCfg] = None) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    rules = make_rules(cfg, mesh)
    dp = dp_axes(mesh)
    batch_axes: tuple = dp
    seq_axes: tuple = ()
    moe_a2a = None
    if cfg.train_sharding == "fsdp" and (shape is None
                                         or shape.kind == "train"):
        # batch over as many axes as divide the PER-MICROBATCH batch
        # (ZeRO-3 data parallelism; grad accumulation shrinks the live
        # batch, so mb > 1 can force dp-only sharding — see EXPERIMENTS
        # §Perf cell 1 iter 4, where the naive combination replicated
        # compute 2x)
        B = (shape.global_batch // max(cfg.microbatches, 1)
             if shape is not None else 0)
        for cand in (dp + ("model",), dp):
            n = math.prod(mesh.shape[a] for a in cand)
            if B == 0 or B % n == 0:
                batch_axes = cand
                break
        return ShardCtx(mesh=mesh, rules=rules, batch_axes=batch_axes,
                        residual_tp=False)
    if shape is not None and shape.is_decode:
        if shape.global_batch == 1:
            batch_axes = ()
            seq_axes = tuple(mesh.axis_names)       # all axes shard the cache
        else:
            seq_axes = ("model",) if tp_size(mesh) > 1 else ()
    elif cfg.n_experts and rules.get("experts") == "data" \
            and (shape is None or not shape.is_decode):
        moe_a2a = make_moe_a2a(mesh, dp_axes=dp, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               residual_tp=cfg.shard_activations)
    return ShardCtx(mesh=mesh, rules=rules, batch_axes=batch_axes,
                    decode_seq_axes=seq_axes,
                    residual_tp=cfg.shard_activations and tp_size(mesh) > 1,
                    moe_a2a=moe_a2a)


# ---------------------------------------------------------------------------
# params: shapes + shardings (no allocation)
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, mesh: Mesh):
    """-> (param ShapeDtypeStructs WITH shardings, pspec tree)."""
    tp = 1 if cfg.train_sharding == "fsdp" else tp_size(mesh)
    shapes = jax.eval_shape(lambda k: init_lm(cfg, k, tp)[0],
                            jax.random.PRNGKey(0))
    spec_tree = init_specs_only(cfg, tp)
    rules = make_rules(cfg, mesh)
    pspecs = spec_tree_to_pspecs(spec_tree, rules)
    if cfg.train_sharding == "fsdp":
        pspecs = jax.tree.map(lambda s: fsdp_pspec(s.shape, mesh), shapes)
    sds = jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        shapes, pspecs)
    return sds, pspecs


_SPEC_CACHE: dict = {}


def init_specs_only(cfg: ModelConfig, tp: int):
    key = (cfg, tp)
    if key not in _SPEC_CACHE:
        # tracing init_lm just for the spec tree is cheap under eval_shape;
        # specs are returned as aux (static python objects survive)
        holder = {}

        def fn(k):
            p, s = init_lm(cfg, k, tp)
            holder["specs"] = s
            return p

        jax.eval_shape(fn, jax.random.PRNGKey(0))
        _SPEC_CACHE[key] = holder["specs"]
    return _SPEC_CACHE[key]


# ---------------------------------------------------------------------------
# input specs per shape cell
# ---------------------------------------------------------------------------

def batch_arrays(cfg: ModelConfig, shape: ShapeCfg, *, np_like=False):
    """Concrete small-dtype host arrays for smoke runs (unsharded)."""
    import numpy as np
    B, S = shape.global_batch, shape.seq_len
    S_text = S - (cfg.frontend_tokens if cfg.frontend_dim
                  and not cfg.is_encdec else 0)
    rng = np.random.default_rng(0)
    out = {"tokens": rng.integers(0, cfg.vocab_size, (B, S_text),
                                  dtype=np.int32)}
    if shape.kind == "train":
        out["labels"] = rng.integers(0, cfg.vocab_size, (B, S_text),
                                     dtype=np.int32)
    if cfg.is_encdec:
        enc = S if shape.kind == "train" else ENC_LEN_SERVE
        out["frames"] = rng.standard_normal((B, enc, cfg.frontend_dim)
                                            ).astype(np.float32)
    elif cfg.frontend_dim:
        out["patches"] = rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of this (arch x shape) cell."""
    ctx = make_ctx(cfg, mesh, shape)
    ba = ctx.ba
    cdt = cfg.compute_jdtype

    def sds(shape_, dtype, spec):
        return jax.ShapeDtypeStruct(shape_, dtype,
                                    sharding=NamedSharding(mesh, spec))

    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        return {"tokens": sds((B,), jnp.int32, P(ba))}
    S_text = S - (cfg.frontend_tokens if cfg.frontend_dim
                  and not cfg.is_encdec else 0)
    out = {"tokens": sds((B, S_text), jnp.int32, P(ba, None))}
    if shape.kind == "train":
        out["labels"] = sds((B, S_text), jnp.int32, P(ba, None))
    if cfg.is_encdec:
        enc = S if shape.kind == "train" else ENC_LEN_SERVE
        out["frames"] = sds((B, enc, cfg.frontend_dim), cdt, P(ba, None, None))
    elif cfg.frontend_dim:
        out["patches"] = sds((B, cfg.frontend_tokens, cfg.frontend_dim), cdt,
                             P(ba, None, None))
    return out


# ---------------------------------------------------------------------------
# decode cache specs
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx):
    """PartitionSpec tree exactly mirroring init_caches structure."""
    ba = ctx.ba
    sa = tuple(ctx.decode_seq_axes) or None

    def kv_specs(seq_sharded: bool, lead: bool):
        ps = kvc.kv_pspec(cfg.kv_layout, batch_axes=ctx.batch_axes,
                          seq_axes=(sa if seq_sharded else None),
                          order=cfg.kv_order)
        return P(None, *ps) if lead else ps

    def entry(kind: str, lead: bool):
        ldim = (None,) if lead else ()
        if kind == "A":
            e = kv_specs(True, lead)
        elif kind == "L":
            e = kv_specs(False, lead)
        elif kind == "M":
            e = (P(*ldim, ba, "model" if ctx.tp > 1 else None, None, None),
                 P(*ldim, ba, None, None))
        elif kind == "R":
            r = "model" if (ctx.tp > 1 and cfg.lru_width % ctx.tp == 0) \
                else None
            e = (P(*ldim, ba, r), P(*ldim, ba, None, r))
        else:
            raise ValueError(kind)
        if cfg.is_encdec and kind in ("A", "L"):
            return {"self": e, "cross": kv_specs(True, lead)}
        return e

    n_groups, pattern, tail = cfg.layer_groups()
    return {"groups": {f"p{i}": entry(k, True)
                       for i, k in enumerate(pattern)},
            "tail": [entry(k, False) for k in tail],
            "pos": P()}


def decode_state_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    """ShapeDtypeStructs (with shardings) for the decode cache pytree."""
    ctx = make_ctx(cfg, mesh, shape)
    B, S = shape.global_batch, shape.seq_len
    enc_len = ENC_LEN_SERVE if cfg.is_encdec else 0
    shapes = jax.eval_shape(
        lambda: init_caches(None, cfg, B, S, ctx, enc_len=enc_len))
    pspecs = cache_pspecs(cfg, ctx)
    return jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        shapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)), pspecs


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], *,
                    lr=None, total_steps: int = 10_000,
                    clip_norm: float = 1.0):
    """-> train_step(state, batch) -> (state, metrics); state = {params,
    opt, step}."""
    ctx = make_ctx(cfg, mesh, None)
    opt = make_optimizer(cfg.optimizer,
                         lr or cosine_schedule(3e-4, 200, total_steps))
    k = cfg.microbatches

    def loss_fn(params, mb):
        return forward_loss(params, mb, cfg, ctx)

    def train_step(state, batch):
        params = state["params"]
        if k > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

            def body(acc, mb):
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, _) = lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = jnp.mean(losses)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss.astype(jnp.float32),
                           "grad_norm": gnorm.astype(jnp.float32)}

    return train_step, opt


def train_state_specs(cfg: ModelConfig, mesh: Mesh, opt):
    """ShapeDtypeStructs + shardings for the full train state."""
    p_sds, p_pspecs = param_specs(cfg, mesh)
    o_shapes = jax.eval_shape(opt.init, p_sds)
    o_pspecs = opt.state_pspecs(p_sds, p_pspecs, mesh, dp_axes(mesh),
                                zero1=cfg.zero1)
    o_sds = jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        o_shapes, o_pspecs)
    step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
    state_sds = {"params": p_sds, "opt": o_sds, "step": step_sds}
    state_pspecs = {"params": p_pspecs, "opt": o_pspecs, "step": P()}
    return state_sds, state_pspecs


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh],
                      shape: Optional[ShapeCfg] = None):
    ctx = make_ctx(cfg, mesh, shape)

    def prefill_step(params, batch):
        return prefill(params, batch, cfg, ctx)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh],
                     shape: Optional[ShapeCfg] = None):
    ctx = make_ctx(cfg, mesh, shape)
    enc_len = ENC_LEN_SERVE if cfg.is_encdec else None

    def step(params, caches, tokens):
        logits, caches = decode_step(params, caches, tokens, cfg, ctx,
                                     enc_len=enc_len)
        return logits, caches

    return step
