"""Step builders: sharded train / prefill / decode step functions and the
ShapeDtypeStruct input/state specs the multi-pod dry-run lowers against.

Sharding scheme (DESIGN.md §5):

  train/prefill   batch over DP axes ("pod","data"); heads / d_ff / vocab /
                  expert-TP over "model"; experts over "data" (explicit-a2a
                  EP); residual d_model over "model" between layers
                  (Megatron SP) so remat-saved carries are TP-sharded;
                  optimizer moments additionally over DP (ZeRO-1).
  decode          batch over DP; KV-cache *sequence* over "model"
                  (flash-decoding LSE combine); ring caches replicated.
  long_500k (B=1) cache sequence over ALL axes; batch unsharded.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph import Graph
from repro.core.layout import RecordArray
from repro.core.tensor import DistTensor
from repro.models import kvcache as kvc
from repro.models.blocks import ShardCtx, layer_decode, norm_apply
from repro.models.common import DEFAULT_RULES, spec_tree_to_pspecs
from repro.models.config import ModelConfig, ShapeCfg
from repro.models.lm import (_prefill_to_decode_cache, decode_step,
                             decoder_pass, embed_tokens, forward_loss,
                             init_caches, init_lm, lm_logits, prefill)
from repro.models.moe import make_moe_a2a
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer
from .mesh import dp_axes, tp_size

ENC_LEN_SERVE = 4096  # frozen encoder length for enc-dec decode cells


# ---------------------------------------------------------------------------
# rules / ctx
# ---------------------------------------------------------------------------

def fsdp_pspec(shape: tuple, mesh: Mesh) -> P:
    """ZeRO-3 placement: shard the first dim divisible by the flat mesh
    (data x model), falling back to model-only / data-only / replicated.
    XLA inserts the per-layer weight all-gather inside the layer scan."""
    axes_options = [tuple(a for a in ("data", "model")
                          if mesh.shape.get(a, 1) > 1),
                    ("model",), ("data",)]
    for axes in axes_options:
        if not axes or any(a not in mesh.shape for a in axes):
            continue
        n = math.prod(mesh.shape[a] for a in axes)
        if n <= 1:
            continue
        for i, s in enumerate(shape):
            if s % n == 0 and s >= n:
                entries: list = [None] * len(shape)
                entries[i] = axes if len(axes) > 1 else axes[0]
                return P(*entries)
    return P()


def make_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    rules = dict(DEFAULT_RULES)
    tp = tp_size(mesh)
    if tp <= 1 or cfg.train_sharding == "fsdp":
        return {k: None for k in rules}
    if cfg.n_kv_heads % tp:
        rules["kv_heads"] = None
    if cfg.d_ff and cfg.d_ff % tp:
        rules["ff"] = None
    if cfg.lru_width and cfg.lru_width % tp:
        rules["rnn"] = None
    if cfg.n_experts:
        data = mesh.shape.get("data", 1)
        if data > 1 and cfg.n_experts % data == 0:
            rules["experts"] = "data"
            rules["expert_ff"] = "model" if cfg.d_ff % tp == 0 else None
        elif cfg.n_experts % tp == 0:
            rules["experts"] = "model"
            rules["expert_ff"] = None
        else:
            rules["experts"] = None
            rules["expert_ff"] = "model" if cfg.d_ff % tp == 0 else None
    return rules


def make_ctx(cfg: ModelConfig, mesh: Optional[Mesh],
             shape: Optional[ShapeCfg] = None) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    rules = make_rules(cfg, mesh)
    dp = dp_axes(mesh)
    batch_axes: tuple = dp
    seq_axes: tuple = ()
    moe_a2a = None
    if cfg.train_sharding == "fsdp" and (shape is None
                                         or shape.kind == "train"):
        # batch over as many axes as divide the PER-MICROBATCH batch
        # (ZeRO-3 data parallelism; grad accumulation shrinks the live
        # batch, so mb > 1 can force dp-only sharding — see EXPERIMENTS
        # §Perf cell 1 iter 4, where the naive combination replicated
        # compute 2x)
        B = (shape.global_batch // max(cfg.microbatches, 1)
             if shape is not None else 0)
        for cand in (dp + ("model",), dp):
            n = math.prod(mesh.shape[a] for a in cand)
            if B == 0 or B % n == 0:
                batch_axes = cand
                break
        return ShardCtx(mesh=mesh, rules=rules, batch_axes=batch_axes,
                        residual_tp=False)
    if shape is not None and shape.is_decode:
        if shape.global_batch == 1:
            batch_axes = ()
            seq_axes = tuple(mesh.axis_names)       # all axes shard the cache
        else:
            seq_axes = ("model",) if tp_size(mesh) > 1 else ()
    elif cfg.n_experts and rules.get("experts") == "data" \
            and (shape is None or not shape.is_decode):
        moe_a2a = make_moe_a2a(mesh, dp_axes=dp, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               residual_tp=cfg.shard_activations)
    return ShardCtx(mesh=mesh, rules=rules, batch_axes=batch_axes,
                    decode_seq_axes=seq_axes,
                    residual_tp=cfg.shard_activations and tp_size(mesh) > 1,
                    moe_a2a=moe_a2a)


# ---------------------------------------------------------------------------
# params: shapes + shardings (no allocation)
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, mesh: Mesh):
    """-> (param ShapeDtypeStructs WITH shardings, pspec tree)."""
    tp = 1 if cfg.train_sharding == "fsdp" else tp_size(mesh)
    shapes = jax.eval_shape(lambda k: init_lm(cfg, k, tp)[0],
                            jax.random.PRNGKey(0))
    spec_tree = init_specs_only(cfg, tp)
    rules = make_rules(cfg, mesh)
    pspecs = spec_tree_to_pspecs(spec_tree, rules)
    if cfg.train_sharding == "fsdp":
        pspecs = jax.tree.map(lambda s: fsdp_pspec(s.shape, mesh), shapes)
    sds = jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        shapes, pspecs)
    return sds, pspecs


_SPEC_CACHE: dict = {}


def init_specs_only(cfg: ModelConfig, tp: int):
    key = (cfg, tp)
    if key not in _SPEC_CACHE:
        # tracing init_lm just for the spec tree is cheap under eval_shape;
        # specs are returned as aux (static python objects survive)
        holder = {}

        def fn(k):
            p, s = init_lm(cfg, k, tp)
            holder["specs"] = s
            return p

        jax.eval_shape(fn, jax.random.PRNGKey(0))
        _SPEC_CACHE[key] = holder["specs"]
    return _SPEC_CACHE[key]


# ---------------------------------------------------------------------------
# input specs per shape cell
# ---------------------------------------------------------------------------

def batch_arrays(cfg: ModelConfig, shape: ShapeCfg, *, np_like=False):
    """Concrete small-dtype host arrays for smoke runs (unsharded)."""
    import numpy as np
    B, S = shape.global_batch, shape.seq_len
    S_text = S - (cfg.frontend_tokens if cfg.frontend_dim
                  and not cfg.is_encdec else 0)
    rng = np.random.default_rng(0)
    out = {"tokens": rng.integers(0, cfg.vocab_size, (B, S_text),
                                  dtype=np.int32)}
    if shape.kind == "train":
        out["labels"] = rng.integers(0, cfg.vocab_size, (B, S_text),
                                     dtype=np.int32)
    if cfg.is_encdec:
        enc = S if shape.kind == "train" else ENC_LEN_SERVE
        out["frames"] = rng.standard_normal((B, enc, cfg.frontend_dim)
                                            ).astype(np.float32)
    elif cfg.frontend_dim:
        out["patches"] = rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of this (arch x shape) cell."""
    ctx = make_ctx(cfg, mesh, shape)
    ba = ctx.ba
    cdt = cfg.compute_jdtype

    def sds(shape_, dtype, spec):
        return jax.ShapeDtypeStruct(shape_, dtype,
                                    sharding=NamedSharding(mesh, spec))

    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        return {"tokens": sds((B,), jnp.int32, P(ba))}
    S_text = S - (cfg.frontend_tokens if cfg.frontend_dim
                  and not cfg.is_encdec else 0)
    out = {"tokens": sds((B, S_text), jnp.int32, P(ba, None))}
    if shape.kind == "train":
        out["labels"] = sds((B, S_text), jnp.int32, P(ba, None))
    if cfg.is_encdec:
        enc = S if shape.kind == "train" else ENC_LEN_SERVE
        out["frames"] = sds((B, enc, cfg.frontend_dim), cdt, P(ba, None, None))
    elif cfg.frontend_dim:
        out["patches"] = sds((B, cfg.frontend_tokens, cfg.frontend_dim), cdt,
                             P(ba, None, None))
    return out


# ---------------------------------------------------------------------------
# decode cache specs
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx):
    """PartitionSpec tree exactly mirroring init_caches structure."""
    ba = ctx.ba
    sa = tuple(ctx.decode_seq_axes) or None

    def kv_specs(seq_sharded: bool, lead: bool):
        ps = kvc.kv_pspec(cfg.kv_layout, batch_axes=ctx.batch_axes,
                          seq_axes=(sa if seq_sharded else None),
                          order=cfg.kv_order)
        return P(None, *ps) if lead else ps

    def entry(kind: str, lead: bool):
        ldim = (None,) if lead else ()
        if kind == "A":
            e = kv_specs(True, lead)
        elif kind == "L":
            e = kv_specs(False, lead)
        elif kind == "M":
            e = (P(*ldim, ba, "model" if ctx.tp > 1 else None, None, None),
                 P(*ldim, ba, None, None))
        elif kind == "R":
            r = "model" if (ctx.tp > 1 and cfg.lru_width % ctx.tp == 0) \
                else None
            e = (P(*ldim, ba, r), P(*ldim, ba, None, r))
        else:
            raise ValueError(kind)
        if cfg.is_encdec and kind in ("A", "L"):
            return {"self": e, "cross": kv_specs(True, lead)}
        return e

    n_groups, pattern, tail = cfg.layer_groups()
    return {"groups": {f"p{i}": entry(k, True)
                       for i, k in enumerate(pattern)},
            "tail": [entry(k, False) for k in tail],
            "pos": P()}


def decode_state_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    """ShapeDtypeStructs (with shardings) for the decode cache pytree."""
    ctx = make_ctx(cfg, mesh, shape)
    B, S = shape.global_batch, shape.seq_len
    enc_len = ENC_LEN_SERVE if cfg.is_encdec else 0
    shapes = jax.eval_shape(
        lambda: init_caches(None, cfg, B, S, ctx, enc_len=enc_len))
    pspecs = cache_pspecs(cfg, ctx)
    return jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        shapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)), pspecs


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], *,
                    lr=None, total_steps: int = 10_000,
                    clip_norm: float = 1.0):
    """-> train_step(state, batch) -> (state, metrics); state = {params,
    opt, step}."""
    ctx = make_ctx(cfg, mesh, None)
    opt = make_optimizer(cfg.optimizer,
                         lr or cosine_schedule(3e-4, 200, total_steps))
    k = cfg.microbatches

    def loss_fn(params, mb):
        return forward_loss(params, mb, cfg, ctx)

    def train_step(state, batch):
        params = state["params"]
        if k > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

            def body(acc, mb):
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, _) = lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = jnp.mean(losses)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss.astype(jnp.float32),
                           "grad_norm": gnorm.astype(jnp.float32)}

    return train_step, opt


def train_state_specs(cfg: ModelConfig, mesh: Mesh, opt):
    """ShapeDtypeStructs + shardings for the full train state."""
    p_sds, p_pspecs = param_specs(cfg, mesh)
    o_shapes = jax.eval_shape(opt.init, p_sds)
    o_pspecs = opt.state_pspecs(p_sds, p_pspecs, mesh, dp_axes(mesh),
                                zero1=cfg.zero1)
    o_sds = jax.tree.map(
        lambda s, ps: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps)),
        o_shapes, o_pspecs)
    step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
    state_sds = {"params": p_sds, "opt": o_sds, "step": step_sds}
    state_pspecs = {"params": p_pspecs, "opt": o_pspecs, "step": P()}
    return state_sds, state_pspecs


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh],
                      shape: Optional[ShapeCfg] = None):
    ctx = make_ctx(cfg, mesh, shape)

    def prefill_step(params, batch):
        return prefill(params, batch, cfg, ctx)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh],
                     shape: Optional[ShapeCfg] = None):
    ctx = make_ctx(cfg, mesh, shape)
    enc_len = ENC_LEN_SERVE if cfg.is_encdec else None

    def step(params, caches, tokens):
        logits, caches = decode_step(params, caches, tokens, cfg, ctx,
                                     enc_len=enc_len)
        return logits, caches

    return step


# ---------------------------------------------------------------------------
# graph-native serving: prefill + batched greedy decode as Ripple graphs
# ---------------------------------------------------------------------------
#
# The decode step becomes a Graph with one node per unrolled layer.  Every
# attention/sliding-window cache is a *record* DistTensor (fields k, v over
# the (B, S, Hkv) / (B, Hkv, S) space) so the layout solver / measured
# autotuner — not the model code — picks AoS / SoA / AoSoA storage.  The
# node fn reads the RecordArray's layout at trace time and re-derives the
# ModelConfig under it, which makes the model code layout-polymorphic
# without a single `if` at the call site.
#
# Zero-trace serving: node fns close over (cfg, params, ctx).  The ctx is
# cached per (cfg, mesh, shape) below so a worker process that rebuilds the
# graph from the SAME cfg/params objects produces an identical plan
# signature and serves straight from the process-wide executable cache.

_CTX_CACHE: dict = {}


def _serving_ctx(cfg: ModelConfig, mesh: Optional[Mesh],
                 shape: ShapeCfg) -> ShardCtx:
    """make_ctx with an id-stable result (the executable-cache signature
    keys closure cells by object identity)."""
    key = (cfg, None if mesh is None else id(mesh), shape)
    if key not in _CTX_CACHE:
        _CTX_CACHE[key] = make_ctx(cfg, mesh, shape)
    return _CTX_CACHE[key]


@dataclass(frozen=True)
class CacheSlot:
    """One decode-cache layer lifted into named executor state tensors.

    ``group``/``part`` address the layer inside the legacy cache pytree
    (``caches["groups"]["p{part}"][group]``; ``group == -1`` -> tail layer
    ``caches["tail"][part]``).  ``tensors`` is one record DistTensor for
    attention kinds (A/L) and two plain DistTensors for state-space kinds
    (M: ssm state + conv buffer; R: rg-lru state + conv buffer)."""

    label: str
    kind: str
    group: int
    part: int
    tensors: tuple


def _slot_tensors(cfg: ModelConfig, label: str, kind: str, batch: int,
                  max_seq: int, tp: int) -> tuple:
    dt = cfg.compute_jdtype
    if kind in ("A", "L"):
        S = min(cfg.window, max_seq) if kind == "L" else max_seq
        Hkv = cfg.padded_kv_heads(tp)
        space = ((batch, S, Hkv) if cfg.kv_order == "bsh"
                 else (batch, Hkv, S))
        return (DistTensor(f"kv_{label}", space, dtype=dt,
                           spec=kvc.kv_spec(cfg.head_dim),
                           layout=cfg.kv_layout),)
    if kind == "M":
        H = cfg.padded_ssm_heads(tp)
        P_, N, K = cfg.ssm_head_dim, cfg.ssm_state, cfg.d_conv
        return (DistTensor(f"ssm_{label}", (batch, H, P_, N),
                           dtype=jnp.float32),
                DistTensor(f"cv_{label}", (batch, K - 1, H * P_ + 2 * N),
                           dtype=dt))
    if kind == "R":
        R, K = cfg.lru_width, cfg.d_conv
        return (DistTensor(f"rg_{label}", (batch, R), dtype=jnp.float32),
                DistTensor(f"cv_{label}", (batch, K - 1, R), dtype=dt))
    raise ValueError(kind)


def serving_cache_slots(cfg: ModelConfig, batch: int, max_seq: int,
                        tp: int = 1) -> tuple:
    """Every decode-cache layer as a CacheSlot, in legacy scan order
    (g0p0, g0p1, ..., g1p0, ..., tail0, ...) so graph-native decode visits
    layers exactly like ``decode_step``'s lax.scan."""
    n_groups, pattern, tail = cfg.layer_groups()
    slots = []
    for gi in range(n_groups):
        for pi, kind in enumerate(pattern):
            label = f"g{gi}p{pi}"
            slots.append(CacheSlot(label, kind, gi, pi,
                                   _slot_tensors(cfg, label, kind, batch,
                                                 max_seq, tp)))
    for ti, kind in enumerate(tail):
        label = f"t{ti}"
        slots.append(CacheSlot(label, kind, -1, ti,
                               _slot_tensors(cfg, label, kind, batch,
                                             max_seq, tp)))
    return tuple(slots)


def _slot_params(params, gi: int, pi: int):
    if gi < 0:
        return params[f"tail{pi}"]["layer"]
    return jax.tree.map(lambda x: x[gi], params["groups"][f"p{pi}"])


def _guard_graph_serving(cfg: ModelConfig) -> None:
    if cfg.is_encdec or cfg.frontend_dim:
        raise NotImplementedError(
            f"{cfg.name}: graph-native serving covers text-only decoder "
            f"archs; encoder-decoder / VLM archs serve through the legacy "
            f"jit path (launch/serve.py falls back automatically)")


def _embed_node(cfg: ModelConfig, ctx: ShardCtx, params):
    def embed(tokens_t, h_t):
        return embed_tokens(params, tokens_t, cfg, ctx)
    return embed


def _attn_layer_node(cfg: ModelConfig, ctx: ShardCtx, params,
                     slot: CacheSlot):
    gi, pi, kind = slot.group, slot.part, slot.kind

    def layer(h_t, kv, pos):
        # the solver's layout choice arrives on the RecordArray; re-derive
        # the config under it so the kernel code is layout-polymorphic
        lcfg = cfg.with_(kv_layout=kv.layout)
        p = _slot_params(params, gi, pi)
        h2, store = layer_decode(p, h_t, kind, lcfg, ctx,
                                 cache=kv.data, pos=pos)
        return h2, RecordArray(store, kv.spec, kv.layout)

    return layer


def _state_layer_node(cfg: ModelConfig, ctx: ShardCtx, params,
                      slot: CacheSlot):
    gi, pi, kind = slot.group, slot.part, slot.kind

    def layer(h_t, s0, s1, pos):
        p = _slot_params(params, gi, pi)
        h2, (n0, n1) = layer_decode(p, h_t, kind, cfg, ctx,
                                    cache=(s0, s1), pos=pos)
        return h2, n0, n1

    return layer


def _head_node(cfg: ModelConfig, ctx: ShardCtx, params):
    def head(h_t, tokens_t, pos, active):
        hn = norm_apply(params["final"], h_t, cfg, "ln")
        logits = lm_logits(params, hn, cfg, ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(active, nxt, tokens_t)
        return nxt, pos + active.astype(jnp.int32)
    return head


@dataclass(frozen=True)
class DecodeGraph:
    """Graph + tensor handles for one batched greedy-decode step.

    State layout: ``tokens``/``pos``/``active`` are (B,) per-slot vectors
    (continuous batching: every batch slot sits at its own depth; inactive
    slots keep their token and don't advance), ``h`` is the (B, d_model)
    residual scratch, and each CacheSlot contributes its cache tensors."""

    graph: Graph
    tokens: DistTensor
    pos: DistTensor
    active: DistTensor
    h: DistTensor
    slots: tuple


@dataclass(frozen=True)
class PrefillGraph:
    """Graph + tensor handles for a single-request (B=1) prefill.

    Writes every decode-cache slot (batch=1) plus ``first`` — the argmax
    token following the prompt; the batcher scatters these into the decode
    state's batch slot at admission."""

    graph: Graph
    prompt: DistTensor
    hseq: DistTensor
    hlast: DistTensor
    first: DistTensor
    slots: tuple


def cache_state_overrides(cfg: ModelConfig, slots: tuple, caches) -> dict:
    """Map a legacy ``prefill()``/``init_caches()`` cache pytree onto the
    graph state names (``Executor.init_state(**overrides)`` kwargs).
    Attention storages arrive in ``cfg.kv_layout`` and are wrapped as
    RecordArrays so init_state relayouts them to the solver's choice."""
    out = {}
    for slot in slots:
        if slot.group < 0:
            entry = caches["tail"][slot.part]
        else:
            entry = jax.tree.map(lambda x: x[slot.group],
                                 caches["groups"][f"p{slot.part}"])
        if slot.kind in ("A", "L"):
            out[slot.tensors[0].name] = RecordArray(
                entry, kvc.kv_spec(cfg.head_dim), cfg.kv_layout)
        else:
            out[slot.tensors[0].name] = entry[0]
            out[slot.tensors[1].name] = entry[1]
    return out


_SERVE_GRAPH_CACHE: dict = {}


def make_decode_graph(cfg: ModelConfig, params, *, batch: int, max_seq: int,
                      mesh: Optional[Mesh] = None) -> DecodeGraph:
    """One greedy-decode step for ``batch`` slots as a Ripple graph.

    Node order mirrors ``decode_step``'s scan exactly (embed -> every
    unrolled layer in g0p0.. order -> final-norm/logits/argmax head) so
    the argmax token sequence is bit-identical to the legacy jit path."""
    _guard_graph_serving(cfg)
    key = ("decode", id(cfg), id(params), batch, max_seq,
           None if mesh is None else id(mesh))
    if key in _SERVE_GRAPH_CACHE:
        return _SERVE_GRAPH_CACHE[key]
    shape = ShapeCfg(f"serve_decode_b{batch}", "decode", max_seq, batch)
    ctx = _serving_ctx(cfg, mesh, shape)
    tp = 1 if mesh is None else tp_size(mesh)
    tokens = DistTensor("tokens", (batch,), dtype=jnp.int32)
    pos = DistTensor("pos", (batch,), dtype=jnp.int32)
    active = DistTensor("active", (batch,), dtype=jnp.bool_)
    h = DistTensor("h", (batch, cfg.d_model), dtype=cfg.compute_jdtype)
    slots = serving_cache_slots(cfg, batch, max_seq, tp)
    g = Graph(name=f"decode_{cfg.name}")
    g.then(_embed_node(cfg, ctx, params), args=(tokens, h), writes=(1,))
    for slot in slots:
        if slot.kind in ("A", "L"):
            kv, = slot.tensors
            g.then(_attn_layer_node(cfg, ctx, params, slot),
                   args=(h, kv, pos), writes=(0, 1))
        else:
            s0, s1 = slot.tensors
            g.then(_state_layer_node(cfg, ctx, params, slot),
                   args=(h, s0, s1, pos), writes=(0, 1, 2))
    g.then(_head_node(cfg, ctx, params),
           args=(h, tokens, pos, active), writes=(1, 2))
    out = DecodeGraph(g, tokens, pos, active, h, slots)
    _SERVE_GRAPH_CACHE[key] = out
    return out


def make_prefill_graph(cfg: ModelConfig, params, *, prompt_len: int,
                       max_seq: int,
                       mesh: Optional[Mesh] = None) -> PrefillGraph:
    """B=1 prompt processing as a Ripple graph: embed -> decoder pass
    (emitting every layer's decode-ready cache) -> first-token head.

    The cache writes are RecordArrays in ``cfg.kv_layout``; the executor
    relayouts them in-trace to whatever layout its solver chose, so the
    prefill and decode plans may disagree about storage freely."""
    _guard_graph_serving(cfg)
    key = ("prefill", id(cfg), id(params), prompt_len, max_seq,
           None if mesh is None else id(mesh))
    if key in _SERVE_GRAPH_CACHE:
        return _SERVE_GRAPH_CACHE[key]
    shape = ShapeCfg(f"serve_prefill_s{prompt_len}", "prefill",
                     prompt_len, 1)
    ctx = _serving_ctx(cfg, mesh, shape)
    tp = 1 if mesh is None else tp_size(mesh)
    dt = cfg.compute_jdtype
    prompt = DistTensor("prompt", (1, prompt_len), dtype=jnp.int32)
    hseq = DistTensor("hseq", (1, prompt_len, cfg.d_model), dtype=dt)
    hlast = DistTensor("hlast", (1, cfg.d_model), dtype=dt)
    first = DistTensor("first", (1,), dtype=jnp.int32)
    slots = serving_cache_slots(cfg, 1, max_seq, tp)
    flat = tuple(t for slot in slots for t in slot.tensors)

    def body(h_, hl_, *cache_vals):
        positions = jnp.arange(h_.shape[1], dtype=jnp.int32)
        hh = ctx.constrain(h_, P(ctx.ba, None, None))
        hh, _, raw = decoder_pass(params, hh, cfg, ctx,
                                  positions=positions, want_cache=True)
        outs = []
        for slot in slots:
            if slot.group < 0:
                raw_entry = raw["tail"][slot.part]
            else:
                raw_entry = jax.tree.map(lambda x: x[slot.group],
                                         raw["groups"][f"p{slot.part}"])
            store = _prefill_to_decode_cache(raw_entry, slot.kind, cfg, 1,
                                             max_seq, dt, ctx.tp)
            if slot.kind in ("A", "L"):
                outs.append(RecordArray(store, kvc.kv_spec(cfg.head_dim),
                                        cfg.kv_layout))
            else:
                outs.extend(store)
        return (hh[:, -1], *outs)

    def head(hl_, first_):
        logits = lm_logits(params, hl_, cfg, ctx)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    g = Graph(name=f"prefill_{cfg.name}_s{prompt_len}")
    g.then(_embed_node(cfg, ctx, params), args=(prompt, hseq), writes=(1,))
    g.then(body, args=(hseq, hlast, *flat),
           writes=tuple(range(1, 2 + len(flat))))
    g.then(head, args=(hlast, first), writes=(1,))
    out = PrefillGraph(g, prompt, hseq, hlast, first, slots)
    _SERVE_GRAPH_CACHE[key] = out
    return out
