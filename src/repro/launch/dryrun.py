import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function (train_step / prefill_step / decode_step by
     shape kind) and its ShapeDtypeStruct inputs (no allocation),
  3. ``jax.jit(...).lower(...).compile()`` — the SPMD partitioner must
     accept every sharding and the buffer assignment must fit,
  4. records memory_analysis(), cost_analysis(), and the collective-op
     byte census parsed from the optimized HLO (for EXPERIMENTS.md
     §Dry-run and the §Roofline analysis).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.analysis import analyze_hlo, normalize_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as S
from repro.models.config import SHAPES, shapes_for

# per-device collective cost model (bytes through the links), ring algs
_COLL_FACTORS = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _first_shape_bytes(sig: str) -> int:
    """Bytes of the first (or tuple-summed) shape in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Sum per-device collective bytes by op kind from optimized HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_FACTORS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start)?\(", ls)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        b = _first_shape_bytes(sig)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    out["total_link_bytes"] = sum(
        v["bytes"] * _COLL_FACTORS[k] for k, v in out.items()
        if k in _COLL_FACTORS)
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train":
        step_fn, opt = S.make_train_step(cfg, mesh)
        state_sds, state_pspecs = S.train_state_specs(cfg, mesh, opt)
        batch_sds = S.input_specs(cfg, shape, mesh)
        fn = jax.jit(step_fn, donate_argnums=0)
        args = (state_sds, batch_sds)
    elif shape.kind == "prefill":
        step_fn = S.make_prefill_step(cfg, mesh, shape)
        p_sds, _ = S.param_specs(cfg, mesh)
        batch_sds = S.input_specs(cfg, shape, mesh)
        fn = jax.jit(step_fn)
        args = (p_sds, batch_sds)
    else:  # decode
        step_fn = S.make_decode_step(cfg, mesh, shape)
        p_sds, _ = S.param_specs(cfg, mesh)
        c_sds, _ = S.decode_state_specs(cfg, shape, mesh)
        tok_sds = S.input_specs(cfg, shape, mesh)["tokens"]
        fn = jax.jit(step_fn, donate_argnums=1)
        args = (p_sds, c_sds, tok_sds)
    return cfg, shape, mesh, fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    t0 = time.time()
    cfg, shape, mesh, fn, args = build_cell(arch, shape_name, multi_pod)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    t2 = time.time()
    loopaware = analyze_hlo(compiled.as_text())
    t_analyze = time.time() - t2
    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(n_dev),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware per-device numbers (repro.analysis.hlo; cost_analysis
        # counts while bodies once, so it is kept only as xla_* reference)
        "flops": float(loopaware["flops"]),
        "bytes_accessed": float(loopaware["bytes"]),
        "collective_link_bytes": float(loopaware["collective_link_bytes"]),
        "collectives": loopaware["collectives"],
        "xla_flops_body_once": float(cost.get("flops", 0.0)),
        "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        "peak_memory_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"analyze {t_analyze:.0f}s\n"
              f"  flops/dev={rec['flops']:.3e}  "
              f"bytes/dev={rec['bytes_accessed']:.3e}  "
              f"link_bytes/dev={rec['collective_link_bytes']/2**30:.3f}GiB  "
              f"temp/dev={rec['temp_bytes']/2**30:.2f}GiB")
        print(f"  memory_analysis: {mem}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            for shape in shapes_for(cfg):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((configs.ALIASES.get(args.arch, args.arch), args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    failed = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, mp))
            except Exception as e:
                failed += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": False, "error": f"{type(e).__name__}: {e}"})
            finally:
                jax.clear_caches()  # 66 compiled cells would exhaust host RAM
            if args.out:  # checkpoint partial results (long run)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} records to {args.out}")
    print(f"[dryrun] {len(results) - failed}/{len(results)} cells OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
