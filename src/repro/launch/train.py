"""Training launcher: data pipeline -> sharded train step -> supervisor
(checkpoint/restart, straggler stats) -> metrics.

On real hardware this runs under ``jax.distributed.initialize`` with the
production mesh; on this container it runs reduced configs on CPU (the
end-to-end driver for examples/train_lm.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch import steps as S
from repro.launch.mesh import make_mesh
from repro.models.lm import init_lm, param_count
from repro.optim import cosine_schedule
from repro.runtime import Supervisor


def build_trainer(cfg, mesh, *, total_steps: int, peak_lr: float = 3e-4):
    step_fn, opt = S.make_train_step(
        cfg, mesh, lr=cosine_schedule(peak_lr, min(100, total_steps // 10),
                                      total_steps))
    tp = 1 if mesh is None else mesh.shape.get("model", 1)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=tp)
    if mesh is not None:
        p_sds, _ = S.param_specs(cfg, mesh)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                              params, p_sds)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    jstep = jax.jit(step_fn, donate_argnums=0)
    return jstep, state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="",
                    help="e.g. '2x2:data,model' (default: no mesh)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split(":")
        mesh = make_mesh(tuple(int(x) for x in shape_s.split("x")),
                         tuple(axes_s.split(",")))

    print(f"[train] arch={cfg.name} params={param_count(cfg):,} "
          f"steps={args.steps} batch={args.batch}x{args.seq}")
    jstep, state = build_trainer(cfg, mesh, total_steps=args.steps)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    metrics_log = []

    def step_and_log(state, batch):
        state, m = jstep(state, batch)
        metrics_log.append({k: float(v) for k, v in m.items()})
        return state

    def batch_at(i):
        b = data.batch_at(i)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_encdec:
            out["frames"] = jnp.asarray(np.random.default_rng(i)
                                        .standard_normal(
                (args.batch, args.seq, cfg.frontend_dim)).astype(np.float32))
        elif cfg.frontend_dim:
            out["patches"] = jnp.asarray(np.random.default_rng(i)
                                         .standard_normal(
                (args.batch, cfg.frontend_tokens, cfg.frontend_dim))
                .astype(np.float32))
        return out

    sup = Supervisor(step_fn=step_and_log,
                     ckpt=CheckpointManager(args.ckpt_dir),
                     ckpt_every=args.ckpt_every)
    t0 = time.time()
    state = sup.run(state, batch_at, start_step=0, num_steps=args.steps,
                    on_step=lambda s, _: (
                        print(f"[train] step {s}: "
                              f"loss={metrics_log[-1]['loss']:.4f} "
                              f"gnorm={metrics_log[-1]['grad_norm']:.3f} "
                              f"{sup.stats.last*1e3:.0f}ms")
                        if s % args.log_every == 0 else None))
    dt = time.time() - t0
    print(f"[train] done: {args.steps} steps in {dt:.1f}s; "
          f"loss {metrics_log[0]['loss']:.4f} -> {metrics_log[-1]['loss']:.4f}; "
          f"stragglers={len(sup.stats.stragglers)}")
    return metrics_log


if __name__ == "__main__":
    main()
