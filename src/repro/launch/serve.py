"""Serving launcher: prefill + batched greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.launch import steps as S
from repro.models.lm import init_lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    prefill_fn = jax.jit(S.make_prefill_step(cfg, None),
                         static_argnames=())
    decode_fn = jax.jit(S.make_decode_step(cfg, None), donate_argnums=1)

    rng = np.random.default_rng(0)
    B = args.batch
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, args.prompt_len)).astype(np.int32))}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, S.ENC_LEN_SERVE, cfg.frontend_dim)).astype(np.float32))
    elif cfg.frontend_dim:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32))

    t0 = time.time()
    max_seq = args.prompt_len + args.gen + (
        cfg.frontend_tokens if cfg.frontend_dim and not cfg.is_encdec else 0)
    from repro.models.blocks import ShardCtx
    from repro.models.lm import prefill as prefill_raw
    logits, caches = jax.jit(
        lambda p, b: prefill_raw(p, b, cfg, ShardCtx(), max_seq=max_seq)
    )(params, batch)
    t_prefill = time.time() - t0
    toks = jnp.argmax(logits, axis=-1)
    out_tokens = [np.asarray(toks)]
    t1 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = decode_fn(params, caches, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.time() - t1
    gen = np.stack(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.0f}ms; decode "
          f"{t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] sample generations (first 3 rows):\n{gen[:3]}")
    return gen


if __name__ == "__main__":
    main()
